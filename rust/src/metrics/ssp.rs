//! SSP execution-mode metrics: per-round observed staleness and the
//! straggler wait time the pipeline hid relative to a BSP barrier.

/// Accumulated over one SSP run by the coordinator's collect half.
#[derive(Debug, Clone, Default)]
pub struct SspStats {
    /// Staleness observed at each collected round: committed version at
    /// collect time minus the version the round's workers had applied at
    /// dispatch time.  Bounded by the configured staleness.
    pub per_round_staleness: Vec<u64>,
    /// Virtual seconds a strict BSP barrier would have added on top of the
    /// pipeline's actual critical path (straggler wait hidden by SSP).
    pub wait_saved_secs: f64,
}

impl SspStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one collected round.
    pub fn record(&mut self, staleness: u64, wait_saved_secs: f64) {
        self.per_round_staleness.push(staleness);
        self.wait_saved_secs += wait_saved_secs.max(0.0);
    }

    pub fn rounds(&self) -> usize {
        self.per_round_staleness.len()
    }

    pub fn max_staleness(&self) -> u64 {
        self.per_round_staleness.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_staleness(&self) -> f64 {
        if self.per_round_staleness.is_empty() {
            return 0.0;
        }
        self.per_round_staleness.iter().sum::<u64>() as f64
            / self.per_round_staleness.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut s = SspStats::new();
        s.record(0, 0.5);
        s.record(2, 1.5);
        s.record(1, -0.1); // negative savings clamp to zero
        assert_eq!(s.rounds(), 3);
        assert_eq!(s.max_staleness(), 2);
        assert!((s.mean_staleness() - 1.0).abs() < 1e-12);
        assert!((s.wait_saved_secs - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = SspStats::new();
        assert_eq!(s.max_staleness(), 0);
        assert_eq!(s.mean_staleness(), 0.0);
        assert_eq!(s.rounds(), 0);
    }
}
