//! SSP execution-mode metrics: per-round observed staleness, the
//! straggler wait time the pipeline hid relative to a BSP barrier, and —
//! for rotation pipelines — the per-worker handoff wait (virtual seconds
//! a worker idled for a queued slice's handoff to land).

/// Accumulated over one SSP run by the coordinator's collect half.
#[derive(Debug, Clone, Default)]
pub struct SspStats {
    /// Staleness observed at each collected round: committed version at
    /// collect time minus the version the round's workers had applied at
    /// dispatch time.  Bounded by the configured staleness.
    pub per_round_staleness: Vec<u64>,
    /// Virtual seconds a strict BSP barrier would have added on top of the
    /// pipeline's actual critical path (straggler wait hidden by SSP).
    pub wait_saved_secs: f64,
    /// Rotation pipelines: virtual seconds each worker spent stalled
    /// waiting for a queued slice's handoff to land before it could start
    /// that sweep (worker-indexed; empty for non-rotation runs).  This is
    /// the slack availability-ordered queues recover — the
    /// strict-vs-availability delta is quantified here, not just asserted
    /// on end-to-end time.
    pub handoff_wait_secs: Vec<f64>,
    /// Rotation pipelines under `SkipPolicy::Defer`: slice-legs the
    /// schedule skipped (slice in flight, leased in a later round instead
    /// of stalling its holder); 0 under `Never`.
    pub skipped_legs: u64,
    /// Worst per-slice coverage debt observed at any collect (rounds
    /// collected minus grants of the laggiest slice) — the engine-side
    /// cross-check of the scheduler's `CoverageDebtLedger` budget.
    pub max_coverage_debt: u64,
    /// Seconds workers spent *physically blocked* on the slice data plane
    /// (parked on router condvars waiting for a handoff).  ~0 under the
    /// sim backend, where a single-threaded driver only ever takes parked
    /// slices; under `--backend threads` it is the measured router/ledger
    /// contention — the baseline future lock-granularity work is judged
    /// against.
    pub router_block_secs: f64,
    /// Membership-recovery passes completed (one per fault the engine
    /// absorbed: a worker crash or a worker join each count once).
    pub recoveries: u64,
    /// Pipeline rounds flushed early because a fault forced a full window
    /// drain before the membership change could be applied (the pipelining
    /// overlap sacrificed to reach a consistent barrier — the work itself
    /// completes, only its round-overlap is lost).
    pub rounds_lost: u64,
    /// Wall seconds spent serializing KV checkpoints (coordinator +
    /// worker snapshots; 0.0 when `--checkpoint-every` is off).
    pub checkpoint_secs: f64,
    /// Slice forwards the lossy-transport layer retransmitted after a
    /// dropped delivery attempt (0 with no `NetFaultPlan` armed).
    pub retransmits: u64,
    /// Duplicate deliveries the receive side discarded idempotently
    /// (injected dups plus redeliveries of already-delivered versions).
    pub dup_discards: u64,
    /// Wall seconds deliveries spent parked in retransmit backoff before
    /// the payload finally landed (the latency the redelivery protocol
    /// paid to mask drops).
    pub retry_wait_secs: f64,
}

impl SspStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one collected round.
    pub fn record(&mut self, staleness: u64, wait_saved_secs: f64) {
        self.per_round_staleness.push(staleness);
        self.wait_saved_secs += wait_saved_secs.max(0.0);
    }

    /// Accumulate one worker's handoff wait for a collected rotation round
    /// (virtual seconds it idled before a queued slice's sweep could
    /// start).
    pub fn record_handoff_wait(&mut self, worker: usize, secs: f64) {
        if self.handoff_wait_secs.len() <= worker {
            self.handoff_wait_secs.resize(worker + 1, 0.0);
        }
        self.handoff_wait_secs[worker] += secs.max(0.0);
    }

    /// Total handoff wait across workers (0.0 for non-rotation runs).
    pub fn total_handoff_wait_secs(&self) -> f64 {
        self.handoff_wait_secs.iter().sum()
    }

    /// Record one collected round's skipped slice-legs
    /// (`SkipPolicy::Defer`; 0 every round under `Never`).
    pub fn record_skips(&mut self, n: u64) {
        self.skipped_legs += n;
    }

    /// Fold one collect's worst observed per-slice coverage debt into the
    /// run-level maximum.
    pub fn note_coverage_debt(&mut self, debt: u64) {
        self.max_coverage_debt = self.max_coverage_debt.max(debt);
    }

    pub fn rounds(&self) -> usize {
        self.per_round_staleness.len()
    }

    pub fn max_staleness(&self) -> u64 {
        self.per_round_staleness.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_staleness(&self) -> f64 {
        if self.per_round_staleness.is_empty() {
            return 0.0;
        }
        self.per_round_staleness.iter().sum::<u64>() as f64
            / self.per_round_staleness.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut s = SspStats::new();
        s.record(0, 0.5);
        s.record(2, 1.5);
        s.record(1, -0.1); // negative savings clamp to zero
        assert_eq!(s.rounds(), 3);
        assert_eq!(s.max_staleness(), 2);
        assert!((s.mean_staleness() - 1.0).abs() < 1e-12);
        assert!((s.wait_saved_secs - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = SspStats::new();
        assert_eq!(s.max_staleness(), 0);
        assert_eq!(s.mean_staleness(), 0.0);
        assert_eq!(s.rounds(), 0);
        assert_eq!(s.total_handoff_wait_secs(), 0.0);
        assert_eq!(s.skipped_legs, 0);
        assert_eq!(s.max_coverage_debt, 0);
        assert_eq!(s.router_block_secs, 0.0);
        assert_eq!(s.recoveries, 0);
        assert_eq!(s.rounds_lost, 0);
        assert_eq!(s.checkpoint_secs, 0.0);
        assert_eq!(s.retransmits, 0);
        assert_eq!(s.dup_discards, 0);
        assert_eq!(s.retry_wait_secs, 0.0);
    }

    #[test]
    fn skip_and_debt_counters_accumulate() {
        let mut s = SspStats::new();
        s.record_skips(0);
        s.record_skips(2);
        s.record_skips(1);
        s.note_coverage_debt(1);
        s.note_coverage_debt(3);
        s.note_coverage_debt(2); // max, not last
        assert_eq!(s.skipped_legs, 3);
        assert_eq!(s.max_coverage_debt, 3);
    }

    #[test]
    fn handoff_wait_accumulates_per_worker() {
        let mut s = SspStats::new();
        s.record_handoff_wait(2, 0.5);
        s.record_handoff_wait(0, 0.25);
        s.record_handoff_wait(2, 0.5);
        s.record_handoff_wait(1, -1.0); // negative waits clamp to zero
        assert_eq!(s.handoff_wait_secs, vec![0.25, 0.0, 1.0]);
        assert!((s.total_handoff_wait_secs() - 1.25).abs() < 1e-12);
    }
}
