//! Run recorder: (round, virtual time, wall time, objective, extras)
//! trajectories with CSV and JSON emission — the data source for every
//! figure harness.

use crate::util::JsonValue;
use std::io::Write;

/// One sample on a convergence trajectory.
#[derive(Debug, Clone)]
pub struct TrajectoryPoint {
    pub round: u64,
    pub virtual_secs: f64,
    pub wall_secs: f64,
    pub objective: f64,
    /// App-specific extras, e.g. ("s_error", Δ_t) or ("nnz", count).
    pub extras: Vec<(String, f64)>,
}

/// Collects trajectory points for one run.
#[derive(Debug, Default)]
pub struct Recorder {
    pub label: String,
    points: Vec<TrajectoryPoint>,
}

impl Recorder {
    pub fn new(label: &str) -> Self {
        Recorder { label: label.to_string(), points: Vec::new() }
    }

    pub fn record(
        &mut self,
        round: u64,
        virtual_secs: f64,
        wall_secs: f64,
        objective: f64,
    ) {
        self.points.push(TrajectoryPoint {
            round,
            virtual_secs,
            wall_secs,
            objective,
            extras: Vec::new(),
        });
    }

    pub fn record_with(
        &mut self,
        round: u64,
        virtual_secs: f64,
        wall_secs: f64,
        objective: f64,
        extras: Vec<(String, f64)>,
    ) {
        self.points.push(TrajectoryPoint { round, virtual_secs, wall_secs, objective, extras });
    }

    pub fn points(&self) -> &[TrajectoryPoint] {
        &self.points
    }

    pub fn last_objective(&self) -> Option<f64> {
        self.points.last().map(|p| p.objective)
    }

    pub fn best_objective_min(&self) -> Option<f64> {
        self.points.iter().map(|p| p.objective).fold(None, |acc, x| {
            Some(acc.map_or(x, |a: f64| a.min(x)))
        })
    }

    /// First virtual time at which the objective reaches `target`
    /// (`minimize=true`: obj <= target; else obj >= target).
    pub fn time_to_target(&self, target: f64, minimize: bool) -> Option<f64> {
        self.points
            .iter()
            .find(|p| {
                if minimize {
                    p.objective <= target
                } else {
                    p.objective >= target
                }
            })
            .map(|p| p.virtual_secs)
    }

    /// CSV with a header; extras become extra columns (from first point).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("round,virtual_secs,wall_secs,objective");
        let extra_names: Vec<&str> = self
            .points
            .first()
            .map(|p| p.extras.iter().map(|(k, _)| k.as_str()).collect())
            .unwrap_or_default();
        for name in &extra_names {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.8}",
                p.round, p.virtual_secs, p.wall_secs, p.objective
            ));
            for (_, v) in &p.extras {
                out.push_str(&format!(",{v:.8}"));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj()
            .field("label", self.label.as_str())
            .field(
                "points",
                JsonValue::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            let mut o = JsonValue::obj()
                                .field("round", p.round)
                                .field("virtual_secs", p.virtual_secs)
                                .field("wall_secs", p.wall_secs)
                                .field("objective", p.objective);
                            for (k, v) in &p.extras {
                                o = o.field(k, *v);
                            }
                            o.build()
                        })
                        .collect(),
                ),
            )
            .build()
    }

    /// Write CSV to `dir/<label>.csv` (creating `dir`).
    pub fn save_csv(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{}/{}.csv", dir, self.label.replace([' ', '/'], "_"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Recorder {
        let mut r = Recorder::new("test");
        r.record(0, 0.0, 0.0, 100.0);
        r.record(1, 1.0, 0.5, 50.0);
        r.record(2, 2.0, 1.0, 25.0);
        r
    }

    #[test]
    fn time_to_target_minimizing() {
        let r = sample();
        assert_eq!(r.time_to_target(50.0, true), Some(1.0));
        assert_eq!(r.time_to_target(10.0, true), None);
    }

    #[test]
    fn time_to_target_maximizing() {
        let mut r = Recorder::new("ll");
        r.record(0, 0.0, 0.0, -300.0);
        r.record(1, 5.0, 1.0, -200.0);
        assert_eq!(r.time_to_target(-250.0, false), Some(5.0));
    }

    #[test]
    fn csv_shape() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("round,virtual_secs"));
        assert!(lines[1].starts_with("0,"));
    }

    #[test]
    fn extras_become_columns() {
        let mut r = Recorder::new("e");
        r.record_with(0, 0.0, 0.0, 1.0, vec![("s_error".into(), 0.001)]);
        let csv = r.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(",s_error"));
        assert!(csv.contains("0.00100000"));
    }

    #[test]
    fn best_objective() {
        assert_eq!(sample().best_objective_min(), Some(25.0));
        assert_eq!(sample().last_objective(), Some(25.0));
    }

    #[test]
    fn json_emits() {
        let j = sample().to_json().to_json();
        assert!(j.contains("\"label\":\"test\""));
        assert!(j.contains("\"points\":["));
    }
}
