//! The paper's s-error (eq. 1): parallelization error in the LDA topic
//! column sums.
//!
//!   Δ_t = (1 / (P·M)) · Σ_p ‖ s̃^p − s ‖₁
//!
//! where s̃^p is worker p's stale local copy of the topic column sums at the
//! end of its push, s is the true (post-pull) value, P is the number of
//! workers and M the total token count.  Δ_t ∈ [0, 2]; the paper's Fig 5
//! shows Δ_t ≤ 0.002 throughout.

/// Compute Δ_t given each worker's local copy and the true sums.
pub fn s_error(local_copies: &[Vec<f32>], s_true: &[f32], n_tokens: usize) -> f64 {
    if local_copies.is_empty() || n_tokens == 0 {
        return 0.0;
    }
    let p = local_copies.len() as f64;
    let m = n_tokens as f64;
    let mut total = 0.0f64;
    for local in local_copies {
        debug_assert_eq!(local.len(), s_true.len());
        for (a, b) in local.iter().zip(s_true.iter()) {
            total += (a - b).abs() as f64;
        }
    }
    total / (p * m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_when_in_sync() {
        let s = vec![10.0, 20.0, 30.0];
        assert_eq!(s_error(&[s.clone(), s.clone()], &s, 60), 0.0);
    }

    #[test]
    fn matches_hand_computation() {
        let s_true = vec![10.0, 20.0];
        let locals = vec![vec![11.0, 19.0], vec![10.0, 22.0]];
        // L1 dists: 2 and 2; P=2, M=30 -> (2+2)/(2*30)
        let want = 4.0 / 60.0;
        assert!((s_error(&locals, &s_true, 30) - want).abs() < 1e-12);
    }

    #[test]
    fn bounded_by_two() {
        // worst case: all mass moved, |s̃-s|_1 <= 2M per worker
        let m = 100usize;
        let s_true = vec![m as f32, 0.0];
        let locals = vec![vec![0.0, m as f32]];
        let d = s_error(&locals, &s_true, m);
        assert!((0.0..=2.0).contains(&d));
        assert!((d - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(s_error(&[], &[1.0], 10), 0.0);
        assert_eq!(s_error(&[vec![1.0]], &[1.0], 0), 0.0);
    }
}
