//! Convergence metrics, the paper's s-error (eq. 1), SSP staleness
//! accounting, and run recorders.

pub mod recorder;
pub mod serror;
pub mod ssp;

pub use recorder::{Recorder, TrajectoryPoint};
pub use serror::s_error;
pub use ssp::SspStats;
