//! Convergence metrics, the paper's s-error (eq. 1), and run recorders.

pub mod recorder;
pub mod serror;

pub use recorder::{Recorder, TrajectoryPoint};
pub use serror::s_error;
