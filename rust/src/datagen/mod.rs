//! Synthetic workload generators matching the paper's §4.1 datasets.
//!
//! * [`lasso_synth`] — the **exact** recipe from the paper: 25 non-zero
//!   samples per feature, with adjacent-feature correlation injected via a
//!   0.9-probability noise carryover chain.
//! * [`mf_ratings`] — Netflix-like low-rank + noise rating matrices at the
//!   paper's density (~1.2%).
//! * [`lda_corpus`] — Zipf-distributed synthetic corpus standing in for the
//!   3.9M-abstract Wikipedia dump (see DESIGN.md §4 substitutions).

pub mod lasso_synth;
pub mod lda_corpus;
pub mod mf_ratings;

pub use lasso_synth::LassoProblem;
pub use lda_corpus::Corpus;
pub use mf_ratings::RatingMatrix;
