//! Synthetic Zipf corpus generator standing in for the paper's 3.9M
//! Wikipedia abstracts (DESIGN.md §4 substitutions).
//!
//! Documents are drawn from a ground-truth LDA model: each document gets a
//! Dirichlet-ish topic mixture (sampled by normalized Gammas approximated
//! with powered uniforms for speed), each topic is a Zipf-tilted
//! distribution over a topic-specific vocabulary band.  This reproduces the
//! skewed word frequencies and topic-concentrated co-occurrence that drive
//! collapsed-Gibbs behaviour on real corpora.

use crate::util::Rng;

/// Token list per document, words in [0, vocab).
pub struct Corpus {
    pub docs: Vec<Vec<u32>>,
    pub vocab: usize,
    pub n_topics_true: usize,
}

impl Corpus {
    pub fn n_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_docs: usize,
    pub vocab: usize,
    /// Mean tokens per document (Wikipedia abstracts average ≈ 45).
    pub doc_len_mean: usize,
    /// Ground-truth number of topics.
    pub n_topics: usize,
    /// Zipf exponent for within-topic word frequencies.
    pub zipf_alpha: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_docs: 2000,
            vocab: 10_000,
            doc_len_mean: 45,
            n_topics: 20,
            zipf_alpha: 1.1,
            seed: 3,
        }
    }
}

/// Generate a corpus from the ground-truth model.
pub fn generate(cfg: &CorpusConfig) -> Corpus {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.n_topics.max(1);
    let band = cfg.vocab / k;

    let mut docs = Vec::with_capacity(cfg.n_docs);
    for _ in 0..cfg.n_docs {
        // sparse topic mixture: 1-3 dominant topics per doc
        let n_active = 1 + rng.below(3);
        let active: Vec<usize> = rng.sample_indices(k, n_active);
        let mut weights = vec![0.0f64; n_active];
        for w in weights.iter_mut() {
            *w = rng.next_f64() + 0.1;
        }

        // Poisson-ish doc length via geometric sum around the mean
        let len = 1 + rng.below(cfg.doc_len_mean * 2);
        let mut doc = Vec::with_capacity(len);
        for _ in 0..len {
            let t = active[rng.weighted(&weights)];
            // word from the topic's vocabulary band, Zipf-tilted, with 10%
            // leakage to the global vocabulary (stop-word-like noise)
            let w = if rng.next_f64() < 0.9 && band > 0 {
                (t * band + rng.zipf(band, cfg.zipf_alpha)) as u32
            } else {
                rng.zipf(cfg.vocab, cfg.zipf_alpha) as u32
            };
            doc.push(w.min(cfg.vocab as u32 - 1));
        }
        docs.push(doc);
    }
    Corpus { docs, vocab: cfg.vocab, n_topics_true: k }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CorpusConfig {
        CorpusConfig {
            n_docs: 200,
            vocab: 1000,
            n_topics: 5,
            ..Default::default()
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = generate(&small());
        assert_eq!(c.docs.len(), 200);
        for doc in &c.docs {
            assert!(!doc.is_empty());
            assert!(doc.iter().all(|&w| (w as usize) < c.vocab));
        }
    }

    #[test]
    fn word_frequencies_are_skewed() {
        let c = generate(&small());
        let mut counts = vec![0usize; c.vocab];
        for doc in &c.docs {
            for &w in doc {
                counts[w as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        let top10: usize = counts.iter().take(c.vocab / 10).sum();
        // Zipf: top 10% of types cover well over half the tokens
        assert!(
            top10 as f64 / total as f64 > 0.5,
            "top10 share = {}",
            top10 as f64 / total as f64
        );
    }

    #[test]
    fn documents_are_topic_concentrated() {
        // tokens of a doc should cluster in few vocabulary bands
        let c = generate(&small());
        let band = c.vocab / c.n_topics_true;
        let mut avg_bands = 0.0;
        for doc in &c.docs {
            let mut bands: Vec<usize> =
                doc.iter().map(|&w| w as usize / band).collect();
            bands.sort_unstable();
            bands.dedup();
            avg_bands += bands.len() as f64;
        }
        avg_bands /= c.docs.len() as f64;
        assert!(avg_bands < 4.5, "avg bands per doc = {avg_bands}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.docs, b.docs);
    }
}
