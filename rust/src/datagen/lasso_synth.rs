//! Paper §4.1 Lasso generator.
//!
//! "We used synthetic data with 50K samples and J=10M to 100M features,
//! where every feature x_j has only 25 non-zero samples.  To simulate
//! correlations between adjacent features (which exist in real-world data),
//! we first added Unif(0,1) noise to x_1.  Then, for j = 2..J, with 0.9
//! probability we add eps_j = Unif(0,1) noise to x_j, otherwise we add
//! 0.9 eps_{j-1} + 0.1 Unif(0,1) to x_j."
//!
//! We reproduce that construction (scaled J), standardize columns (the
//! paper's CD update assumes unit-norm columns), and synthesize y from a
//! sparse ground-truth beta so convergence behaviour is meaningful.

use crate::sparse::{ops, CscBuilder, CscMatrix};
use crate::util::Rng;

/// A generated Lasso problem.
pub struct LassoProblem {
    /// Standardized design matrix (n × j), 25 nnz per column.
    pub x: CscMatrix,
    /// Response vector (n).
    pub y: Vec<f32>,
    /// Ground-truth coefficients used to synthesize y.
    pub beta_true: Vec<f32>,
    /// Index pairs (j-1, j) that were built as correlated neighbours.
    pub correlated_pairs: Vec<(usize, usize)>,
}

/// Generator parameters (paper values as defaults, J scaled by caller).
#[derive(Debug, Clone)]
pub struct LassoGenConfig {
    pub n_samples: usize,
    pub n_features: usize,
    /// Non-zeros per feature column (paper: 25).
    pub nnz_per_feature: usize,
    /// Probability of *independent* noise (paper: 0.9); with 1-p the
    /// column reuses its left neighbour's noise (correlation injection).
    pub independent_prob: f64,
    /// Fraction of features with non-zero ground-truth coefficient.
    pub signal_density: f64,
    /// Observation noise stddev on y.
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Default for LassoGenConfig {
    fn default() -> Self {
        LassoGenConfig {
            n_samples: 2048,
            n_features: 16384,
            nnz_per_feature: 25,
            independent_prob: 0.9,
            signal_density: 0.005,
            noise_sigma: 0.1,
            seed: 1,
        }
    }
}

/// Generate a Lasso problem per the paper's recipe.
pub fn generate(cfg: &LassoGenConfig) -> LassoProblem {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.n_samples;
    let j = cfg.n_features;
    let nnz = cfg.nnz_per_feature.min(n);

    let mut builder = CscBuilder::new(n);
    let mut correlated_pairs = Vec::new();

    // Previous column's (rows, noise values): the "eps_{j-1}" carryover.
    let mut prev_rows: Vec<usize> = Vec::new();
    let mut prev_eps: Vec<f32> = Vec::new();
    let mut col_buf: Vec<(u32, f32)> = Vec::with_capacity(nnz);

    for col in 0..j {
        let independent = col == 0 || rng.next_f64() < cfg.independent_prob;
        let rows;
        let eps: Vec<f32>;
        if independent {
            let mut r = rng.sample_indices(n, nnz);
            r.sort_unstable();
            eps = (0..r.len()).map(|_| rng.next_f32()).collect();
            rows = r;
        } else {
            // correlated with the left neighbour: same support, blended noise
            rows = prev_rows.clone();
            eps = prev_eps
                .iter()
                .map(|&e| 0.9 * e + 0.1 * rng.next_f32())
                .collect();
            correlated_pairs.push((col - 1, col));
        }
        col_buf.clear();
        for (&r, &e) in rows.iter().zip(eps.iter()) {
            col_buf.push((r as u32, e));
        }
        builder.push_col(&col_buf);
        prev_rows = rows;
        prev_eps = eps;
    }

    let raw = builder.finish();
    let (x, _) = ops::standardize_columns(&raw);

    // sparse ground truth + response
    let mut beta_true = vec![0.0f32; j];
    let n_signal = ((j as f64) * cfg.signal_density).ceil() as usize;
    for idx in rng.sample_indices(j, n_signal.max(1)) {
        beta_true[idx] = (rng.normal() * 2.0) as f32;
    }
    let mut y = x.matvec(&beta_true);
    for yi in y.iter_mut() {
        *yi += (rng.normal() * cfg.noise_sigma) as f32;
    }

    LassoProblem { x, y, beta_true, correlated_pairs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LassoGenConfig {
        LassoGenConfig {
            n_samples: 200,
            n_features: 500,
            ..Default::default()
        }
    }

    #[test]
    fn shape_and_sparsity_match_recipe() {
        let p = generate(&small());
        assert_eq!(p.x.rows(), 200);
        assert_eq!(p.x.cols(), 500);
        for j in 0..p.x.cols() {
            assert_eq!(p.x.col_nnz(j), 25, "column {j}");
        }
    }

    #[test]
    fn columns_are_standardized() {
        let p = generate(&small());
        for j in 0..p.x.cols() {
            assert!((p.x.col_norm_sq(j) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn correlated_pairs_have_high_correlation() {
        let p = generate(&small());
        assert!(
            !p.correlated_pairs.is_empty(),
            "expected ~10% correlated columns"
        );
        let mut avg = 0.0;
        for &(a, b) in &p.correlated_pairs {
            avg += p.x.col_dot_col(a, b) as f64;
        }
        avg /= p.correlated_pairs.len() as f64;
        // blended noise on identical support => correlation near 1
        assert!(avg > 0.8, "avg correlated-pair dot = {avg}");
    }

    #[test]
    fn independent_pairs_have_low_correlation() {
        let p = generate(&small());
        let corr: std::collections::HashSet<usize> =
            p.correlated_pairs.iter().map(|&(_, b)| b).collect();
        let mut avg = 0.0;
        let mut cnt = 0;
        for jx in 1..p.x.cols() {
            if !corr.contains(&jx) {
                avg += p.x.col_dot_col(jx - 1, jx).abs() as f64;
                cnt += 1;
            }
        }
        avg /= cnt as f64;
        // disjoint-ish random supports of 25/200 rows overlap rarely
        assert!(avg < 0.3, "avg independent-pair |dot| = {avg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn fraction_of_correlated_columns_near_one_minus_p() {
        let mut cfg = small();
        cfg.n_features = 2000;
        let p = generate(&cfg);
        let frac = p.correlated_pairs.len() as f64 / 2000.0;
        assert!((frac - 0.1).abs() < 0.03, "frac={frac}");
    }
}
