//! Netflix-like rating matrix generator (paper §4.1, MF experiments).
//!
//! The Netflix data (480,189 users × 17,770 movies, 100M ratings ≈ 1.2%
//! density) is proprietary; we synthesize a low-rank-plus-noise matrix with
//! matched density and scaled dimensions — CCD/ALS cost and convergence are
//! governed by rank, density and conditioning, which this preserves
//! (DESIGN.md §4).

use crate::sparse::CsrMatrix;
use crate::util::Rng;

/// A generated rating problem.
pub struct RatingMatrix {
    /// Observed ratings, CSR (users × items).
    pub a: CsrMatrix,
    /// Ground-truth rank used for synthesis.
    pub true_rank: usize,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct MfGenConfig {
    pub n_users: usize,
    pub n_items: usize,
    /// Observation density (paper's Netflix: ~0.012).
    pub density: f64,
    /// Ground-truth rank of the synthesized preference structure.
    pub true_rank: usize,
    /// Observation noise stddev.
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Default for MfGenConfig {
    fn default() -> Self {
        MfGenConfig {
            n_users: 2000,
            n_items: 1500,
            density: 0.012,
            true_rank: 8,
            noise_sigma: 0.1,
            seed: 2,
        }
    }
}

/// Generate ratings A ≈ U V^T + noise at the requested density.
pub fn generate(cfg: &MfGenConfig) -> RatingMatrix {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.true_rank;
    let scale = 1.0 / (k as f64).sqrt();
    let u: Vec<f32> = (0..cfg.n_users * k)
        .map(|_| (rng.normal() * scale) as f32)
        .collect();
    let v: Vec<f32> = (0..cfg.n_items * k)
        .map(|_| (rng.normal() * scale) as f32)
        .collect();

    let mut trips = Vec::new();
    for i in 0..cfg.n_users {
        for j in 0..cfg.n_items {
            if rng.next_f64() < cfg.density {
                let mut val = 0.0f32;
                for p in 0..k {
                    val += u[i * k + p] * v[j * k + p];
                }
                val += (rng.normal() * cfg.noise_sigma) as f32;
                trips.push((i as u32, j as u32, val));
            }
        }
    }
    // guarantee every user/item has at least one rating (avoids dead rows)
    for i in 0..cfg.n_users {
        let j = rng.below(cfg.n_items);
        trips.push((i as u32, j as u32, 0.1));
    }
    for j in 0..cfg.n_items {
        let i = rng.below(cfg.n_users);
        trips.push((i as u32, j as u32, 0.1));
    }
    // dedupe (keep first) — from_triplets would sum duplicates otherwise
    trips.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
    trips.dedup_by_key(|&mut (r, c, _)| ((r as u64) << 32) | c as u64);

    RatingMatrix {
        a: CsrMatrix::from_triplets(cfg.n_users, cfg.n_items, &trips),
        true_rank: k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> MfGenConfig {
        MfGenConfig {
            n_users: 300,
            n_items: 200,
            density: 0.05,
            ..Default::default()
        }
    }

    #[test]
    fn density_is_close_to_requested() {
        let r = generate(&small());
        let density =
            r.a.nnz() as f64 / (r.a.rows() as f64 * r.a.cols() as f64);
        assert!((density - 0.05).abs() < 0.02, "density={density}");
    }

    #[test]
    fn no_empty_rows_or_columns() {
        let r = generate(&small());
        for i in 0..r.a.rows() {
            assert!(r.a.row_nnz(i) > 0, "empty user row {i}");
        }
        let t = r.a.transpose();
        for j in 0..t.rows() {
            assert!(t.row_nnz(j) > 0, "empty item column {j}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(generate(&small()).a, generate(&small()).a);
    }

    #[test]
    fn low_rank_structure_is_recoverable() {
        // The best rank-k approximation of the generated data must explain
        // much more variance than noise would: check via the generator's own
        // factors implicitly — ratings should have nontrivial magnitude.
        let r = generate(&small());
        let mut sumsq = 0.0f64;
        for i in 0..r.a.rows() {
            for (_, v) in r.a.row_iter(i) {
                sumsq += (v as f64) * (v as f64);
            }
        }
        assert!(sumsq / r.a.nnz() as f64 > 0.01);
    }
}
