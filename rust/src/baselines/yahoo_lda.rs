//! Data-parallel LDA baseline (YahooLDA-style).
//!
//! Every worker replicates the full V×K word-topic table **B** and the
//! topic sums s.  A sweep: each worker Gibbs-samples *all* of its tokens
//! against its (increasingly stale) replica; afterwards the coordinator
//! merges the per-worker count deltas and redistributes the table.  This is
//! the architecture of Ahmed et al. [1] compressed to one merge per sweep —
//! its defining properties are (a) per-machine memory ∝ full model size
//! regardless of cluster size (paper Fig 3) and (b) within-sweep staleness
//! that grows with the model and worker count (the convergence drag in
//! Figs 8/9).

use crate::cluster::{MemoryTracker, NetworkModel, VirtualClock, WorkerPool};
use crate::datagen::Corpus;
use crate::metrics::Recorder;
use crate::util::stats::Stopwatch;
use crate::util::Rng;

/// Baseline configuration.
#[derive(Debug, Clone)]
pub struct YahooLdaConfig {
    pub n_topics: usize,
    pub alpha: f32,
    pub gamma: f32,
    pub n_workers: usize,
    pub seed: u64,
}

struct Replica {
    /// Full word-topic replica (V × K).
    b: Vec<f32>,
    s: Vec<f32>,
    /// This worker's tokens: (local_doc, word, z).
    tokens: Vec<(u32, u32, u32)>,
    d_tab: Vec<f32>,
    doc_totals: Vec<f32>,
    k: usize,
    alpha: f32,
    gamma: f32,
    v: usize,
    rng: Rng,
    prob: Vec<f32>,
}

impl Replica {
    fn sweep(&mut self) -> (Vec<f32>, Vec<f32>) {
        // returns (delta_b, delta_s) relative to the sweep-start replica
        let b0 = self.b.clone();
        let s0 = self.s.clone();
        let k = self.k;
        let vgamma = self.v as f32 * self.gamma;
        for idx in 0..self.tokens.len() {
            let (d, w, zi) = self.tokens[idx];
            let (drow, brow) = (d as usize * k, w as usize * k);
            let zi = zi as usize;
            self.d_tab[drow + zi] -= 1.0;
            self.b[brow + zi] -= 1.0;
            self.s[zi] -= 1.0;
            let mut total = 0.0f32;
            for kk in 0..k {
                let p = (self.gamma + self.b[brow + kk])
                    / (vgamma + self.s[kk])
                    * (self.alpha + self.d_tab[drow + kk]);
                total += p;
                self.prob[kk] = total;
            }
            let u = self.rng.next_f32() * total;
            let mut z_new = k - 1;
            for (kk, &c) in self.prob.iter().enumerate() {
                if u < c {
                    z_new = kk;
                    break;
                }
            }
            self.d_tab[drow + z_new] += 1.0;
            self.b[brow + z_new] += 1.0;
            self.s[z_new] += 1.0;
            self.tokens[idx].2 = z_new as u32;
        }
        let delta_b: Vec<f32> =
            self.b.iter().zip(b0.iter()).map(|(a, b)| a - b).collect();
        let delta_s: Vec<f32> =
            self.s.iter().zip(s0.iter()).map(|(a, b)| a - b).collect();
        (delta_b, delta_s)
    }

    fn doc_loglik(&self) -> f64 {
        let k = self.k;
        let mut ll = 0.0f64;
        for d in 0..self.doc_totals.len() {
            let denom = self.doc_totals[d] + k as f32 * self.alpha;
            if denom <= 0.0 {
                continue;
            }
            for kk in 0..k {
                let c = self.d_tab[d * k + kk];
                if c > 0.0 {
                    ll += c as f64 * (((c + self.alpha) / denom) as f64).ln();
                }
            }
        }
        ll
    }

    fn model_bytes(&self) -> u64 {
        // the full replica is the point of this baseline
        ((self.b.len() + self.s.len() + self.d_tab.len()) * 4) as u64
    }
}

/// The baseline runner (same instrumentation as the STRADS engine).
pub struct YahooLda {
    pool: WorkerPool<Replica>,
    /// Coordinator's master copy of B and s.
    b: Vec<f32>,
    s: Vec<f32>,
    cfg: YahooLdaConfig,
    vocab: usize,
    n_tokens: usize,
    pub clock: VirtualClock,
    pub network: NetworkModel,
    pub memory: MemoryTracker,
}

impl YahooLda {
    pub fn new(
        corpus: &Corpus,
        cfg: YahooLdaConfig,
        network: crate::cluster::NetworkConfig,
        mem_capacity: Option<u64>,
    ) -> Self {
        let k = cfg.n_topics;
        let v = corpus.vocab;
        let mut rng = Rng::new(cfg.seed);
        let mut b = vec![0.0f32; v * k];
        let mut s = vec![0.0f32; k];

        let mut per_worker: Vec<Vec<(u32, u32, u32)>> =
            (0..cfg.n_workers).map(|_| Vec::new()).collect();
        let mut per_worker_docs = vec![0u32; cfg.n_workers];
        for (d, doc) in corpus.docs.iter().enumerate() {
            let p = d % cfg.n_workers;
            let local = per_worker_docs[p];
            per_worker_docs[p] += 1;
            for &w in doc {
                let z = rng.below(k) as u32;
                b[w as usize * k + z as usize] += 1.0;
                s[z as usize] += 1.0;
                per_worker[p].push((local, w, z));
            }
        }

        let replicas: Vec<Replica> = per_worker
            .into_iter()
            .enumerate()
            .map(|(p, tokens)| {
                let n_docs = per_worker_docs[p].max(1) as usize;
                let mut d_tab = vec![0.0f32; n_docs * k];
                let mut doc_totals = vec![0.0f32; n_docs];
                for &(d, _, z) in &tokens {
                    d_tab[d as usize * k + z as usize] += 1.0;
                    doc_totals[d as usize] += 1.0;
                }
                Replica {
                    b: b.clone(),
                    s: s.clone(),
                    tokens,
                    d_tab,
                    doc_totals,
                    k,
                    alpha: cfg.alpha,
                    gamma: cfg.gamma,
                    v,
                    rng: Rng::new(cfg.seed ^ (p as u64 + 1) * 0x9E37),
                    prob: vec![0.0f32; k],
                }
            })
            .collect();

        let n_workers = cfg.n_workers;
        YahooLda {
            pool: WorkerPool::new(replicas),
            b,
            s,
            cfg,
            vocab: v,
            n_tokens: corpus.n_tokens(),
            clock: VirtualClock::new(),
            network: NetworkModel::new(network, n_workers),
            memory: MemoryTracker::new(n_workers, mem_capacity),
        }
    }

    /// One data-parallel sweep: all workers sample everything, then merge.
    pub fn sweep(&mut self) {
        let results = self.pool.run(|_| {
            move |rep: &mut Replica| rep.sweep()
        });
        let mut compute = Vec::with_capacity(results.len());
        // merge deltas into the master copy
        for (p, ((db, ds), secs)) in results.into_iter().enumerate() {
            self.network.send_up(p, (db.len() + ds.len()) * 4);
            for (bi, d) in self.b.iter_mut().zip(db.iter()) {
                *bi += d;
            }
            for (si, d) in self.s.iter_mut().zip(ds.iter()) {
                *si += d;
            }
            compute.push(secs);
        }
        // redistribute the merged table (full replica per worker)
        let (b, s) = (self.b.clone(), self.s.clone());
        for p in 0..self.pool.n_workers() {
            self.network.send_down(p, (b.len() + s.len()) * 4);
        }
        self.pool.broadcast(move |_| {
            let (b, s) = (b.clone(), s.clone());
            move |rep: &mut Replica| {
                rep.b = b;
                rep.s = s;
            }
        });
        let comm = self.network.round_time_and_reset();
        self.clock.advance_round(&compute, comm, 0.0);
    }

    /// Full log-likelihood (doc part from workers + word part from master).
    pub fn loglik(&mut self) -> f64 {
        let doc: f64 = self
            .pool
            .run(|_| |rep: &mut Replica| rep.doc_loglik())
            .into_iter()
            .map(|(v, _)| v)
            .sum();
        let k = self.cfg.n_topics;
        let vg = self.vocab as f64 * self.cfg.gamma as f64;
        let mut word = 0.0f64;
        for w in 0..self.vocab {
            for kk in 0..k {
                let c = self.b[w * k + kk] as f64;
                if c > 0.0 {
                    word += c
                        * ((c + self.cfg.gamma as f64)
                            / (self.s[kk] as f64 + vg))
                            .ln();
                }
            }
        }
        doc + word
    }

    /// Memory census; Err when a replica exceeds machine capacity (the
    /// paper's YahooLDA DNF mechanism).
    pub fn memory_census(&mut self) -> Result<u64, String> {
        let sizes = self.pool.run(|_| |rep: &mut Replica| rep.model_bytes());
        let mut err = None;
        for (p, (bytes, _)) in sizes.into_iter().enumerate() {
            if let Err(e) = self.memory.set(p, bytes) {
                err = Some(e.to_string());
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(self.memory.max_per_machine()),
        }
    }

    /// Instrumented run loop (mirrors `StradsEngine::run`).
    pub fn run(&mut self, sweeps: u64, label: &str) -> (Recorder, Option<String>) {
        let wall = Stopwatch::start();
        let mut rec = Recorder::new(label);
        rec.record(0, self.clock.seconds(), wall.secs(), self.loglik());
        let mut oom = None;
        for t in 0..sweeps {
            self.sweep();
            rec.record(t + 1, self.clock.seconds(), wall.secs(), self.loglik());
            if let Err(e) = self.memory_census() {
                oom = Some(e);
                break;
            }
        }
        (rec, oom)
    }

    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NetworkConfig;
    use crate::datagen::lda_corpus::{self, CorpusConfig};

    fn corpus() -> Corpus {
        lda_corpus::generate(&CorpusConfig {
            n_docs: 100,
            vocab: 300,
            doc_len_mean: 25,
            n_topics: 4,
            seed: 5,
            ..Default::default()
        })
    }

    fn cfg(workers: usize) -> YahooLdaConfig {
        YahooLdaConfig {
            n_topics: 8,
            alpha: 0.1,
            gamma: 0.01,
            n_workers: workers,
            seed: 6,
        }
    }

    #[test]
    fn sweeps_improve_loglik() {
        let mut y = YahooLda::new(&corpus(), cfg(3), NetworkConfig::ideal(), None);
        let l0 = y.loglik();
        for _ in 0..5 {
            y.sweep();
        }
        assert!(y.loglik() > l0);
    }

    #[test]
    fn token_count_conserved_across_merge() {
        let mut y = YahooLda::new(&corpus(), cfg(4), NetworkConfig::ideal(), None);
        let t0: f32 = y.s.iter().sum();
        for _ in 0..3 {
            y.sweep();
        }
        let t1: f32 = y.s.iter().sum();
        assert!((t0 - t1).abs() < 1e-2, "{t0} vs {t1}");
    }

    #[test]
    fn replica_memory_does_not_shrink_with_workers() {
        let mut y2 = YahooLda::new(&corpus(), cfg(2), NetworkConfig::ideal(), None);
        let mut y8 = YahooLda::new(&corpus(), cfg(8), NetworkConfig::ideal(), None);
        let m2 = y2.memory_census().unwrap();
        let m8 = y8.memory_census().unwrap();
        // full replication: per-machine usage roughly constant (doc tables
        // shrink slightly); definitely not ~4x smaller
        assert!(m8 as f64 > 0.7 * m2 as f64, "m2={m2} m8={m8}");
    }

    #[test]
    fn capacity_violation_reported() {
        let mut y = YahooLda::new(&corpus(), cfg(2), NetworkConfig::ideal(), Some(1024));
        assert!(y.memory_census().is_err());
    }
}
