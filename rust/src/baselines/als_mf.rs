//! GraphLab-style Alternating Least Squares baseline for MF.
//!
//! ALS alternates closed-form solves: fixing H, each user row w_i solves a
//! K×K ridge system built from the H rows of its rated items — and
//! symmetrically for H.  Both factor matrices are **fully replicated** on
//! every worker (GraphLab's vertex-replication behaviour at high-degree
//! nodes approximates this), so per-machine memory and per-update cost grow
//! as O((N+M)K) and O(K³) — the reason the paper's Fig 8 (center) shows
//! GraphLab failing beyond rank ≈ 80 while STRADS CCD keeps scaling.

use crate::cluster::{MemoryTracker, NetworkConfig, NetworkModel, VirtualClock, WorkerPool};
use crate::metrics::Recorder;
use crate::sparse::CsrMatrix;
use crate::util::linalg::{cholesky_solve, syr};
use crate::util::stats::Stopwatch;
use crate::util::Rng;

/// Baseline configuration.
#[derive(Debug, Clone)]
pub struct AlsConfig {
    pub rank: usize,
    pub lambda: f32,
    pub n_workers: usize,
    pub seed: u64,
}

struct AlsWorker {
    /// User-row shard of ratings.
    a: CsrMatrix,
    /// Item-column shard (transpose rows) for the H solves.
    a_t: CsrMatrix,
    /// Item range [item_lo, item_hi) owned for H solves.
    item_lo: usize,
    item_hi: usize,
    /// Full replicas of both factors (the baseline's memory signature).
    w: Vec<f32>,
    h: Vec<f32>,
    /// This worker's user range in the global W.
    user_lo: usize,
    user_hi: usize,
    rank: usize,
    lambda: f32,
}

impl AlsWorker {
    /// Solve all owned user rows against the (replicated) H.
    fn solve_w(&mut self) -> Vec<f32> {
        let k = self.rank;
        let mut out = vec![0.0f32; (self.user_hi - self.user_lo) * k];
        let mut gram = vec![0.0f64; k * k];
        let mut rhs = vec![0.0f64; k];
        for (local, i) in (self.user_lo..self.user_hi).enumerate() {
            gram.iter_mut().for_each(|x| *x = 0.0);
            rhs.iter_mut().for_each(|x| *x = 0.0);
            let mut hj = vec![0.0f64; k];
            for (j, v) in self.a.row_iter(local) {
                for p in 0..k {
                    hj[p] = self.h[p * self.a.cols() + j as usize] as f64;
                }
                syr(&mut gram, &hj);
                for p in 0..k {
                    rhs[p] += v as f64 * hj[p];
                }
            }
            if let Some(x) = cholesky_solve(&gram, self.lambda as f64, &rhs) {
                for p in 0..k {
                    out[local * k + p] = x[p] as f32;
                }
            }
            let _ = i;
        }
        out
    }

    /// Solve all owned item columns against the (replicated) W.
    fn solve_h(&mut self) -> Vec<f32> {
        let k = self.rank;
        let n_users = self.a_t.cols();
        let mut out = vec![0.0f32; (self.item_hi - self.item_lo) * k];
        let mut gram = vec![0.0f64; k * k];
        let mut rhs = vec![0.0f64; k];
        for (local, _j) in (self.item_lo..self.item_hi).enumerate() {
            gram.iter_mut().for_each(|x| *x = 0.0);
            rhs.iter_mut().for_each(|x| *x = 0.0);
            let mut wi = vec![0.0f64; k];
            for (i, v) in self.a_t.row_iter(local) {
                for p in 0..k {
                    wi[p] = self.w[i as usize * k + p] as f64;
                }
                syr(&mut gram, &wi);
                for p in 0..k {
                    rhs[p] += v as f64 * wi[p];
                }
            }
            let _ = n_users;
            if let Some(x) = cholesky_solve(&gram, self.lambda as f64, &rhs) {
                for p in 0..k {
                    out[local * k + p] = x[p] as f32;
                }
            }
        }
        out
    }

    fn loss(&self) -> f64 {
        let k = self.rank;
        let m = self.a.cols();
        let mut sq = 0.0f64;
        for (local, i) in (self.user_lo..self.user_hi).enumerate() {
            let _ = i;
            let w_row = &self.w[(self.user_lo + local) * k..(self.user_lo + local + 1) * k];
            for (j, v) in self.a.row_iter(local) {
                let mut pred = 0.0f32;
                for p in 0..k {
                    pred += w_row[p] * self.h[p * m + j as usize];
                }
                sq += ((v - pred) as f64).powi(2);
            }
        }
        sq
    }

    fn model_bytes(&self) -> u64 {
        // both factors fully replicated
        ((self.w.len() + self.h.len()) * 4) as u64
    }
}

/// The instrumented ALS baseline runner.
pub struct AlsMf {
    pool: WorkerPool<AlsWorker>,
    w: Vec<f32>,
    h: Vec<f32>,
    n_users: usize,
    n_items: usize,
    cfg: AlsConfig,
    user_ranges: Vec<(usize, usize)>,
    item_ranges: Vec<(usize, usize)>,
    pub clock: VirtualClock,
    pub network: NetworkModel,
    pub memory: MemoryTracker,
}

impl AlsMf {
    pub fn new(
        a: &CsrMatrix,
        cfg: AlsConfig,
        network: NetworkConfig,
        mem_capacity: Option<u64>,
    ) -> Self {
        let (n, m, k) = (a.rows(), a.cols(), cfg.rank);
        let mut rng = Rng::new(cfg.seed);
        let scale = 1.0 / (k as f32).sqrt();
        let w: Vec<f32> = (0..n * k).map(|_| rng.normal_f32() * scale).collect();
        let h: Vec<f32> = (0..k * m).map(|_| rng.normal_f32() * scale).collect();
        let a_t = a.transpose();

        let p = cfg.n_workers;
        let ur: Vec<(usize, usize)> = (0..p)
            .map(|q| (q * n / p, if q == p - 1 { n } else { (q + 1) * n / p }))
            .collect();
        let ir: Vec<(usize, usize)> = (0..p)
            .map(|q| (q * m / p, if q == p - 1 { m } else { (q + 1) * m / p }))
            .collect();

        let workers: Vec<AlsWorker> = (0..p)
            .map(|q| AlsWorker {
                a: a.row_slice(ur[q].0, ur[q].1),
                a_t: a_t.row_slice(ir[q].0, ir[q].1),
                item_lo: ir[q].0,
                item_hi: ir[q].1,
                w: w.clone(),
                h: h.clone(),
                user_lo: ur[q].0,
                user_hi: ur[q].1,
                rank: k,
                lambda: cfg.lambda,
            })
            .collect();

        let n_workers = cfg.n_workers;
        AlsMf {
            pool: WorkerPool::new(workers),
            w,
            h,
            n_users: n,
            n_items: m,
            cfg,
            user_ranges: ur,
            item_ranges: ir,
            clock: VirtualClock::new(),
            network: NetworkModel::new(network, n_workers),
            memory: MemoryTracker::new(n_workers, mem_capacity),
        }
    }

    /// One ALS iteration: solve W (all workers), broadcast; solve H,
    /// broadcast.
    pub fn iterate(&mut self) {
        let k = self.cfg.rank;
        // --- W phase
        let results = self.pool.run(|_| move |ws: &mut AlsWorker| ws.solve_w());
        let mut compute = Vec::new();
        for (p, (block, secs)) in results.into_iter().enumerate() {
            self.network.send_up(p, block.len() * 4);
            let (lo, _) = self.user_ranges[p];
            self.w[lo * k..lo * k + block.len()].copy_from_slice(&block);
            compute.push(secs);
        }
        let w = self.w.clone();
        for p in 0..self.pool.n_workers() {
            self.network.send_down(p, w.len() * 4);
        }
        self.pool.broadcast(move |_| {
            let w = w.clone();
            move |ws: &mut AlsWorker| ws.w = w
        });
        let comm_w = self.network.round_time_and_reset();
        self.clock.advance_round(&compute, comm_w, 0.0);

        // --- H phase
        let results = self.pool.run(|_| move |ws: &mut AlsWorker| ws.solve_h());
        let mut compute = Vec::new();
        let m = self.n_items;
        for (p, (block, secs)) in results.into_iter().enumerate() {
            self.network.send_up(p, block.len() * 4);
            let (lo, hi) = self.item_ranges[p];
            for (local, j) in (lo..hi).enumerate() {
                for q in 0..k {
                    self.h[q * m + j] = block[local * k + q];
                }
            }
            compute.push(secs);
        }
        let h = self.h.clone();
        for p in 0..self.pool.n_workers() {
            self.network.send_down(p, h.len() * 4);
        }
        self.pool.broadcast(move |_| {
            let h = h.clone();
            move |ws: &mut AlsWorker| ws.h = h
        });
        let comm_h = self.network.round_time_and_reset();
        self.clock.advance_round(&compute, comm_h, 0.0);
    }

    /// Regularized objective (paper eq. 2).
    pub fn objective(&mut self) -> f64 {
        let sq: f64 = self
            .pool
            .run(|_| |ws: &mut AlsWorker| ws.loss())
            .into_iter()
            .map(|(v, _)| v)
            .sum();
        let wreg: f64 = self.w.iter().map(|&x| (x as f64).powi(2)).sum();
        let hreg: f64 = self.h.iter().map(|&x| (x as f64).powi(2)).sum();
        sq + self.cfg.lambda as f64 * (wreg + hreg)
    }

    pub fn memory_census(&mut self) -> Result<u64, String> {
        let sizes = self.pool.run(|_| |ws: &mut AlsWorker| ws.model_bytes());
        let mut err = None;
        for (p, (bytes, _)) in sizes.into_iter().enumerate() {
            if let Err(e) = self.memory.set(p, bytes) {
                err = Some(e.to_string());
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(self.memory.max_per_machine()),
        }
    }

    /// Instrumented run loop.
    pub fn run(&mut self, iters: u64, label: &str) -> (Recorder, Option<String>) {
        let wall = Stopwatch::start();
        let mut rec = Recorder::new(label);
        rec.record(0, self.clock.seconds(), wall.secs(), self.objective());
        let mut oom = None;
        for t in 0..iters {
            self.iterate();
            rec.record(t + 1, self.clock.seconds(), wall.secs(), self.objective());
            if let Err(e) = self.memory_census() {
                oom = Some(e);
                break;
            }
        }
        (rec, oom)
    }

    pub fn factors(&self) -> (&[f32], &[f32]) {
        (&self.w, &self.h)
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.n_users, self.n_items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::mf_ratings::{self, MfGenConfig};

    fn data() -> CsrMatrix {
        mf_ratings::generate(&MfGenConfig {
            n_users: 150,
            n_items: 100,
            density: 0.08,
            true_rank: 3,
            seed: 8,
            ..Default::default()
        })
        .a
    }

    fn cfg(rank: usize, workers: usize) -> AlsConfig {
        AlsConfig { rank, lambda: 0.1, n_workers: workers, seed: 9 }
    }

    #[test]
    fn als_iterations_reduce_objective() {
        let a = data();
        let mut als = AlsMf::new(&a, cfg(4, 3), NetworkConfig::ideal(), None);
        let o0 = als.objective();
        for _ in 0..5 {
            als.iterate();
        }
        let o1 = als.objective();
        assert!(o1 < 0.8 * o0, "objective {o0} -> {o1}");
    }

    #[test]
    fn replication_memory_grows_with_rank() {
        let a = data();
        let mut a8 = AlsMf::new(&a, cfg(8, 2), NetworkConfig::ideal(), None);
        let mut a32 = AlsMf::new(&a, cfg(32, 2), NetworkConfig::ideal(), None);
        let m8 = a8.memory_census().unwrap();
        let m32 = a32.memory_census().unwrap();
        assert!(
            (m32 as f64 / m8 as f64 - 4.0).abs() < 0.2,
            "m8={m8} m32={m32}"
        );
    }

    #[test]
    fn memory_capacity_fails_large_rank() {
        let a = data();
        let cap = {
            let mut probe = AlsMf::new(&a, cfg(8, 2), NetworkConfig::ideal(), None);
            probe.memory_census().unwrap() + 1024
        };
        let mut big = AlsMf::new(&a, cfg(64, 2), NetworkConfig::ideal(), Some(cap));
        assert!(big.memory_census().is_err());
    }

    #[test]
    fn run_records_trajectory() {
        let a = data();
        let mut als = AlsMf::new(&a, cfg(4, 2), NetworkConfig::gbps40(), None);
        let (rec, oom) = als.run(3, "als");
        assert_eq!(rec.points().len(), 4);
        assert!(oom.is_none());
        assert!(als.clock.seconds() > 0.0);
    }
}
