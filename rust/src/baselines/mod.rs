//! Baseline systems the paper compares against (§4):
//!
//! * [`yahoo_lda`] — data-parallel LDA in the style of YahooLDA/Ahmed et
//!   al.: every worker holds a **full replica** of the word-topic table,
//!   samples all its tokens each sweep, and merges deltas afterwards.
//!   Memory per machine does not shrink with more machines (Fig 3) and the
//!   replicas go stale within a sweep (convergence drag, Fig 8/9).
//! * [`als_mf`] — GraphLab-style Alternating Least Squares: each update
//!   solves a K×K normal-equations system per row/column with full-factor
//!   replication; the O(K²) memory and O(K³) solves are why it collapses
//!   at rank ≥ 80 in the paper's Fig 8 (center).
//! * Lasso-RR — random parallel CD (Shotgun imitation) is *not* a separate
//!   system: the paper runs it as a STRADS schedule, and so do we
//!   ([`crate::scheduler::RandomScheduler`] plugged into
//!   [`crate::apps::LassoApp`]).

pub mod als_mf;
pub mod yahoo_lda;

pub use als_mf::{AlsConfig, AlsMf};
pub use yahoo_lda::{YahooLda, YahooLdaConfig};
