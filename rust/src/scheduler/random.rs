//! Uniform-random parallel scheduling — the **Lasso-RR** baseline.
//!
//! "Lasso-RR imitates the random scheduling scheme proposed by [the]
//! Shotgun algorithm on STRADS" (paper §4): draw U coefficients uniformly
//! at random with no priorities and no dependency filtering.

use crate::util::Rng;

/// Stateless-per-round uniform scheduler.
pub struct RandomScheduler {
    n_features: usize,
    u: usize,
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(n_features: usize, u: usize, seed: u64) -> Self {
        assert!(u >= 1 && n_features >= 1);
        RandomScheduler { n_features, u: u.min(n_features), rng: Rng::new(seed) }
    }

    /// Next concurrent update set: U distinct uniform indices.
    pub fn next_set(&mut self) -> Vec<usize> {
        self.rng.sample_indices(self.n_features, self.u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check};

    #[test]
    fn draws_u_distinct() {
        let mut s = RandomScheduler::new(100, 10, 1);
        let set = s.next_set();
        assert_eq!(set.len(), 10);
        let mut d = set.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn u_clamped_to_n() {
        let mut s = RandomScheduler::new(3, 10, 1);
        assert_eq!(s.next_set().len(), 3);
    }

    #[test]
    fn covers_the_space_over_time() {
        let mut s = RandomScheduler::new(50, 5, 2);
        let mut seen = vec![false; 50];
        for _ in 0..200 {
            for j in s.next_set() {
                seen[j] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn prop_indices_in_range() {
        prop_check("random scheduler range", 100, |g| {
            let n = g.usize_in(1, 1000);
            let u = g.usize_in(1, 32);
            let mut s = RandomScheduler::new(n, u, g.seed());
            let set = s.next_set();
            ensure(set.iter().all(|&j| j < n), format!("{set:?} n={n}"))
        });
    }
}
