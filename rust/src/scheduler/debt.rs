//! Coverage-debt accounting for skip-capable rotation schedules.
//!
//! [`crate::scheduler::rotation::SkipPolicy::Defer`] lets the scheduler
//! *skip* granting a slice whose handoff is still in flight (the worker
//! sweeps the rest of its queue instead of stalling) and lease it in a
//! later round.  Skipping relaxes the rotation's U-round coverage
//! guarantee, so every skip must be accounted: the [`CoverageDebtLedger`]
//! tracks each slice's **coverage debt** — the number of rounds the slice
//! has been deferred — and refuses to defer past `debt_limit`.
//!
//! Debt semantics are a per-slice *deferral budget*, not a resettable
//! counter: `debt[a] = rounds elapsed − rounds granted` is monotone, so
//! after any `R` rounds slice `a` has been granted at least
//! `R − debt_limit` times.  A granted slice advances exactly one virtual
//! ring position, and any `U` consecutive positions cover every worker
//! residue, which yields the bounded horizon the skip mode is sold on:
//! **every worker holds every slice within `U + debt_limit` rounds** —
//! the property `tests/rotation_properties.rs` pins for the full mode
//! matrix.  (A resettable counter would only bound the horizon by
//! `U·(1+debt_limit)`: each of the U steps could be deferred afresh.)
//!
//! `debt_limit = 0` therefore refuses every deferral — `Defer { 0 }`
//! degrades to the plain availability-ordered rotation with no skips —
//! and a slice stalled past its budget is *force-granted*, never starved:
//! a scheduler that tries to defer anyway panics here with the slice,
//! round, and debt context.

use crate::trace::{Event, TraceBuffer};
use std::sync::Arc;

/// Per-slice coverage-debt ledger (see the module docs for the budget
/// semantics and the `U + debt_limit` coverage bound it buys).
#[derive(Debug, Clone)]
pub struct CoverageDebtLedger {
    /// Rounds each slice has been deferred so far (monotone).
    debt: Vec<u64>,
    debt_limit: u64,
    total_deferrals: u64,
    /// Trace sink for `DebtCharge` events (None = tracing off).
    trace: Option<Arc<TraceBuffer>>,
}

impl CoverageDebtLedger {
    pub fn new(n_slices: usize, debt_limit: u64) -> Self {
        CoverageDebtLedger {
            debt: vec![0; n_slices],
            debt_limit,
            total_deferrals: 0,
            trace: None,
        }
    }

    /// Attach (or detach) a trace sink: every subsequent
    /// [`CoverageDebtLedger::record_skip`] emits an [`Event::DebtCharge`]
    /// carrying the post-charge debt.
    pub fn install_trace(&mut self, sink: Option<Arc<TraceBuffer>>) {
        self.trace = sink;
    }

    pub fn n_slices(&self) -> usize {
        self.debt.len()
    }

    pub fn debt_limit(&self) -> u64 {
        self.debt_limit
    }

    /// Whether the slice still has deferral budget.  `debt_limit = 0`
    /// always answers no: the schedule degrades to its no-skip form.
    pub fn may_defer(&self, slice_id: usize) -> bool {
        self.debt[slice_id] < self.debt_limit
    }

    /// Record one deferred round for the slice.  Panics — with the slice,
    /// round, and debt context — when the budget is exhausted: a
    /// permanently-stalled slice must be force-granted (its taker then
    /// fails loudly through the router's bounded spin), never silently
    /// starved out of the rotation.
    pub fn record_skip(&mut self, slice_id: usize, round: u64) {
        assert!(
            self.may_defer(slice_id),
            "slice {slice_id} starved: deferring again at round {round} \
             would push its coverage debt past debt_limit {} (debt {}) — \
             the scheduler must force-grant an over-budget slice",
            self.debt_limit,
            self.debt[slice_id],
        );
        self.debt[slice_id] += 1;
        self.total_deferrals += 1;
        if let Some(sink) = &self.trace {
            sink.push(Event::DebtCharge {
                round,
                slice: slice_id,
                debt: self.debt[slice_id],
            });
        }
    }

    /// Record a grant.  Debt is a lifetime budget (module docs), so a
    /// grant spends nothing back — it only marks the slice as having
    /// moved this round.
    pub fn record_grant(&mut self, _slice_id: usize) {}

    /// Current coverage debt of one slice.
    pub fn debt(&self, slice_id: usize) -> u64 {
        self.debt[slice_id]
    }

    /// Worst coverage debt across slices.
    pub fn max_debt(&self) -> u64 {
        self.debt.iter().copied().max().unwrap_or(0)
    }

    /// Total deferrals recorded over the run.
    pub fn total_deferrals(&self) -> u64 {
        self.total_deferrals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_spent_per_slice_and_never_refunded() {
        let mut l = CoverageDebtLedger::new(2, 2);
        assert!(l.may_defer(0));
        l.record_skip(0, 0);
        l.record_grant(0); // grants do not refund the budget
        l.record_skip(0, 2);
        assert!(!l.may_defer(0), "budget of 2 exhausted");
        assert!(l.may_defer(1), "budgets are per slice");
        assert_eq!(l.debt(0), 2);
        assert_eq!(l.debt(1), 0);
        assert_eq!(l.max_debt(), 2);
        assert_eq!(l.total_deferrals(), 2);
    }

    #[test]
    fn zero_limit_never_defers() {
        let l = CoverageDebtLedger::new(3, 0);
        for a in 0..3 {
            assert!(!l.may_defer(a));
        }
    }

    #[test]
    #[should_panic(expected = "slice 1 starved")]
    fn over_budget_skip_panics_with_context() {
        let mut l = CoverageDebtLedger::new(2, 1);
        l.record_skip(1, 4);
        l.record_skip(1, 5); // budget 1 already spent
    }
}
