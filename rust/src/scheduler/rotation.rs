//! Word-rotation scheduling (paper §3.1, pseudocode Fig 4).
//!
//! The V words are split into U subsets V_1..V_U.  In round C, worker a is
//! assigned subset ((a + C - 1) mod U) + 1 (1-indexed in the paper; we use
//! 0-indexed `(a + c) % u`).  Every subset is held by exactly one worker
//! per round (disjointness ⇒ near-conditional-independence of the parallel
//! Gibbs updates), and after U rounds every worker has seen every subset.

/// Stateful rotation scheduler over `n_slices` partitions and an equal
/// number of workers.
#[derive(Debug, Clone)]
pub struct RotationScheduler {
    n_slices: usize,
    /// Rotation counter C (a "global model variable" in the paper).
    counter: u64,
}

impl RotationScheduler {
    pub fn new(n_slices: usize) -> Self {
        assert!(n_slices > 0);
        RotationScheduler { n_slices, counter: 0 }
    }

    /// Slice assigned to `worker` this round.
    pub fn slice_for(&self, worker: usize) -> usize {
        (worker + self.counter as usize) % self.n_slices
    }

    /// Assignments for all workers this round, then advance the counter.
    pub fn next_round(&mut self) -> Vec<usize> {
        let out = (0..self.n_slices).map(|w| self.slice_for(w)).collect();
        self.counter += 1;
        out
    }

    pub fn round(&self) -> u64 {
        self.counter
    }

    pub fn n_slices(&self) -> usize {
        self.n_slices
    }

    /// Partition vocabulary ids [0, v) into `u` balanced slices; returns
    /// slice id per word.  Words are strided across slices so Zipf-heavy
    /// low ids spread evenly (load balance, same intent as the paper's
    /// frequency-aware split).
    pub fn partition_words(v: usize, u: usize) -> Vec<usize> {
        (0..v).map(|w| w % u).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check, Prop};

    #[test]
    fn each_round_is_a_permutation() {
        let mut s = RotationScheduler::new(8);
        for _ in 0..20 {
            let mut assign = s.next_round();
            assign.sort_unstable();
            assert_eq!(assign, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_worker_sees_every_slice_in_u_rounds() {
        let u = 6;
        let mut s = RotationScheduler::new(u);
        let mut seen = vec![vec![false; u]; u];
        for _ in 0..u {
            for (w, slice) in s.next_round().into_iter().enumerate() {
                seen[w][slice] = true;
            }
        }
        assert!(seen.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn matches_paper_formula() {
        // paper: idx = ((a + C - 1) mod U) + 1 with 1-indexed a, C
        let mut s = RotationScheduler::new(4);
        s.next_round(); // C becomes 1
        // our round C=1: worker a0 -> slice 1
        assert_eq!(s.slice_for(0), 1);
        assert_eq!(s.slice_for(3), 0);
    }

    #[test]
    fn word_partition_is_balanced() {
        let part = RotationScheduler::partition_words(103, 4);
        let mut counts = [0usize; 4];
        for &s in &part {
            counts[s] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn prop_rotation_disjoint_every_round() {
        prop_check("rotation disjointness", 100, |g| {
            let u = g.usize_in(1, 64);
            let rounds = g.usize_in(1, 20);
            let mut s = RotationScheduler::new(u);
            for _ in 0..rounds {
                let mut a = s.next_round();
                a.sort_unstable();
                a.dedup();
                if a.len() != u {
                    return Prop::Fail(format!("collision with u={u}"));
                }
            }
            Prop::Ok
        });
    }

    #[test]
    fn prop_full_coverage_after_u_rounds() {
        prop_check("rotation coverage", 50, |g| {
            let u = g.usize_in(1, 32);
            let mut s = RotationScheduler::new(u);
            let mut cover = vec![0usize; u];
            for _ in 0..u {
                cover[s.slice_for(g.usize_in(0, u - 1))] += 0; // no-op read
                for (w, slice) in s.next_round().into_iter().enumerate() {
                    if w == 0 {
                        cover[slice] += 1;
                    }
                }
            }
            ensure(
                cover.iter().all(|&c| c == 1),
                format!("worker 0 coverage {cover:?}"),
            )
        });
    }
}
