//! Word-rotation scheduling (paper §3.1, pseudocode Fig 4), generalized
//! from "P slices on P workers" to **U ≥ P slices rotating over P
//! workers** (slice over-decomposition à la Zheng et al., "Model-Parallel
//! Inference for Big Topic Models").
//!
//! The V words are split into U subsets V_1..V_U arranged on a **virtual
//! ring** of U positions.  Worker `p` owns positions `{p, p+P, p+2P, …}`,
//! so each round it holds ⌈U/P⌉ (or ⌊U/P⌋) slices — its *slice queue* —
//! and sweeps them in position order.  Each round the whole ring shifts by
//! one position, so every subset is held by exactly one worker per round
//! (disjointness ⇒ near-conditional-independence of the parallel Gibbs
//! updates) and every worker sees every subset within U rounds.  With
//! U = P and the identity placement this reduces bit-exactly to the
//! paper's formula: worker `a` holds subset `(a + C) % U` in round `C`.
//!
//! Why over-decompose?  Under pipelined rotation
//! ([`crate::coordinator::ExecutionMode::Rotation`]) a worker's next slice
//! arrives from its previous holder as an async handoff.  With U = P the
//! worker has exactly one slice per round and stalls for the full handoff
//! gap; with U > P it samples one queued slice while another is still in
//! flight, hiding the gap (see the engine's per-slice virtual-time model).
//!
//! The *placement* — which slice starts at which virtual position — is a
//! free knob.  Positions `{c, c+P, …}` always belong to one worker and
//! travel the ring together (a **cohort**), so placement decides (a) how
//! balanced each worker's per-round token mass is and (b) which cohorts
//! start on which workers.  [`skew_aware_placement`] balances cohort
//! masses LPT-style and starts heavy cohorts on fast workers (Lee et al.,
//! "Structure-Aware Dynamic Scheduler").

/// How a worker services its per-round slice queue.
///
/// The rotation primitive only requires per-round *disjointness* of the
/// slice leases, not a fixed service order — which slice of its queue a
/// worker sweeps first is a free knob.  `Strict` is the PR-3 discipline
/// (virtual-position order, bit-exact with the original stream);
/// `Availability` sweeps whichever queued slice's handoff *landed first*
/// (earliest-ready-first), so a worker never stalls on one in-flight
/// handoff while another queued slice already sits parked.  The knob
/// changes neither the queues' contents nor any invariant — disjointness,
/// U-round coverage, and fork-free version chains are order-independent —
/// only the within-queue sweep order (worker side, via
/// [`crate::kvstore::SliceRouter::try_take`] + arrival stamps) and the
/// engine's virtual-time replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueOrder {
    /// Fixed virtual-position order (the paper's stream; default).
    #[default]
    Strict,
    /// Earliest-ready-first over the worker's queued slices.
    Availability,
}

/// The virtual ring position that holds `position`'s current slice *next*
/// round on a `u`-position ring — the single source of truth for the
/// rotation's orientation.  Position `v` holds slice `(v + C) % U` in
/// round `C`; that slice is held by `(v - 1) % U` in round `C + 1`.  With
/// U = P positions are workers and this is the worker-ring successor used
/// by `StradsApp::handoff_successor`'s default.
pub fn ring_successor(position: usize, u: usize) -> usize {
    (position + u - 1) % u
}

/// Inverse of [`ring_successor`]: the position whose previous-round slice
/// `position` receives this round.
pub fn ring_source(position: usize, u: usize) -> usize {
    (position + 1) % u
}

/// The worker that owns virtual ring position `position` on a `p`-worker
/// cluster (positions stride the worker set).
pub fn position_owner(position: usize, n_workers: usize) -> usize {
    position % n_workers
}

/// Skew-aware ring placement: order `masses.len()` slices on the virtual
/// ring so that (a) each worker's per-round token mass is balanced and
/// (b) heavy slices start on fast workers.
///
/// Positions `{c, c+P, …}` form a *cohort*: one worker holds all of them
/// each round and the cohort travels the ring as a unit, so cohort
/// composition fully determines the per-round load split.  Greedy
/// construction, heaviest first:
///
/// 1. workers are ranked by `speeds` (relative speed, higher = faster);
/// 2. each slice goes to the cohort with the smallest *time* load
///    (mass ÷ owner speed) that still has free positions;
/// 3. within a cohort, heavier slices take earlier positions — they are
///    swept first, releasing their handoff to the next holder earliest.
///
/// Returns `placement[position] = slice_id`, a permutation of
/// `0..masses.len()`; feed it to [`RotationScheduler::set_placement`].
pub fn skew_aware_placement(masses: &[u64], speeds: &[f64]) -> Vec<usize> {
    let u = masses.len();
    let p = speeds.len();
    assert!(p > 0, "placement needs at least one worker");
    assert!(u >= p, "fewer slices than workers");
    // rank workers fastest-first (ties broken by id for determinism)
    let mut worker_rank: Vec<usize> = (0..p).collect();
    worker_rank.sort_by(|&a, &b| {
        speeds[b].partial_cmp(&speeds[a]).unwrap().then(a.cmp(&b))
    });
    // cohort g is anchored at residue worker_rank[g]; its capacity is the
    // number of ring positions with that residue
    let capacity: Vec<usize> =
        worker_rank.iter().map(|&w| (u - w).div_ceil(p)).collect();
    // LPT into cohorts, weighted by the owning worker's speed
    let mut order: Vec<usize> = (0..u).collect();
    order.sort_by(|&a, &b| masses[b].cmp(&masses[a]).then(a.cmp(&b)));
    let mut cohort_slices: Vec<Vec<usize>> = vec![Vec::new(); p];
    let mut cohort_load = vec![0.0f64; p];
    for slice in order {
        let mut best: Option<usize> = None;
        for g in 0..p {
            if cohort_slices[g].len() >= capacity[g] {
                continue;
            }
            let t = cohort_load[g] / speeds[worker_rank[g]].max(1e-12);
            let better = match best {
                None => true,
                Some(bg) => {
                    let bt =
                        cohort_load[bg] / speeds[worker_rank[bg]].max(1e-12);
                    t < bt
                }
            };
            if better {
                best = Some(g);
            }
        }
        let g = best.expect("cohort capacities sum to the slice count");
        cohort_slices[g].push(slice); // heaviest first: earliest position
        cohort_load[g] += masses[slice] as f64;
    }
    let mut placement = vec![usize::MAX; u];
    for (g, slices) in cohort_slices.iter().enumerate() {
        let w = worker_rank[g];
        for (j, &slice) in slices.iter().enumerate() {
            placement[w + j * p] = slice;
        }
    }
    debug_assert!(placement.iter().all(|&s| s < u));
    placement
}

/// Stateful rotation scheduler over `n_slices` (U) partitions and
/// `n_workers` (P ≤ U) workers.
#[derive(Debug, Clone)]
pub struct RotationScheduler {
    n_slices: usize,
    n_workers: usize,
    /// `placement[v]` = slice initially at virtual ring position `v`.
    placement: Vec<usize>,
    /// Rotation counter C (a "global model variable" in the paper).
    counter: u64,
    /// Within-queue service discipline (does not affect queue contents).
    order: QueueOrder,
}

impl RotationScheduler {
    /// One slice per worker (U = P), identity placement — the paper's
    /// original schedule.
    pub fn new(n_slices: usize) -> Self {
        Self::with_workers(n_slices, n_slices)
    }

    /// U ≥ P slices over P workers, identity placement.
    pub fn with_workers(n_slices: usize, n_workers: usize) -> Self {
        assert!(n_workers > 0, "need at least one worker");
        assert!(
            n_slices >= n_workers,
            "fewer slices ({n_slices}) than workers ({n_workers})"
        );
        RotationScheduler {
            n_slices,
            n_workers,
            placement: (0..n_slices).collect(),
            counter: 0,
            order: QueueOrder::Strict,
        }
    }

    /// Set the within-queue service discipline (see [`QueueOrder`]).  May
    /// be flipped at any round boundary: the queues themselves are
    /// unchanged, so no handoff chain forks.
    pub fn set_queue_order(&mut self, order: QueueOrder) {
        self.order = order;
    }

    /// The within-queue service discipline in effect.
    pub fn queue_order(&self) -> QueueOrder {
        self.order
    }

    /// Install a ring placement (e.g. from [`skew_aware_placement`]).
    /// Must be a permutation of the slice ids, set before the first round
    /// — re-ordering a ring with slices already in flight would fork the
    /// handoff chains.
    pub fn set_placement(&mut self, placement: Vec<usize>) {
        assert_eq!(self.counter, 0, "placement must be set before round 0");
        assert_eq!(placement.len(), self.n_slices);
        let mut seen = vec![false; self.n_slices];
        for &s in &placement {
            assert!(s < self.n_slices && !seen[s], "placement not a permutation");
            seen[s] = true;
        }
        self.placement = placement;
    }

    /// Slice at virtual ring position `v` this round.
    pub fn slice_at(&self, v: usize) -> usize {
        self.placement[(v + self.counter as usize) % self.n_slices]
    }

    /// First slice of `worker`'s queue this round (its only slice when
    /// U = P, where this matches the paper's `(a + C) % U`).
    pub fn slice_for(&self, worker: usize) -> usize {
        self.slice_at(worker)
    }

    /// This round's slice queue per worker (position order `p, p+P, …`),
    /// without advancing the counter.  Queues are disjoint and jointly
    /// cover all U slices.
    pub fn queues(&self) -> Vec<Vec<usize>> {
        (0..self.n_workers)
            .map(|p| {
                (p..self.n_slices)
                    .step_by(self.n_workers)
                    .map(|v| self.slice_at(v))
                    .collect()
            })
            .collect()
    }

    /// Assignments for all workers this round (single-slice U = P form),
    /// then advance the counter.
    pub fn next_round(&mut self) -> Vec<usize> {
        assert_eq!(
            self.n_slices, self.n_workers,
            "next_round is the U = P form; use next_round_queues"
        );
        self.next_round_queues()
            .into_iter()
            .map(|q| q[0])
            .collect()
    }

    /// Slice queues for all workers this round, then advance the counter.
    pub fn next_round_queues(&mut self) -> Vec<Vec<usize>> {
        let out = self.queues();
        self.counter += 1;
        out
    }

    pub fn round(&self) -> u64 {
        self.counter
    }

    pub fn n_slices(&self) -> usize {
        self.n_slices
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// The worker holding the slice at position `v` *next* round — where a
    /// pipelined rotation forwards that slice (see [`ring_successor`]).
    pub fn next_holder(&self, v: usize) -> usize {
        position_owner(ring_successor(v, self.n_slices), self.n_workers)
    }

    /// U = P form: the worker that holds `worker`'s current slice next
    /// round (see [`ring_successor`]).
    pub fn handoff_successor(&self, worker: usize) -> usize {
        ring_successor(worker, self.n_slices)
    }

    /// U = P form: the worker whose previous-round slice `worker` receives
    /// this round — the inverse of
    /// [`RotationScheduler::handoff_successor`] (see [`ring_source`]).
    pub fn handoff_source(&self, worker: usize) -> usize {
        ring_source(worker, self.n_slices)
    }

    /// Partition vocabulary ids [0, v) into `u` slices by striding the
    /// **id** space (`w % u`).  This balances word *counts* only — it is
    /// frequency-blind, so a corpus whose heavy words cluster in id space
    /// (e.g. the topic-banded generator in `datagen::lda_corpus`) can
    /// still overload one slice.  Use
    /// [`RotationScheduler::partition_words_by_freq`] when corpus
    /// frequencies are known.
    pub fn partition_words(v: usize, u: usize) -> Vec<usize> {
        (0..v).map(|w| w % u).collect()
    }

    /// Frequency-weighted split: words are ranked by corpus frequency and
    /// greedily assigned, heaviest first, to the currently lightest slice
    /// (ties broken toward the slice with fewer words), so Zipf-heavy
    /// heads spread across slices instead of piling into one.  This is the
    /// paper's frequency-aware load balance for rotation rounds: per-round
    /// compute is proportional to a slice's *token mass*, not its word
    /// count.  Returns the slice id per word.
    pub fn partition_words_by_freq(freqs: &[u64], u: usize) -> Vec<usize> {
        assert!(u > 0);
        let mut order: Vec<usize> = (0..freqs.len()).collect();
        order.sort_by(|&a, &b| freqs[b].cmp(&freqs[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; u];
        let mut count = vec![0usize; u];
        let mut out = vec![0usize; freqs.len()];
        for w in order {
            let mut best = 0usize;
            for a in 1..u {
                if (load[a], count[a]) < (load[best], count[best]) {
                    best = a;
                }
            }
            out[w] = best;
            load[best] += freqs[w];
            count[best] += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check, Prop};

    #[test]
    fn each_round_is_a_permutation() {
        let mut s = RotationScheduler::new(8);
        for _ in 0..20 {
            let mut assign = s.next_round();
            assign.sort_unstable();
            assert_eq!(assign, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_worker_sees_every_slice_in_u_rounds() {
        let u = 6;
        let mut s = RotationScheduler::new(u);
        let mut seen = vec![vec![false; u]; u];
        for _ in 0..u {
            for (w, slice) in s.next_round().into_iter().enumerate() {
                seen[w][slice] = true;
            }
        }
        assert!(seen.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn matches_paper_formula() {
        // paper: idx = ((a + C - 1) mod U) + 1 with 1-indexed a, C
        let mut s = RotationScheduler::new(4);
        s.next_round(); // C becomes 1
        // our round C=1: worker a0 -> slice 1
        assert_eq!(s.slice_for(0), 1);
        assert_eq!(s.slice_for(3), 0);
    }

    #[test]
    fn handoff_order_matches_the_rotation() {
        // forwarding every slice to its successor must reproduce the next
        // round's assignment exactly
        let u = 7;
        let mut s = RotationScheduler::new(u);
        for _ in 0..2 * u {
            let now = s.next_round();
            let next = (0..u).map(|w| s.slice_for(w)).collect::<Vec<_>>();
            for (w, &slice) in now.iter().enumerate() {
                let succ = s.handoff_successor(w);
                assert_eq!(next[succ], slice, "worker {w} -> {succ}");
                assert_eq!(s.handoff_source(succ), w);
            }
        }
    }

    #[test]
    fn multislice_queues_match_next_holder() {
        // U = 2P ring: the slice at position v this round must be in the
        // queue of next_holder(v)'s worker next round.
        let (u, p) = (8, 4);
        let mut s = RotationScheduler::with_workers(u, p);
        for _ in 0..3 * u {
            let dest: Vec<usize> = (0..u).map(|v| s.next_holder(v)).collect();
            let now = s.next_round_queues();
            let next = s.queues();
            for w in 0..p {
                for (j, &slice) in now[w].iter().enumerate() {
                    let v = w + j * p;
                    assert!(
                        next[dest[v]].contains(&slice),
                        "slice {slice} at pos {v} must move to worker {}",
                        dest[v]
                    );
                }
            }
        }
    }

    #[test]
    fn u_equals_p_queues_reproduce_the_single_slice_schedule() {
        // the generalized queue path with U = P must emit exactly the
        // paper's `(a + C) % U` assignment, one slice per worker — the
        // schedule-level half of the "U = P is bit-identical to the
        // single-slice rotation" regression (the app-level half lives in
        // tests/rotation_handoff.rs).
        let u = 5;
        let mut s = RotationScheduler::with_workers(u, u);
        for c in 0..3 * u as u64 {
            for (w, q) in s.next_round_queues().into_iter().enumerate() {
                assert_eq!(q, vec![(w + c as usize) % u]);
            }
        }
    }

    #[test]
    fn freq_partition_balances_token_mass_on_a_zipf_corpus() {
        use crate::datagen::lda_corpus::{self, CorpusConfig};
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 400,
            vocab: 1200,
            n_topics: 6,
            ..Default::default()
        });
        let mut freqs = vec![0u64; corpus.vocab];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let u = 8;
        let mass = |part: &[usize]| {
            let mut m = vec![0u64; u];
            for (w, &a) in part.iter().enumerate() {
                m[a] += freqs[w];
            }
            m
        };
        let by_freq = mass(&RotationScheduler::partition_words_by_freq(&freqs, u));
        let (mn, mx) = (
            *by_freq.iter().min().unwrap() as f64,
            *by_freq.iter().max().unwrap() as f64,
        );
        assert!(
            mx <= 1.1 * mn,
            "freq-aware split imbalanced: {by_freq:?}"
        );
        // ...and it must not do worse than the frequency-blind id stride
        let by_id = mass(&RotationScheduler::partition_words(corpus.vocab, u));
        let (id_mn, id_mx) = (
            *by_id.iter().min().unwrap() as f64,
            *by_id.iter().max().unwrap() as f64,
        );
        assert!(mx / mn <= id_mx / id_mn.max(1.0) + 1e-9);
    }

    #[test]
    fn freq_partition_spreads_zero_freq_words_too() {
        // all-zero frequencies degenerate to a word-count round-robin
        let part = RotationScheduler::partition_words_by_freq(&[0; 10], 3);
        let mut counts = [0usize; 3];
        for &a in &part {
            counts[a] += 1;
        }
        let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn word_partition_is_balanced() {
        let part = RotationScheduler::partition_words(103, 4);
        let mut counts = [0usize; 4];
        for &s in &part {
            counts[s] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn prop_rotation_disjoint_every_round() {
        prop_check("rotation disjointness", 100, |g| {
            let u = g.usize_in(1, 64);
            let rounds = g.usize_in(1, 20);
            let mut s = RotationScheduler::new(u);
            for _ in 0..rounds {
                let mut a = s.next_round();
                a.sort_unstable();
                a.dedup();
                if a.len() != u {
                    return Prop::Fail(format!("collision with u={u}"));
                }
            }
            Prop::Ok
        });
    }

    #[test]
    fn prop_full_coverage_after_u_rounds() {
        prop_check("rotation coverage", 50, |g| {
            let u = g.usize_in(1, 32);
            let mut s = RotationScheduler::new(u);
            let mut cover = vec![0usize; u];
            for _ in 0..u {
                cover[s.slice_for(g.usize_in(0, u - 1))] += 0; // no-op read
                for (w, slice) in s.next_round().into_iter().enumerate() {
                    if w == 0 {
                        cover[slice] += 1;
                    }
                }
            }
            ensure(
                cover.iter().all(|&c| c == 1),
                format!("worker 0 coverage {cover:?}"),
            )
        });
    }

    #[test]
    fn prop_multislice_rounds_disjoint_and_cover() {
        // random U ≥ P rings (random placements too): every round's queues
        // are disjoint and jointly cover all U slices, queue sizes differ
        // by at most one, and every worker sees every slice within U
        // rounds.
        prop_check("multi-slice rotation", 60, |g| {
            let p = g.usize_in(1, 8);
            let u = p * g.usize_in(1, 4) + g.usize_in(0, p - 1);
            let mut s = RotationScheduler::with_workers(u, p);
            // random permutation placement via sort-by-random-key
            let mut keyed: Vec<(u64, usize)> =
                (0..u).map(|a| (g.seed(), a)).collect();
            keyed.sort_unstable();
            s.set_placement(keyed.into_iter().map(|(_, a)| a).collect());
            let mut seen = vec![vec![false; u]; p];
            for _ in 0..u {
                let queues = s.next_round_queues();
                let mut all: Vec<usize> =
                    queues.iter().flatten().copied().collect();
                all.sort_unstable();
                if all != (0..u).collect::<Vec<_>>() {
                    return Prop::Fail(format!(
                        "round not a partition of slices (u={u}, p={p})"
                    ));
                }
                let (qmin, qmax) = (
                    queues.iter().map(|q| q.len()).min().unwrap(),
                    queues.iter().map(|q| q.len()).max().unwrap(),
                );
                if qmax - qmin > 1 {
                    return Prop::Fail(format!(
                        "queue sizes unbalanced: {qmin}..{qmax}"
                    ));
                }
                for (w, q) in queues.iter().enumerate() {
                    for &a in q {
                        seen[w][a] = true;
                    }
                }
            }
            ensure(
                seen.iter().all(|row| row.iter().all(|&b| b)),
                format!("coverage hole after {u} rounds (p={p})"),
            )
        });
    }

    #[test]
    fn prop_skew_placement_is_permutation() {
        prop_check("skew-aware placement", 80, |g| {
            let p = g.usize_in(1, 6);
            let u = p * g.usize_in(1, 5);
            let masses: Vec<u64> =
                (0..u).map(|_| g.usize_in(0, 10_000) as u64).collect();
            let speeds: Vec<f64> = (0..p).map(|_| g.f64_in(0.1, 8.0)).collect();
            let placement = skew_aware_placement(&masses, &speeds);
            let mut sorted = placement.clone();
            sorted.sort_unstable();
            ensure(
                sorted == (0..u).collect::<Vec<_>>(),
                format!("not a permutation: {placement:?}"),
            )
        });
    }

    #[test]
    fn skew_placement_balances_cohorts_and_favors_fast_workers() {
        // 4 slices, 2 workers, worker 1 twice as fast: the heaviest slice
        // must start on worker 1's residue, and cohort time loads
        // (mass / speed) must be no worse than the heaviest single slice.
        let masses = vec![100u64, 10, 60, 50];
        let speeds = vec![1.0, 2.0];
        let placement = skew_aware_placement(&masses, &speeds);
        // cohort of worker w = positions {w, w+2}
        let cohort = |w: usize| vec![placement[w], placement[w + 2]];
        let mass =
            |c: &[usize]| c.iter().map(|&a| masses[a]).sum::<u64>() as f64;
        let (c0, c1) = (cohort(0), cohort(1));
        // heaviest slice (id 0) lands on the fast worker's cohort
        assert!(c1.contains(&0), "heavy slice on slow worker: {placement:?}");
        // time loads balanced within the heaviest slice's time
        let (t0, t1) = (mass(&c0) / 1.0, mass(&c1) / 2.0);
        assert!(
            (t0 - t1).abs() <= 100.0,
            "time imbalance {t0} vs {t1}: {placement:?}"
        );
    }

    #[test]
    fn skew_placement_handles_uneven_slice_counts() {
        // U = 5, P = 2: residue 0 owns 3 positions, residue 1 owns 2
        let masses = vec![5u64, 4, 3, 2, 1];
        let speeds = vec![1.0, 1.0];
        let placement = skew_aware_placement(&masses, &speeds);
        let mut sorted = placement.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn bad_placement_panics() {
        let mut s = RotationScheduler::with_workers(4, 2);
        s.set_placement(vec![0, 1, 2, 2]);
    }

    #[test]
    fn queue_order_knob_does_not_perturb_the_queues() {
        // Availability reorders the *service* of a queue, never its
        // contents: the emitted queue stream must be identical to Strict's
        // (which itself is the PR-3 / paper stream, locked by
        // u_equals_p_queues_reproduce_the_single_slice_schedule above).
        let (u, p) = (10, 4);
        let mut strict = RotationScheduler::with_workers(u, p);
        let mut avail = RotationScheduler::with_workers(u, p);
        avail.set_queue_order(QueueOrder::Availability);
        assert_eq!(avail.queue_order(), QueueOrder::Availability);
        assert_eq!(strict.queue_order(), QueueOrder::Strict);
        for _ in 0..3 * u {
            assert_eq!(strict.next_round_queues(), avail.next_round_queues());
        }
    }
}
