//! Word-rotation scheduling (paper §3.1, pseudocode Fig 4).
//!
//! The V words are split into U subsets V_1..V_U.  In round C, worker a is
//! assigned subset ((a + C - 1) mod U) + 1 (1-indexed in the paper; we use
//! 0-indexed `(a + c) % u`).  Every subset is held by exactly one worker
//! per round (disjointness ⇒ near-conditional-independence of the parallel
//! Gibbs updates), and after U rounds every worker has seen every subset.

/// The worker that holds `worker`'s current slice *next* round on a
/// `u`-worker ring — the single source of truth for the rotation's
/// orientation.  Worker `w` holds slice `(w + C) % U` in round `C`; that
/// slice is held by `(w - 1) % U` in round `C + 1`.  Used by both
/// [`RotationScheduler::handoff_successor`] and the engine's
/// `StradsApp::handoff_successor` default.
pub fn ring_successor(worker: usize, u: usize) -> usize {
    (worker + u - 1) % u
}

/// Inverse of [`ring_successor`]: the worker whose previous-round slice
/// `worker` receives this round.
pub fn ring_source(worker: usize, u: usize) -> usize {
    (worker + 1) % u
}

/// Stateful rotation scheduler over `n_slices` partitions and an equal
/// number of workers.
#[derive(Debug, Clone)]
pub struct RotationScheduler {
    n_slices: usize,
    /// Rotation counter C (a "global model variable" in the paper).
    counter: u64,
}

impl RotationScheduler {
    pub fn new(n_slices: usize) -> Self {
        assert!(n_slices > 0);
        RotationScheduler { n_slices, counter: 0 }
    }

    /// Slice assigned to `worker` this round.
    pub fn slice_for(&self, worker: usize) -> usize {
        (worker + self.counter as usize) % self.n_slices
    }

    /// Assignments for all workers this round, then advance the counter.
    pub fn next_round(&mut self) -> Vec<usize> {
        let out = (0..self.n_slices).map(|w| self.slice_for(w)).collect();
        self.counter += 1;
        out
    }

    pub fn round(&self) -> u64 {
        self.counter
    }

    pub fn n_slices(&self) -> usize {
        self.n_slices
    }

    /// The worker that holds `worker`'s current slice *next* round — the
    /// ring successor a pipelined rotation forwards the slice to (see
    /// [`ring_successor`]).
    pub fn handoff_successor(&self, worker: usize) -> usize {
        ring_successor(worker, self.n_slices)
    }

    /// The worker whose previous-round slice `worker` receives this round
    /// — the ring source a pipelined rotation waits on.  Inverse of
    /// [`RotationScheduler::handoff_successor`] (see [`ring_source`]).
    pub fn handoff_source(&self, worker: usize) -> usize {
        ring_source(worker, self.n_slices)
    }

    /// Partition vocabulary ids [0, v) into `u` slices by striding the
    /// **id** space (`w % u`).  This balances word *counts* only — it is
    /// frequency-blind, so a corpus whose heavy words cluster in id space
    /// (e.g. the topic-banded generator in `datagen::lda_corpus`) can
    /// still overload one slice.  Use
    /// [`RotationScheduler::partition_words_by_freq`] when corpus
    /// frequencies are known.
    pub fn partition_words(v: usize, u: usize) -> Vec<usize> {
        (0..v).map(|w| w % u).collect()
    }

    /// Frequency-weighted split: words are ranked by corpus frequency and
    /// greedily assigned, heaviest first, to the currently lightest slice
    /// (ties broken toward the slice with fewer words), so Zipf-heavy
    /// heads spread across slices instead of piling into one.  This is the
    /// paper's frequency-aware load balance for rotation rounds: per-round
    /// compute is proportional to a slice's *token mass*, not its word
    /// count.  Returns the slice id per word.
    pub fn partition_words_by_freq(freqs: &[u64], u: usize) -> Vec<usize> {
        assert!(u > 0);
        let mut order: Vec<usize> = (0..freqs.len()).collect();
        order.sort_by(|&a, &b| freqs[b].cmp(&freqs[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; u];
        let mut count = vec![0usize; u];
        let mut out = vec![0usize; freqs.len()];
        for w in order {
            let mut best = 0usize;
            for a in 1..u {
                if (load[a], count[a]) < (load[best], count[best]) {
                    best = a;
                }
            }
            out[w] = best;
            load[best] += freqs[w];
            count[best] += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check, Prop};

    #[test]
    fn each_round_is_a_permutation() {
        let mut s = RotationScheduler::new(8);
        for _ in 0..20 {
            let mut assign = s.next_round();
            assign.sort_unstable();
            assert_eq!(assign, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_worker_sees_every_slice_in_u_rounds() {
        let u = 6;
        let mut s = RotationScheduler::new(u);
        let mut seen = vec![vec![false; u]; u];
        for _ in 0..u {
            for (w, slice) in s.next_round().into_iter().enumerate() {
                seen[w][slice] = true;
            }
        }
        assert!(seen.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn matches_paper_formula() {
        // paper: idx = ((a + C - 1) mod U) + 1 with 1-indexed a, C
        let mut s = RotationScheduler::new(4);
        s.next_round(); // C becomes 1
        // our round C=1: worker a0 -> slice 1
        assert_eq!(s.slice_for(0), 1);
        assert_eq!(s.slice_for(3), 0);
    }

    #[test]
    fn handoff_order_matches_the_rotation() {
        // forwarding every slice to its successor must reproduce the next
        // round's assignment exactly
        let u = 7;
        let mut s = RotationScheduler::new(u);
        for _ in 0..2 * u {
            let now = s.next_round();
            let next = (0..u).map(|w| s.slice_for(w)).collect::<Vec<_>>();
            for (w, &slice) in now.iter().enumerate() {
                let succ = s.handoff_successor(w);
                assert_eq!(next[succ], slice, "worker {w} -> {succ}");
                assert_eq!(s.handoff_source(succ), w);
            }
        }
    }

    #[test]
    fn freq_partition_balances_token_mass_on_a_zipf_corpus() {
        use crate::datagen::lda_corpus::{self, CorpusConfig};
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 400,
            vocab: 1200,
            n_topics: 6,
            ..Default::default()
        });
        let mut freqs = vec![0u64; corpus.vocab];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let u = 8;
        let mass = |part: &[usize]| {
            let mut m = vec![0u64; u];
            for (w, &a) in part.iter().enumerate() {
                m[a] += freqs[w];
            }
            m
        };
        let by_freq = mass(&RotationScheduler::partition_words_by_freq(&freqs, u));
        let (mn, mx) = (
            *by_freq.iter().min().unwrap() as f64,
            *by_freq.iter().max().unwrap() as f64,
        );
        assert!(
            mx <= 1.1 * mn,
            "freq-aware split imbalanced: {by_freq:?}"
        );
        // ...and it must not do worse than the frequency-blind id stride
        let by_id = mass(&RotationScheduler::partition_words(corpus.vocab, u));
        let (id_mn, id_mx) = (
            *by_id.iter().min().unwrap() as f64,
            *by_id.iter().max().unwrap() as f64,
        );
        assert!(mx / mn <= id_mx / id_mn.max(1.0) + 1e-9);
    }

    #[test]
    fn freq_partition_spreads_zero_freq_words_too() {
        // all-zero frequencies degenerate to a word-count round-robin
        let part = RotationScheduler::partition_words_by_freq(&[0; 10], 3);
        let mut counts = [0usize; 3];
        for &a in &part {
            counts[a] += 1;
        }
        let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn word_partition_is_balanced() {
        let part = RotationScheduler::partition_words(103, 4);
        let mut counts = [0usize; 4];
        for &s in &part {
            counts[s] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn prop_rotation_disjoint_every_round() {
        prop_check("rotation disjointness", 100, |g| {
            let u = g.usize_in(1, 64);
            let rounds = g.usize_in(1, 20);
            let mut s = RotationScheduler::new(u);
            for _ in 0..rounds {
                let mut a = s.next_round();
                a.sort_unstable();
                a.dedup();
                if a.len() != u {
                    return Prop::Fail(format!("collision with u={u}"));
                }
            }
            Prop::Ok
        });
    }

    #[test]
    fn prop_full_coverage_after_u_rounds() {
        prop_check("rotation coverage", 50, |g| {
            let u = g.usize_in(1, 32);
            let mut s = RotationScheduler::new(u);
            let mut cover = vec![0usize; u];
            for _ in 0..u {
                cover[s.slice_for(g.usize_in(0, u - 1))] += 0; // no-op read
                for (w, slice) in s.next_round().into_iter().enumerate() {
                    if w == 0 {
                        cover[slice] += 1;
                    }
                }
            }
            ensure(
                cover.iter().all(|&c| c == 1),
                format!("worker 0 coverage {cover:?}"),
            )
        });
    }
}
