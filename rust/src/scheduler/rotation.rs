//! Word-rotation scheduling (paper §3.1, pseudocode Fig 4), generalized
//! from "P slices on P workers" to **U ≥ P slices rotating over P
//! workers** (slice over-decomposition à la Zheng et al., "Model-Parallel
//! Inference for Big Topic Models").
//!
//! The V words are split into U subsets V_1..V_U arranged on a **virtual
//! ring** of U positions.  Worker `p` owns positions `{p, p+P, p+2P, …}`,
//! so each round it holds ⌈U/P⌉ (or ⌊U/P⌋) slices — its *slice queue* —
//! and sweeps them in position order.  Each round the whole ring shifts by
//! one position, so every subset is held by exactly one worker per round
//! (disjointness ⇒ near-conditional-independence of the parallel Gibbs
//! updates) and every worker sees every subset within U rounds.  With
//! U = P and the identity placement this reduces bit-exactly to the
//! paper's formula: worker `a` holds subset `(a + C) % U` in round `C`.
//!
//! Why over-decompose?  Under pipelined rotation
//! ([`crate::coordinator::ExecutionMode::Rotation`]) a worker's next slice
//! arrives from its previous holder as an async handoff.  With U = P the
//! worker has exactly one slice per round and stalls for the full handoff
//! gap; with U > P it samples one queued slice while another is still in
//! flight, hiding the gap (see the engine's per-slice virtual-time model).
//!
//! The *placement* — which slice starts at which virtual position — is a
//! free knob.  Positions `{c, c+P, …}` always belong to one worker and
//! travel the ring together (a **cohort**), so placement decides (a) how
//! balanced each worker's per-round token mass is and (b) which cohorts
//! start on which workers.  [`skew_aware_placement`] balances cohort
//! masses LPT-style and starts heavy cohorts on fast workers (Lee et al.,
//! "Structure-Aware Dynamic Scheduler").

use crate::scheduler::debt::CoverageDebtLedger;
use crate::trace::{Event, TraceBuffer, TracePlumbing, TraceReplayer};
use std::sync::Arc;

/// How a worker services its per-round slice queue.
///
/// The rotation primitive only requires per-round *disjointness* of the
/// slice leases, not a fixed service order — which slice of its queue a
/// worker sweeps first is a free knob.  `Strict` is the PR-3 discipline
/// (virtual-position order, bit-exact with the original stream);
/// `Availability` sweeps whichever queued slice's handoff *landed first*
/// (earliest-ready-first), so a worker never stalls on one in-flight
/// handoff while another queued slice already sits parked; `Dynamic`
/// additionally weighs slice **token mass** — among the parked slices it
/// sweeps the heaviest first, so the sweep that gates the most downstream
/// compute releases its handoff earliest (the prioritized scheduling of
/// Lee et al., "Structure-Aware Dynamic Scheduler", applied to the
/// within-queue order).  Both reordering modes are *work-conserving*: a
/// worker's own round never finishes later than under any other
/// non-idling order, so Dynamic can only shift *when* each slice's
/// handoff lands downstream — which is exactly where skewed masses make
/// heaviest-first pay.  The knob changes neither the queues' contents nor
/// any invariant — disjointness, coverage, and fork-free version chains
/// are order-independent — only the within-queue sweep order (worker
/// side, via [`crate::kvstore::SliceRouter::try_take`] polls + arrival
/// stamps / [`crate::kvstore::SliceMass`] scores) and the engine's
/// virtual-time replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueOrder {
    /// Fixed virtual-position order (the paper's stream; default).
    #[default]
    Strict,
    /// Earliest-ready-first over the worker's queued slices.
    Availability,
    /// Heaviest-parked-first: among the queued slices whose handoffs have
    /// landed, sweep the one with the largest token mass (ties broken
    /// toward the earlier arrival, then queue position); wait only when
    /// none is parked.
    Dynamic,
}

/// Whether a round may *skip* a still-in-flight slice entirely.
///
/// Reordering ([`QueueOrder`]) changes only the within-queue sweep order;
/// `Defer` goes further: a slice whose handoff has not landed at schedule
/// time is left out of the round's grants altogether — its current holder
/// keeps the lease slot open and the slice is leased in a later round —
/// bounded by a per-slice [`CoverageDebtLedger`] budget so full coverage
/// still holds within `U + debt_limit` rounds (see
/// [`crate::scheduler::debt`]).  `Never` (default) grants every slice
/// every round — the PR-4 schedule, bit-exact.
///
/// Two properties of `Defer` follow from its availability signal reading
/// the **live** data plane ([`crate::kvstore::rotation_availability`]):
/// it is a *pipelining-only* relaxation — at depth 1 every handoff has
/// landed before the next schedule runs, so no round ever skips — and
/// under depth ≥ 2 the skip decisions depend on how far the in-flight
/// rounds' workers have physically progressed, so two identical runs may
/// skip differently.  Every invariant (disjointness, the
/// `U + debt_limit` coverage horizon, fork-free chains, conservation) is
/// interleaving-independent — `tests/rotation_properties.rs` sweeps
/// arbitrary availability patterns — but deterministic-replay
/// bit-exactness is only promised for `Never` (and `Defer { 0 }`, which
/// never skips).
///
/// Load-balance caveat: a deferral *permanently merges* ring positions —
/// the slice behind the frozen one advances into its position, and from
/// then on the two travel the ring together (one worker carries an extra
/// leg each round while another carries one fewer).  The lifetime budget
/// bounds the damage — at most `U × debt_limit` merge events per run —
/// so small budgets absorb transient outages at a bounded, permanent
/// balance cost; un-merging (re-spreading positions once the ring is
/// healthy) is the debt-aware placement follow-on in the ROADMAP.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SkipPolicy {
    /// Grant every slice every round (the paper's schedule; default).
    #[default]
    Never,
    /// Skip a round's unavailable slice and lease it later, deferring at
    /// most `debt_limit` rounds per slice over the run.
    Defer {
        /// Per-slice deferral budget (0 degrades to `Never`).
        debt_limit: u64,
    },
}

/// One granted lease of a round: the slice and the worker that holds it
/// next round (its handoff destination).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GrantLeg {
    pub slice_id: usize,
    pub dest_worker: usize,
}

/// The virtual ring position that holds `position`'s current slice *next*
/// round on a `u`-position ring — the single source of truth for the
/// rotation's orientation.  Position `v` holds slice `(v + C) % U` in
/// round `C`; that slice is held by `(v - 1) % U` in round `C + 1`.  With
/// U = P positions are workers and this is the worker-ring successor used
/// by `StradsApp::handoff_successor`'s default.
pub fn ring_successor(position: usize, u: usize) -> usize {
    (position + u - 1) % u
}

/// Inverse of [`ring_successor`]: the position whose previous-round slice
/// `position` receives this round.
pub fn ring_source(position: usize, u: usize) -> usize {
    (position + 1) % u
}

/// The worker that owns virtual ring position `position` on a `p`-worker
/// cluster (positions stride the worker set).
pub fn position_owner(position: usize, n_workers: usize) -> usize {
    position % n_workers
}

/// Membership-aware [`position_owner`]: the first **live** worker at or
/// cyclically after the position's residue.  With every worker alive this
/// is exactly `position % P`; with worker `w` dead, `w`'s positions fall
/// to the next live worker on the ring (which then carries a double
/// queue) so every slice is still granted every round — coverage survives
/// a crash with no skips, at a bounded balance cost until the membership
/// heals or a recovery re-placement rebalances the ring.
pub fn live_owner(alive: &[bool], position: usize) -> usize {
    let p = alive.len();
    let mut w = position % p;
    for _ in 0..p {
        if alive[w] {
            return w;
        }
        w = (w + 1) % p;
    }
    panic!("no live workers on the ring")
}

/// Skew-aware ring placement: order `masses.len()` slices on the virtual
/// ring so that (a) each worker's per-round token mass is balanced and
/// (b) heavy slices start on fast workers.
///
/// Positions `{c, c+P, …}` form a *cohort*: one worker holds all of them
/// each round and the cohort travels the ring as a unit, so cohort
/// composition fully determines the per-round load split.  Greedy
/// construction, heaviest first:
///
/// 1. workers are ranked by `speeds` (relative speed, higher = faster);
/// 2. each slice goes to the cohort with the smallest *time* load
///    (mass ÷ owner speed) that still has free positions;
/// 3. within a cohort, heavier slices take earlier positions — they are
///    swept first, releasing their handoff to the next holder earliest.
///
/// Returns `placement[position] = slice_id`, a permutation of
/// `0..masses.len()`; feed it to [`RotationScheduler::set_placement`].
pub fn skew_aware_placement(masses: &[u64], speeds: &[f64]) -> Vec<usize> {
    let u = masses.len();
    let p = speeds.len();
    assert!(p > 0, "placement needs at least one worker");
    assert!(u >= p, "fewer slices than workers");
    // rank workers fastest-first (ties broken by id for determinism)
    let mut worker_rank: Vec<usize> = (0..p).collect();
    worker_rank.sort_by(|&a, &b| {
        speeds[b].partial_cmp(&speeds[a]).unwrap().then(a.cmp(&b))
    });
    // cohort g is anchored at residue worker_rank[g]; its capacity is the
    // number of ring positions with that residue
    let capacity: Vec<usize> =
        worker_rank.iter().map(|&w| (u - w).div_ceil(p)).collect();
    // LPT into cohorts, weighted by the owning worker's speed
    let mut order: Vec<usize> = (0..u).collect();
    order.sort_by(|&a, &b| masses[b].cmp(&masses[a]).then(a.cmp(&b)));
    let mut cohort_slices: Vec<Vec<usize>> = vec![Vec::new(); p];
    let mut cohort_load = vec![0.0f64; p];
    for slice in order {
        let mut best: Option<usize> = None;
        for g in 0..p {
            if cohort_slices[g].len() >= capacity[g] {
                continue;
            }
            let t = cohort_load[g] / speeds[worker_rank[g]].max(1e-12);
            let better = match best {
                None => true,
                Some(bg) => {
                    let bt =
                        cohort_load[bg] / speeds[worker_rank[bg]].max(1e-12);
                    t < bt
                }
            };
            if better {
                best = Some(g);
            }
        }
        let g = best.expect("cohort capacities sum to the slice count");
        cohort_slices[g].push(slice); // heaviest first: earliest position
        cohort_load[g] += masses[slice] as f64;
    }
    let mut placement = vec![usize::MAX; u];
    for (g, slices) in cohort_slices.iter().enumerate() {
        let w = worker_rank[g];
        for (j, &slice) in slices.iter().enumerate() {
            placement[w + j * p] = slice;
        }
    }
    debug_assert!(placement.iter().all(|&s| s < u));
    placement
}

/// Stateful rotation scheduler over `n_slices` (U) partitions and
/// `n_workers` (P ≤ U) workers.
#[derive(Debug, Clone)]
pub struct RotationScheduler {
    n_slices: usize,
    n_workers: usize,
    /// `placement[v]` = slice initially at virtual ring position `v`.
    placement: Vec<usize>,
    /// Rotation counter C (a "global model variable" in the paper).
    counter: u64,
    /// Cluster membership: `alive[w]` = worker `w` currently accepts
    /// grants.  Dead workers' ring positions fall to the next live worker
    /// (see [`live_owner`]); all true initially.
    alive: Vec<bool>,
    /// Within-queue service discipline (does not affect queue contents).
    order: QueueOrder,
    /// Whether rounds may defer unavailable slices (see [`SkipPolicy`]).
    skip: SkipPolicy,
    /// `Defer` mode only: each slice's current virtual ring position —
    /// per-slice rotation progress, since a deferred slice stands still
    /// while the rest of the ring advances.  Empty under `Never`, where
    /// the pure `(v + C) % U` math needs no per-slice state.
    pos_of: Vec<usize>,
    /// `Defer` mode only: the per-slice deferral budget.
    debt: Option<CoverageDebtLedger>,
    /// Trace sink for `Skip` events (None = tracing off).
    trace: Option<Arc<TraceBuffer>>,
    /// Replay source: when set, `Defer`'s availability poll is answered by
    /// the recorded skip set instead of the live signal, so a replayed run
    /// reproduces the original schedule exactly.
    replay: Option<Arc<TraceReplayer>>,
}

impl RotationScheduler {
    /// One slice per worker (U = P), identity placement — the paper's
    /// original schedule.
    pub fn new(n_slices: usize) -> Self {
        Self::with_workers(n_slices, n_slices)
    }

    /// U ≥ P slices over P workers, identity placement.
    pub fn with_workers(n_slices: usize, n_workers: usize) -> Self {
        assert!(n_workers > 0, "need at least one worker");
        assert!(
            n_slices >= n_workers,
            "fewer slices ({n_slices}) than workers ({n_workers})"
        );
        RotationScheduler {
            n_slices,
            n_workers,
            placement: (0..n_slices).collect(),
            counter: 0,
            alive: vec![true; n_workers],
            order: QueueOrder::Strict,
            skip: SkipPolicy::Never,
            pos_of: Vec::new(),
            debt: None,
            trace: None,
            replay: None,
        }
    }

    /// Wire this scheduler into a run's trace plumbing: the sink receives
    /// `Skip` events (and is forwarded into the debt ledger for
    /// `DebtCharge` events), and a replayer — when present — overrides the
    /// live availability signal in [`RotationScheduler::next_round_grants`].
    /// Call after [`RotationScheduler::set_skip_policy`]; installing on a
    /// `Never`-mode scheduler is a harmless no-op beyond storing the sink.
    pub fn install_trace(&mut self, plumbing: &TracePlumbing) {
        self.trace = plumbing.sink.clone();
        self.replay = plumbing.replayer.clone();
        if let Some(debt) = &mut self.debt {
            debt.install_trace(self.trace.clone());
        }
    }

    /// Set the within-queue service discipline (see [`QueueOrder`]).  May
    /// be flipped at any round boundary: the queues themselves are
    /// unchanged, so no handoff chain forks.
    pub fn set_queue_order(&mut self, order: QueueOrder) {
        self.order = order;
    }

    /// The within-queue service discipline in effect.
    pub fn queue_order(&self) -> QueueOrder {
        self.order
    }

    /// Set the skip policy (see [`SkipPolicy`]).  Must precede round 0:
    /// `Defer` tracks per-slice ring positions, and adopting it mid-run
    /// would fork the position bookkeeping from the rounds already
    /// granted.
    pub fn set_skip_policy(&mut self, skip: SkipPolicy) {
        assert_eq!(self.counter, 0, "skip policy must be set before round 0");
        self.skip = skip;
        match skip {
            SkipPolicy::Never => {
                self.pos_of = Vec::new();
                self.debt = None;
            }
            SkipPolicy::Defer { debt_limit } => {
                self.rebuild_positions();
                let mut ledger =
                    CoverageDebtLedger::new(self.n_slices, debt_limit);
                ledger.install_trace(self.trace.clone());
                self.debt = Some(ledger);
            }
        }
    }

    /// The skip policy in effect.
    pub fn skip_policy(&self) -> SkipPolicy {
        self.skip
    }

    /// The deferral ledger (`Defer` mode only).
    pub fn coverage_debt(&self) -> Option<&CoverageDebtLedger> {
        self.debt.as_ref()
    }

    /// `pos_of[slice] = v` with `placement[v] = slice` (round-0 state).
    fn rebuild_positions(&mut self) {
        self.pos_of = vec![0; self.n_slices];
        for (v, &a) in self.placement.iter().enumerate() {
            self.pos_of[a] = v;
        }
    }

    /// Install a ring placement (e.g. from [`skew_aware_placement`]).
    /// Must be a permutation of the slice ids, set before the first round
    /// — re-ordering a ring with slices already in flight would fork the
    /// handoff chains.  For the mid-run (crash-recovery) form see
    /// [`RotationScheduler::re_place`].
    pub fn set_placement(&mut self, placement: Vec<usize>) {
        assert_eq!(self.counter, 0, "placement must be set before round 0");
        assert_eq!(placement.len(), self.n_slices);
        Self::check_permutation(&placement, self.n_slices);
        self.placement = placement;
        if self.debt.is_some() {
            self.rebuild_positions();
        }
    }

    fn check_permutation(placement: &[usize], u: usize) {
        let mut seen = vec![false; u];
        for &s in placement {
            assert!(s < u && !seen[s], "placement not a permutation");
            seen[s] = true;
        }
    }

    /// Mid-run re-placement for crash recovery: install `current`, the
    /// slice that sits at each virtual ring position **starting this
    /// round** (so [`RotationScheduler::slice_at`]`(v) == current[v]`
    /// until the counter next advances).  Unlike
    /// [`RotationScheduler::set_placement`] this is legal at any *drained*
    /// round boundary — no leases in flight, every chain settled — which
    /// is exactly when the engine runs recovery; calling it with rounds
    /// still in flight would fork the handoff chains.  Under
    /// [`SkipPolicy::Defer`] the per-slice positions are rebuilt from
    /// `current`, folding any frozen (deferred) positions into the new
    /// ring: the one-time coverage delay this adds is bounded by U rounds
    /// and is accounted as recovery cost, on top of the usual
    /// `U + debt_limit` horizon.
    pub fn re_place(&mut self, current: Vec<usize>) {
        assert_eq!(current.len(), self.n_slices);
        Self::check_permutation(&current, self.n_slices);
        let u = self.n_slices;
        let c = self.counter as usize;
        let mut placement = vec![usize::MAX; u];
        for (v, &a) in current.iter().enumerate() {
            placement[(v + c) % u] = a;
        }
        self.placement = placement;
        if self.debt.is_some() {
            for (v, &a) in current.iter().enumerate() {
                self.pos_of[a] = v;
            }
        }
    }

    /// Mark one worker dead (`false`) or live again (`true`).  Grants
    /// re-route immediately: a dead worker's ring positions fall to the
    /// next live worker ([`live_owner`]) and return when it rejoins.
    /// Legal at any round boundary; at least one worker must stay live.
    pub fn set_alive(&mut self, worker: usize, alive: bool) {
        self.alive[worker] = alive;
        assert!(
            self.alive.iter().any(|&b| b),
            "no live workers left on the ring"
        );
    }

    /// Current membership mask (`alive[w]` = worker accepts grants).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Number of live workers.
    pub fn n_live(&self) -> usize {
        self.alive.iter().filter(|&&b| b).count()
    }

    /// The live worker that services virtual ring position `v` this round
    /// (membership-aware [`position_owner`]).
    pub fn owner_of(&self, v: usize) -> usize {
        live_owner(&self.alive, v)
    }

    /// Restore the rotation counter from a checkpoint (resume support).
    /// [`SkipPolicy::Never`] only: `Defer` carries per-slice position
    /// state a bare counter cannot reconstruct.
    pub fn set_round(&mut self, counter: u64) {
        assert!(
            self.debt.is_none(),
            "checkpoint resume requires SkipPolicy::Never"
        );
        self.counter = counter;
    }

    /// Slice at virtual ring position `v` this round.
    pub fn slice_at(&self, v: usize) -> usize {
        self.placement[(v + self.counter as usize) % self.n_slices]
    }

    /// First slice of `worker`'s queue this round (its only slice when
    /// U = P, where this matches the paper's `(a + C) % U`).
    pub fn slice_for(&self, worker: usize) -> usize {
        self.slice_at(worker)
    }

    /// This round's slice queue per worker (position order `p, p+P, …`),
    /// without advancing the counter.  Queues are disjoint and jointly
    /// cover all U slices.
    pub fn queues(&self) -> Vec<Vec<usize>> {
        (0..self.n_workers)
            .map(|p| {
                (p..self.n_slices)
                    .step_by(self.n_workers)
                    .map(|v| self.slice_at(v))
                    .collect()
            })
            .collect()
    }

    /// Assignments for all workers this round (single-slice U = P form),
    /// then advance the counter.
    pub fn next_round(&mut self) -> Vec<usize> {
        assert_eq!(
            self.n_slices, self.n_workers,
            "next_round is the U = P form; use next_round_queues"
        );
        self.next_round_queues()
            .into_iter()
            .map(|q| q[0])
            .collect()
    }

    /// Slice queues for all workers this round, then advance the counter.
    pub fn next_round_queues(&mut self) -> Vec<Vec<usize>> {
        let out = self.queues();
        self.counter += 1;
        out
    }

    /// This round's grants — one [`GrantLeg`] queue per worker, in sweep
    /// (position) order — then advance the counter.  `available(a)`
    /// answers whether slice `a`'s handoff has already landed (the data
    /// plane's [`crate::kvstore::SliceRouter::parked_version`] poll; BSP
    /// callers answer `true`).
    ///
    /// Under [`SkipPolicy::Never`] the signal is ignored and the grants
    /// are exactly [`RotationScheduler::next_round_queues`] with each
    /// leg's ring destination — the PR-4 stream, bit-exact.  Under
    /// [`SkipPolicy::Defer`] an unavailable slice with remaining
    /// [`CoverageDebtLedger`] budget is skipped — no lease granted, its
    /// ring position frozen — and granted in a later round to whichever
    /// worker its (then-advanced) position maps to; an over-budget slice
    /// is force-granted so it can never starve.  Granted or skipped, every
    /// slice is accounted every round: grants stay disjoint, and full
    /// coverage holds within `U + debt_limit` rounds (see
    /// [`crate::scheduler::debt`]).
    pub fn next_round_grants(
        &mut self,
        mut available: impl FnMut(usize) -> bool,
    ) -> Vec<Vec<GrantLeg>> {
        let u = self.n_slices;
        let p = self.n_workers;
        match self.skip {
            SkipPolicy::Never => {
                // walk positions in ring order so each live worker's queue
                // is position-sorted (identical to the PR-4 queue stream
                // when every worker is alive); a dead worker's positions
                // land on the next live worker, interleaved by position
                let mut grants: Vec<Vec<GrantLeg>> = vec![Vec::new(); p];
                for v in 0..u {
                    grants[self.owner_of(v)].push(GrantLeg {
                        slice_id: self.slice_at(v),
                        dest_worker: self.next_holder(v),
                    });
                }
                self.counter += 1;
                grants
            }
            SkipPolicy::Defer { .. } => {
                let round = self.counter;
                let trace = self.trace.clone();
                let replay = self.replay.clone();
                let debt = self.debt.as_mut().expect("Defer mode has a ledger");
                // (position, slice) per worker; sorted below so a queue's
                // sweep order is position order, exactly like Never mode
                let mut grants: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p];
                for a in 0..u {
                    let v = self.pos_of[a];
                    // under replay the recorded skip set *is* the
                    // availability signal: the debt ledger then evolves
                    // identically to the recorded run's
                    let avail = match &replay {
                        Some(rep) => !rep.skipped(round, a),
                        None => available(a),
                    };
                    if !avail && debt.may_defer(a) {
                        debt.record_skip(a, round);
                        if let Some(sink) = &trace {
                            sink.push(Event::Skip {
                                round,
                                slice: a,
                                debt: debt.debt(a),
                            });
                        }
                        continue; // position frozen: leased next round
                    }
                    debt.record_grant(a);
                    grants[live_owner(&self.alive, v)].push((v, a));
                    self.pos_of[a] = ring_successor(v, u);
                }
                self.counter += 1;
                grants
                    .into_iter()
                    .map(|mut q| {
                        q.sort_unstable();
                        q.into_iter()
                            .map(|(v, slice_id)| GrantLeg {
                                slice_id,
                                dest_worker: live_owner(
                                    &self.alive,
                                    ring_successor(v, u),
                                ),
                            })
                            .collect()
                    })
                    .collect()
            }
        }
    }

    pub fn round(&self) -> u64 {
        self.counter
    }

    pub fn n_slices(&self) -> usize {
        self.n_slices
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// The **live** worker holding the slice at position `v` *next* round
    /// — where a pipelined rotation forwards that slice (see
    /// [`ring_successor`]; membership-aware, so a handoff never targets a
    /// dead worker).
    pub fn next_holder(&self, v: usize) -> usize {
        live_owner(&self.alive, ring_successor(v, self.n_slices))
    }

    /// U = P form: the worker that holds `worker`'s current slice next
    /// round (see [`ring_successor`]).
    pub fn handoff_successor(&self, worker: usize) -> usize {
        ring_successor(worker, self.n_slices)
    }

    /// U = P form: the worker whose previous-round slice `worker` receives
    /// this round — the inverse of
    /// [`RotationScheduler::handoff_successor`] (see [`ring_source`]).
    pub fn handoff_source(&self, worker: usize) -> usize {
        ring_source(worker, self.n_slices)
    }

    /// Partition vocabulary ids [0, v) into `u` slices by striding the
    /// **id** space (`w % u`).  This balances word *counts* only — it is
    /// frequency-blind, so a corpus whose heavy words cluster in id space
    /// (e.g. the topic-banded generator in `datagen::lda_corpus`) can
    /// still overload one slice.  Use
    /// [`RotationScheduler::partition_words_by_freq`] when corpus
    /// frequencies are known.
    pub fn partition_words(v: usize, u: usize) -> Vec<usize> {
        (0..v).map(|w| w % u).collect()
    }

    /// Frequency-weighted split: words are ranked by corpus frequency and
    /// greedily assigned, heaviest first, to the currently lightest slice
    /// (ties broken toward the slice with fewer words), so Zipf-heavy
    /// heads spread across slices instead of piling into one.  This is the
    /// paper's frequency-aware load balance for rotation rounds: per-round
    /// compute is proportional to a slice's *token mass*, not its word
    /// count.  Returns the slice id per word.
    pub fn partition_words_by_freq(freqs: &[u64], u: usize) -> Vec<usize> {
        assert!(u > 0);
        let mut order: Vec<usize> = (0..freqs.len()).collect();
        order.sort_by(|&a, &b| freqs[b].cmp(&freqs[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; u];
        let mut count = vec![0usize; u];
        let mut out = vec![0usize; freqs.len()];
        for w in order {
            let mut best = 0usize;
            for a in 1..u {
                if (load[a], count[a]) < (load[best], count[best]) {
                    best = a;
                }
            }
            out[w] = best;
            load[best] += freqs[w];
            count[best] += 1;
        }
        out
    }

    /// Partition words into `targets.len()` slices whose token masses
    /// approximate the given (relative) target shares — the controlled
    /// *skewed* split the dynamic-order experiments need (a Zipf mass
    /// profile across slices), where
    /// [`RotationScheduler::partition_words_by_freq`] deliberately
    /// flattens the masses.  Greedy, heaviest word first: each word goes
    /// to the slice with the smallest resulting `load / target` ratio
    /// (ties toward the lower slice id), so realized masses track the
    /// targets as closely as the word granularity allows.  A final pass
    /// hands one word to any slice the greedy left empty (stolen from the
    /// most word-rich slice), so every slice is materializable.  Returns
    /// the slice id per word.
    pub fn partition_words_to_targets(
        freqs: &[u64],
        targets: &[f64],
    ) -> Vec<usize> {
        let u = targets.len();
        assert!(u > 0 && freqs.len() >= u, "fewer words than slices");
        assert!(
            targets.iter().all(|&t| t > 0.0 && t.is_finite()),
            "targets must be positive and finite"
        );
        let mut order: Vec<usize> = (0..freqs.len()).collect();
        order.sort_by(|&a, &b| freqs[b].cmp(&freqs[a]).then(a.cmp(&b)));
        let mut load = vec![0.0f64; u];
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); u];
        let mut out = vec![0usize; freqs.len()];
        for w in order {
            let f = freqs[w] as f64;
            let mut best = 0usize;
            let mut best_ratio = f64::INFINITY;
            for a in 0..u {
                let ratio = (load[a] + f) / targets[a];
                if ratio < best_ratio {
                    best_ratio = ratio;
                    best = a;
                }
            }
            out[w] = best;
            load[best] += f;
            members[best].push(w);
        }
        // no slice may end up wordless: steal from the most populous
        for a in 0..u {
            if members[a].is_empty() {
                let donor = (0..u)
                    .max_by_key(|&d| members[d].len())
                    .expect("u > 0");
                assert!(
                    members[donor].len() > 1,
                    "cannot populate slice {a}: no donor has spare words"
                );
                let w = members[donor].pop().expect("donor non-empty");
                members[a].push(w);
                out[w] = a;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check, Prop};

    #[test]
    fn each_round_is_a_permutation() {
        let mut s = RotationScheduler::new(8);
        for _ in 0..20 {
            let mut assign = s.next_round();
            assign.sort_unstable();
            assert_eq!(assign, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_worker_sees_every_slice_in_u_rounds() {
        let u = 6;
        let mut s = RotationScheduler::new(u);
        let mut seen = vec![vec![false; u]; u];
        for _ in 0..u {
            for (w, slice) in s.next_round().into_iter().enumerate() {
                seen[w][slice] = true;
            }
        }
        assert!(seen.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn matches_paper_formula() {
        // paper: idx = ((a + C - 1) mod U) + 1 with 1-indexed a, C
        let mut s = RotationScheduler::new(4);
        s.next_round(); // C becomes 1
        // our round C=1: worker a0 -> slice 1
        assert_eq!(s.slice_for(0), 1);
        assert_eq!(s.slice_for(3), 0);
    }

    #[test]
    fn handoff_order_matches_the_rotation() {
        // forwarding every slice to its successor must reproduce the next
        // round's assignment exactly
        let u = 7;
        let mut s = RotationScheduler::new(u);
        for _ in 0..2 * u {
            let now = s.next_round();
            let next = (0..u).map(|w| s.slice_for(w)).collect::<Vec<_>>();
            for (w, &slice) in now.iter().enumerate() {
                let succ = s.handoff_successor(w);
                assert_eq!(next[succ], slice, "worker {w} -> {succ}");
                assert_eq!(s.handoff_source(succ), w);
            }
        }
    }

    #[test]
    fn multislice_queues_match_next_holder() {
        // U = 2P ring: the slice at position v this round must be in the
        // queue of next_holder(v)'s worker next round.
        let (u, p) = (8, 4);
        let mut s = RotationScheduler::with_workers(u, p);
        for _ in 0..3 * u {
            let dest: Vec<usize> = (0..u).map(|v| s.next_holder(v)).collect();
            let now = s.next_round_queues();
            let next = s.queues();
            for w in 0..p {
                for (j, &slice) in now[w].iter().enumerate() {
                    let v = w + j * p;
                    assert!(
                        next[dest[v]].contains(&slice),
                        "slice {slice} at pos {v} must move to worker {}",
                        dest[v]
                    );
                }
            }
        }
    }

    #[test]
    fn u_equals_p_queues_reproduce_the_single_slice_schedule() {
        // the generalized queue path with U = P must emit exactly the
        // paper's `(a + C) % U` assignment, one slice per worker — the
        // schedule-level half of the "U = P is bit-identical to the
        // single-slice rotation" regression (the app-level half lives in
        // tests/rotation_handoff.rs).
        let u = 5;
        let mut s = RotationScheduler::with_workers(u, u);
        for c in 0..3 * u as u64 {
            for (w, q) in s.next_round_queues().into_iter().enumerate() {
                assert_eq!(q, vec![(w + c as usize) % u]);
            }
        }
    }

    #[test]
    fn freq_partition_balances_token_mass_on_a_zipf_corpus() {
        use crate::datagen::lda_corpus::{self, CorpusConfig};
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 400,
            vocab: 1200,
            n_topics: 6,
            ..Default::default()
        });
        let mut freqs = vec![0u64; corpus.vocab];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let u = 8;
        let mass = |part: &[usize]| {
            let mut m = vec![0u64; u];
            for (w, &a) in part.iter().enumerate() {
                m[a] += freqs[w];
            }
            m
        };
        let by_freq = mass(&RotationScheduler::partition_words_by_freq(&freqs, u));
        let (mn, mx) = (
            *by_freq.iter().min().unwrap() as f64,
            *by_freq.iter().max().unwrap() as f64,
        );
        assert!(
            mx <= 1.1 * mn,
            "freq-aware split imbalanced: {by_freq:?}"
        );
        // ...and it must not do worse than the frequency-blind id stride
        let by_id = mass(&RotationScheduler::partition_words(corpus.vocab, u));
        let (id_mn, id_mx) = (
            *by_id.iter().min().unwrap() as f64,
            *by_id.iter().max().unwrap() as f64,
        );
        assert!(mx / mn <= id_mx / id_mn.max(1.0) + 1e-9);
    }

    #[test]
    fn freq_partition_spreads_zero_freq_words_too() {
        // all-zero frequencies degenerate to a word-count round-robin
        let part = RotationScheduler::partition_words_by_freq(&[0; 10], 3);
        let mut counts = [0usize; 3];
        for &a in &part {
            counts[a] += 1;
        }
        let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn word_partition_is_balanced() {
        let part = RotationScheduler::partition_words(103, 4);
        let mut counts = [0usize; 4];
        for &s in &part {
            counts[s] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn prop_rotation_disjoint_every_round() {
        prop_check("rotation disjointness", 100, |g| {
            let u = g.usize_in(1, 64);
            let rounds = g.usize_in(1, 20);
            let mut s = RotationScheduler::new(u);
            for _ in 0..rounds {
                let mut a = s.next_round();
                a.sort_unstable();
                a.dedup();
                if a.len() != u {
                    return Prop::Fail(format!("collision with u={u}"));
                }
            }
            Prop::Ok
        });
    }

    #[test]
    fn prop_full_coverage_after_u_rounds() {
        prop_check("rotation coverage", 50, |g| {
            let u = g.usize_in(1, 32);
            let mut s = RotationScheduler::new(u);
            let mut cover = vec![0usize; u];
            for _ in 0..u {
                cover[s.slice_for(g.usize_in(0, u - 1))] += 0; // no-op read
                for (w, slice) in s.next_round().into_iter().enumerate() {
                    if w == 0 {
                        cover[slice] += 1;
                    }
                }
            }
            ensure(
                cover.iter().all(|&c| c == 1),
                format!("worker 0 coverage {cover:?}"),
            )
        });
    }

    #[test]
    fn prop_multislice_rounds_disjoint_and_cover() {
        // random U ≥ P rings (random placements too): every round's queues
        // are disjoint and jointly cover all U slices, queue sizes differ
        // by at most one, and every worker sees every slice within U
        // rounds.
        prop_check("multi-slice rotation", 60, |g| {
            let p = g.usize_in(1, 8);
            let u = p * g.usize_in(1, 4) + g.usize_in(0, p - 1);
            let mut s = RotationScheduler::with_workers(u, p);
            // random permutation placement via sort-by-random-key
            let mut keyed: Vec<(u64, usize)> =
                (0..u).map(|a| (g.seed(), a)).collect();
            keyed.sort_unstable();
            s.set_placement(keyed.into_iter().map(|(_, a)| a).collect());
            let mut seen = vec![vec![false; u]; p];
            for _ in 0..u {
                let queues = s.next_round_queues();
                let mut all: Vec<usize> =
                    queues.iter().flatten().copied().collect();
                all.sort_unstable();
                if all != (0..u).collect::<Vec<_>>() {
                    return Prop::Fail(format!(
                        "round not a partition of slices (u={u}, p={p})"
                    ));
                }
                let (qmin, qmax) = (
                    queues.iter().map(|q| q.len()).min().unwrap(),
                    queues.iter().map(|q| q.len()).max().unwrap(),
                );
                if qmax - qmin > 1 {
                    return Prop::Fail(format!(
                        "queue sizes unbalanced: {qmin}..{qmax}"
                    ));
                }
                for (w, q) in queues.iter().enumerate() {
                    for &a in q {
                        seen[w][a] = true;
                    }
                }
            }
            ensure(
                seen.iter().all(|row| row.iter().all(|&b| b)),
                format!("coverage hole after {u} rounds (p={p})"),
            )
        });
    }

    #[test]
    fn prop_skew_placement_is_permutation() {
        prop_check("skew-aware placement", 80, |g| {
            let p = g.usize_in(1, 6);
            let u = p * g.usize_in(1, 5);
            let masses: Vec<u64> =
                (0..u).map(|_| g.usize_in(0, 10_000) as u64).collect();
            let speeds: Vec<f64> = (0..p).map(|_| g.f64_in(0.1, 8.0)).collect();
            let placement = skew_aware_placement(&masses, &speeds);
            let mut sorted = placement.clone();
            sorted.sort_unstable();
            ensure(
                sorted == (0..u).collect::<Vec<_>>(),
                format!("not a permutation: {placement:?}"),
            )
        });
    }

    #[test]
    fn skew_placement_balances_cohorts_and_favors_fast_workers() {
        // 4 slices, 2 workers, worker 1 twice as fast: the heaviest slice
        // must start on worker 1's residue, and cohort time loads
        // (mass / speed) must be no worse than the heaviest single slice.
        let masses = vec![100u64, 10, 60, 50];
        let speeds = vec![1.0, 2.0];
        let placement = skew_aware_placement(&masses, &speeds);
        // cohort of worker w = positions {w, w+2}
        let cohort = |w: usize| vec![placement[w], placement[w + 2]];
        let mass =
            |c: &[usize]| c.iter().map(|&a| masses[a]).sum::<u64>() as f64;
        let (c0, c1) = (cohort(0), cohort(1));
        // heaviest slice (id 0) lands on the fast worker's cohort
        assert!(c1.contains(&0), "heavy slice on slow worker: {placement:?}");
        // time loads balanced within the heaviest slice's time
        let (t0, t1) = (mass(&c0) / 1.0, mass(&c1) / 2.0);
        assert!(
            (t0 - t1).abs() <= 100.0,
            "time imbalance {t0} vs {t1}: {placement:?}"
        );
    }

    #[test]
    fn skew_placement_handles_uneven_slice_counts() {
        // U = 5, P = 2: residue 0 owns 3 positions, residue 1 owns 2
        let masses = vec![5u64, 4, 3, 2, 1];
        let speeds = vec![1.0, 1.0];
        let placement = skew_aware_placement(&masses, &speeds);
        let mut sorted = placement.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn bad_placement_panics() {
        let mut s = RotationScheduler::with_workers(4, 2);
        s.set_placement(vec![0, 1, 2, 2]);
    }

    #[test]
    fn never_grants_match_the_queue_stream_with_ring_dests() {
        // next_round_grants under SkipPolicy::Never must be exactly the
        // PR-4 queue stream with each leg's next_holder destination —
        // the formula apps used before the grant API existed.
        let (u, p) = (10usize, 4usize);
        let mut a = RotationScheduler::with_workers(u, p);
        let mut b = RotationScheduler::with_workers(u, p);
        for _ in 0..2 * u {
            let grants = a.next_round_grants(|_| false); // signal ignored
            let queues = b.next_round_queues();
            for (w, (gq, qq)) in grants.iter().zip(queues.iter()).enumerate() {
                let slices: Vec<usize> =
                    gq.iter().map(|l| l.slice_id).collect();
                assert_eq!(&slices, qq, "worker {w}");
                for (j, leg) in gq.iter().enumerate() {
                    assert_eq!(leg.dest_worker, b.next_holder(w + j * p));
                }
            }
        }
    }

    #[test]
    fn defer_zero_budget_matches_never_exactly() {
        // debt_limit = 0 refuses every deferral: the grant stream must be
        // identical to Never's under any availability signal.
        let (u, p) = (9usize, 4usize);
        let mut never = RotationScheduler::with_workers(u, p);
        let mut defer = RotationScheduler::with_workers(u, p);
        defer.set_skip_policy(SkipPolicy::Defer { debt_limit: 0 });
        let mut x = 7u64;
        for _ in 0..2 * u {
            let n = never.next_round_grants(|_| true);
            let d = defer.next_round_grants(|a| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(a as u64);
                x & 1 == 0
            });
            assert_eq!(n, d);
        }
        assert_eq!(defer.coverage_debt().unwrap().total_deferrals(), 0);
    }

    #[test]
    fn defer_skips_then_regrants_to_the_frozen_position_owner() {
        // U = P = 2, identity placement.  Round 0: slice 1 (position 1,
        // worker 1) is unavailable and gets deferred; slice 0 is granted
        // to worker 0 and advances.  Round 1: slice 1 is still at
        // position 1 — granted to worker 1 — while slice 0 has moved to
        // position 1... both now compete; disjointness must hold and the
        // deferred slice lands on its frozen position's owner.
        let mut s = RotationScheduler::with_workers(2, 2);
        s.set_skip_policy(SkipPolicy::Defer { debt_limit: 1 });
        let r0 = s.next_round_grants(|a| a != 1);
        assert_eq!(r0[0], vec![GrantLeg { slice_id: 0, dest_worker: 1 }]);
        assert!(r0[1].is_empty(), "slice 1 deferred: worker 1 idles");
        assert_eq!(s.coverage_debt().unwrap().debt(1), 1);
        // round 1, everything available: slice 0 now at position 1,
        // slice 1 still at position 1 — worker 1 sweeps both (position
        // ties broken by slice id), worker 0 none
        let r1 = s.next_round_grants(|_| true);
        assert!(r1[0].is_empty());
        assert_eq!(
            r1[1],
            vec![
                GrantLeg { slice_id: 0, dest_worker: 0 },
                GrantLeg { slice_id: 1, dest_worker: 0 },
            ]
        );
        // budget exhausted for slice 1: a further outage force-grants it
        let r2 = s.next_round_grants(|a| a != 1);
        let granted: Vec<usize> = r2
            .iter()
            .flatten()
            .map(|l| l.slice_id)
            .collect();
        assert!(granted.contains(&1), "over-budget slice must be granted");
    }

    #[test]
    fn defer_grants_stay_disjoint_and_cover_within_horizon() {
        // random availability outages: every round's grants are disjoint,
        // granted + deferred account for every slice, and every worker
        // holds every slice within U + debt_limit rounds.
        prop_check("defer coverage horizon", 60, |g| {
            let p = g.usize_in(1, 5);
            let u = p * g.usize_in(1, 3) + g.usize_in(0, p - 1);
            let debt_limit = g.usize_in(0, 3) as u64;
            let mut s = RotationScheduler::with_workers(u, p);
            s.set_skip_policy(SkipPolicy::Defer { debt_limit });
            let mut seen = vec![vec![false; u]; p];
            let rounds = u as u64 + debt_limit;
            for _ in 0..rounds {
                let avail: Vec<bool> =
                    (0..u).map(|_| g.bool_with(0.7)).collect();
                let grants = s.next_round_grants(|a| avail[a]);
                let mut granted: Vec<usize> = grants
                    .iter()
                    .flatten()
                    .map(|l| l.slice_id)
                    .collect();
                granted.sort_unstable();
                let n_granted = granted.len();
                granted.dedup();
                if granted.len() != n_granted {
                    return Prop::Fail(format!(
                        "slice granted twice in one round (u={u}, p={p})"
                    ));
                }
                for (w, q) in grants.iter().enumerate() {
                    for leg in q {
                        if leg.dest_worker >= p {
                            return Prop::Fail(format!(
                                "dest {} out of range",
                                leg.dest_worker
                            ));
                        }
                        seen[w][leg.slice_id] = true;
                    }
                }
            }
            let debt = s.coverage_debt().unwrap();
            if debt.max_debt() > debt_limit {
                return Prop::Fail(format!(
                    "debt {} over limit {debt_limit}",
                    debt.max_debt()
                ));
            }
            ensure(
                seen.iter().all(|row| row.iter().all(|&b| b)),
                format!(
                    "coverage hole after U + debt_limit = {rounds} rounds \
                     (u={u}, p={p}, debt_limit={debt_limit})"
                ),
            )
        });
    }

    #[test]
    fn target_partition_tracks_a_zipf_profile() {
        use crate::datagen::lda_corpus::{self, CorpusConfig};
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 400,
            vocab: 1200,
            n_topics: 6,
            ..Default::default()
        });
        let mut freqs = vec![0u64; corpus.vocab];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let u = 8;
        let targets: Vec<f64> =
            (0..u).map(|a| 1.0 / (a + 1) as f64).collect();
        let part =
            RotationScheduler::partition_words_to_targets(&freqs, &targets);
        let mut mass = vec![0u64; u];
        for (w, &a) in part.iter().enumerate() {
            mass[a] += freqs[w];
        }
        let total: u64 = mass.iter().sum();
        let tsum: f64 = targets.iter().sum();
        for a in 0..u {
            let want = targets[a] / tsum;
            let got = mass[a] as f64 / total as f64;
            assert!(
                (got - want).abs() < 0.25 * want + 0.01,
                "slice {a}: share {got:.4} vs target {want:.4} ({mass:?})"
            );
        }
        // the realized profile is genuinely skewed: head ≥ 2× tail
        assert!(mass[0] as f64 >= 2.0 * mass[u - 1] as f64, "{mass:?}");
    }

    #[test]
    fn target_partition_populates_every_slice() {
        // one giant word plus tiny ones: the greedy must still hand every
        // slice at least one word
        let mut freqs = vec![1u64; 6];
        freqs[0] = 1_000_000;
        let part = RotationScheduler::partition_words_to_targets(
            &freqs,
            &[10.0, 1.0, 1.0],
        );
        let mut count = [0usize; 3];
        for &a in &part {
            count[a] += 1;
        }
        assert!(count.iter().all(|&c| c >= 1), "{count:?}");
    }

    #[test]
    fn dead_workers_positions_fall_to_the_next_live_worker() {
        // U = 6, P = 3: kill worker 1.  Every round must still grant all
        // six slices, worker 1's queue must be empty, worker 2 (the next
        // live residue) must carry the double queue, and no grant or
        // handoff destination may name the dead worker.
        let (u, p) = (6usize, 3usize);
        let mut s = RotationScheduler::with_workers(u, p);
        s.set_alive(1, false);
        assert_eq!(s.n_live(), 2);
        assert_eq!(s.alive(), &[true, false, true]);
        assert_eq!(s.owner_of(1), 2, "residue 1 falls to worker 2");
        assert_eq!(s.owner_of(4), 2);
        for _ in 0..2 * u {
            let grants = s.next_round_grants(|_| true);
            assert!(grants[1].is_empty(), "dead worker must idle");
            assert_eq!(grants[0].len(), 2);
            assert_eq!(grants[2].len(), 4, "neighbor carries the double queue");
            let mut all: Vec<usize> = grants
                .iter()
                .flatten()
                .map(|l| l.slice_id)
                .collect();
            all.sort_unstable();
            assert_eq!(all, (0..u).collect::<Vec<_>>(), "coverage survives");
            assert!(
                grants.iter().flatten().all(|l| l.dest_worker != 1),
                "no handoff may target the dead worker"
            );
        }
        // rejoin: the ring heals to the all-alive stream
        s.set_alive(1, true);
        let healed = s.next_round_grants(|_| true);
        assert_eq!(healed.iter().map(|q| q.len()).collect::<Vec<_>>(), [2, 2, 2]);
    }

    #[test]
    fn membership_with_all_alive_matches_the_position_owner_stream() {
        // the live-owner generalization must be invisible when nobody died
        let (u, p) = (10usize, 4usize);
        let mut a = RotationScheduler::with_workers(u, p);
        let mut b = RotationScheduler::with_workers(u, p);
        b.set_alive(0, false);
        b.set_alive(0, true); // toggling through dead-and-back is identity
        for _ in 0..2 * u {
            assert_eq!(
                a.next_round_grants(|_| true),
                b.next_round_grants(|_| true)
            );
        }
        for v in 0..u {
            assert_eq!(a.owner_of(v), position_owner(v, p));
        }
    }

    #[test]
    #[should_panic(expected = "no live workers")]
    fn killing_the_last_worker_panics() {
        let mut s = RotationScheduler::with_workers(2, 2);
        s.set_alive(0, false);
        s.set_alive(1, false);
    }

    #[test]
    fn re_place_installs_the_current_view_mid_run() {
        let (u, p) = (4usize, 2usize);
        let mut s = RotationScheduler::with_workers(u, p);
        for _ in 0..3 {
            s.next_round_grants(|_| true);
        }
        // install "slice 3 now sits at position 0, 2 at 1, ..." mid-run
        let current = vec![3usize, 2, 1, 0];
        s.re_place(current.clone());
        for (v, &a) in current.iter().enumerate() {
            assert_eq!(s.slice_at(v), a, "position {v}");
        }
        // the ring keeps rotating from the new view
        let before: Vec<usize> = (0..u).map(|v| s.slice_at(v)).collect();
        s.next_round_grants(|_| true);
        for v in 0..u {
            assert_eq!(s.slice_at(v), before[(v + 1) % u]);
        }
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn re_place_rejects_non_permutations() {
        let mut s = RotationScheduler::with_workers(4, 2);
        s.next_round_grants(|_| true);
        s.re_place(vec![0, 1, 2, 2]);
    }

    #[test]
    fn defer_grants_avoid_dead_workers_too() {
        // Defer mode with an outage and a dead worker: grants stay
        // disjoint, cover granted+deferred, and never name worker 0
        let (u, p) = (6usize, 3usize);
        let mut s = RotationScheduler::with_workers(u, p);
        s.set_skip_policy(SkipPolicy::Defer { debt_limit: 2 });
        s.set_alive(0, false);
        for r in 0..3 * u as u64 {
            let grants = s.next_round_grants(|a| a % 3 != (r % 3) as usize);
            assert!(grants[0].is_empty(), "dead worker must idle");
            assert!(
                grants.iter().flatten().all(|l| l.dest_worker != 0),
                "no handoff may target the dead worker"
            );
            let mut granted: Vec<usize> =
                grants.iter().flatten().map(|l| l.slice_id).collect();
            let n = granted.len();
            granted.sort_unstable();
            granted.dedup();
            assert_eq!(granted.len(), n, "grants must stay disjoint");
        }
    }

    #[test]
    fn queue_order_knob_does_not_perturb_the_queues() {
        // Availability reorders the *service* of a queue, never its
        // contents: the emitted queue stream must be identical to Strict's
        // (which itself is the PR-3 / paper stream, locked by
        // u_equals_p_queues_reproduce_the_single_slice_schedule above).
        let (u, p) = (10, 4);
        let mut strict = RotationScheduler::with_workers(u, p);
        let mut avail = RotationScheduler::with_workers(u, p);
        avail.set_queue_order(QueueOrder::Availability);
        assert_eq!(avail.queue_order(), QueueOrder::Availability);
        assert_eq!(strict.queue_order(), QueueOrder::Strict);
        for _ in 0..3 * u {
            assert_eq!(strict.next_round_queues(), avail.next_round_queues());
        }
    }
}
