//! Dynamic priority scheduling for Lasso (paper §3.3).
//!
//! Maintains the sampling distribution  c_j ∝ |β_j^(t-1) − β_j^(t-2)| + η
//! over coefficients, draws U′ candidates from it, then dependency-filters
//! them down to at most U concurrently-safe coefficients.  The two
//! ingredients — *prioritization* (focus on fast-moving coefficients) and
//! *dependency avoidance* — are independently toggleable for the ablation
//! benches.

use super::dependency::DependencyChecker;
use crate::sparse::CscMatrix;
use crate::util::{FenwickTree, Rng};

/// Configuration for the dynamic Lasso scheduler.
#[derive(Debug, Clone)]
pub struct PriorityConfig {
    /// Concurrent update set size U (= number of workers in the paper).
    pub u: usize,
    /// Candidate pool size U′ ≥ U.
    pub u_prime: usize,
    /// Dependency threshold ρ ∈ (0, 1].
    pub rho: f32,
    /// Exploration constant η > 0.
    pub eta: f64,
    /// Ablation toggles.
    pub use_priority: bool,
    pub use_dependency_filter: bool,
}

impl PriorityConfig {
    pub fn paper_defaults(u: usize) -> Self {
        PriorityConfig {
            u,
            u_prime: u * 4,
            rho: 0.1,
            eta: 1e-6,
            use_priority: true,
            use_dependency_filter: true,
        }
    }
}

/// Stateful dynamic scheduler.
///
/// Priority weights live in a [`FenwickTree`]: the c distribution changes
/// every pull, and the tree gives O(log J) draws + updates instead of the
/// O(J) inverse-CDF scan (the coordinator's former top hot spot — see
/// EXPERIMENTS.md §Perf).
pub struct PriorityScheduler {
    cfg: PriorityConfig,
    /// Priority weights c_j (unnormalized) in a sampling tree.
    weights: FenwickTree,
    rng: Rng,
    /// Cumulative scheduler-side work (candidate draws + filter checks).
    filter_checks: u64,
}

impl PriorityScheduler {
    pub fn new(n_features: usize, cfg: PriorityConfig, seed: u64) -> Self {
        assert!(cfg.u >= 1 && cfg.u_prime >= cfg.u);
        // start uniform: every coefficient equally likely before we have
        // any delta history
        PriorityScheduler {
            weights: FenwickTree::new(&vec![1.0; n_features]),
            cfg,
            rng: Rng::new(seed),
            filter_checks: 0,
        }
    }

    pub fn config(&self) -> &PriorityConfig {
        &self.cfg
    }

    /// Update priorities after a pull: c_j gets |δβ_j| + η.
    pub fn update_priority(&mut self, j: usize, delta_abs: f64) {
        self.weights.set(j, delta_abs + self.cfg.eta);
    }

    /// Draw the next concurrent update set B (paper: sample U′ from c,
    /// filter to U with pairwise correlation < ρ).
    pub fn next_set(&mut self, x: &CscMatrix) -> Vec<usize> {
        let candidates = if self.cfg.use_priority {
            self.sample_candidates()
        } else {
            self.rng.sample_indices(self.weights.len(), self.cfg.u_prime)
        };
        if !self.cfg.use_dependency_filter {
            let mut out = candidates;
            out.truncate(self.cfg.u);
            return out;
        }
        let mut checker = DependencyChecker::new(x, self.cfg.rho);
        let kept = checker.filter(&candidates, self.cfg.u);
        self.filter_checks += checker.checks();
        kept
    }

    /// Weighted sampling of U′ distinct candidates from c: draw without
    /// replacement by zeroing drawn weights in the tree, then restore.
    /// O(U′ log J) total.
    fn sample_candidates(&mut self) -> Vec<usize> {
        let n = self.weights.len();
        let want = self.cfg.u_prime.min(n);
        let mut out = Vec::with_capacity(want);
        let mut saved: Vec<(usize, f64)> = Vec::with_capacity(want);
        while out.len() < want {
            let total = self.weights.total();
            if total <= 0.0 {
                // degenerate: fill uniformly from undrawn indices
                let j = self.rng.below(n);
                if !saved.iter().any(|&(i, _)| i == j) {
                    saved.push((j, self.weights.get(j)));
                    self.weights.set(j, 0.0);
                    out.push(j);
                }
                continue;
            }
            let j = self.weights.sample(self.rng.next_f64() * total);
            saved.push((j, self.weights.get(j)));
            self.weights.set(j, 0.0); // without replacement
            out.push(j);
        }
        for (j, w) in saved {
            self.weights.set(j, w);
        }
        out
    }

    pub fn filter_checks(&self) -> u64 {
        self.filter_checks
    }

    /// Current weight of coefficient j (tests/diagnostics).
    pub fn weight(&self, j: usize) -> f64 {
        self.weights.get(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{prop_check, Prop};

    fn orthogonal_x(n_features: usize) -> CscMatrix {
        // identity-ish: each column has a single distinct nonzero row
        let trips: Vec<(u32, u32, f32)> = (0..n_features)
            .map(|j| (j as u32, j as u32, 1.0))
            .collect();
        CscMatrix::from_triplets(n_features, n_features, &trips)
    }

    fn cfg(u: usize, u_prime: usize) -> PriorityConfig {
        PriorityConfig {
            u,
            u_prime,
            rho: 0.5,
            eta: 1e-6,
            use_priority: true,
            use_dependency_filter: true,
        }
    }

    #[test]
    fn returns_at_most_u_distinct_indices() {
        let x = orthogonal_x(50);
        let mut s = PriorityScheduler::new(50, cfg(8, 32), 1);
        let set = s.next_set(&x);
        assert!(set.len() <= 8 && !set.is_empty());
        let mut d = set.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), set.len());
    }

    #[test]
    fn priorities_bias_selection() {
        let x = orthogonal_x(100);
        let mut s = PriorityScheduler::new(100, cfg(4, 16), 2);
        // make coefficient 7 dominate
        for j in 0..100 {
            s.update_priority(j, if j == 7 { 100.0 } else { 0.0 });
        }
        let mut hits = 0;
        for _ in 0..50 {
            if s.next_set(&x).contains(&7) {
                hits += 1;
            }
        }
        assert!(hits >= 48, "hits={hits}");
    }

    #[test]
    fn correlated_pair_never_coscheduled() {
        // two identical columns 0 and 1
        let x = CscMatrix::from_triplets(
            4,
            4,
            &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)],
        );
        let mut s = PriorityScheduler::new(4, cfg(4, 4), 3);
        for _ in 0..100 {
            let set = s.next_set(&x);
            assert!(
                !(set.contains(&0) && set.contains(&1)),
                "co-scheduled correlated pair: {set:?}"
            );
        }
    }

    #[test]
    fn ablation_disable_filter_allows_conflicts_eventually() {
        let x = CscMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let mut c = cfg(2, 2);
        c.use_dependency_filter = false;
        let mut s = PriorityScheduler::new(2, c, 4);
        let mut saw_conflict = false;
        for _ in 0..50 {
            let set = s.next_set(&x);
            if set.contains(&0) && set.contains(&1) {
                saw_conflict = true;
            }
        }
        assert!(saw_conflict);
    }

    #[test]
    fn prop_sets_are_pairwise_uncorrelated() {
        prop_check("priority pairwise safety", 30, |g| {
            let n = g.usize_in(4, 40);
            let x = orthogonal_x(n);
            let u = g.usize_in(1, n.min(8));
            let mut s = PriorityScheduler::new(
                n,
                cfg(u, (u * 3).min(n)),
                g.seed(),
            );
            let set = s.next_set(&x);
            let mut checker = DependencyChecker::new(&x, 0.5);
            for i in 0..set.len() {
                for j in (i + 1)..set.len() {
                    if checker.correlation(set[i], set[j]) >= 0.5 {
                        return Prop::Fail(format!("pair {set:?}"));
                    }
                }
            }
            Prop::Ok
        });
    }
}
