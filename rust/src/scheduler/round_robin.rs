//! Round-robin block scheduling for MF (paper §3.2, pseudocode Fig 6).
//!
//! CCD alternates between the two factor matrices, cycling the rank index:
//! the global `counter` walks (W, k=0), (H, k=0), (W, k=1), (H, k=1), …
//! Within a phase, the W/H columns are implicitly partitioned by the data
//! sharding (workers hold row/column shards), so the schedule only needs to
//! emit which factor and which rank row is updated next.

/// Which factor matrix a round updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Factor {
    W,
    H,
}

/// One scheduled MF round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MfRound {
    pub factor: Factor,
    /// Rank index k ∈ [0, rank).
    pub k: usize,
}

/// Stateful round-robin scheduler over rank indices.
#[derive(Debug, Clone)]
pub struct RoundRobinScheduler {
    rank: usize,
    counter: u64,
}

impl RoundRobinScheduler {
    pub fn new(rank: usize) -> Self {
        assert!(rank > 0);
        RoundRobinScheduler { rank, counter: 0 }
    }

    /// Next (factor, k) pair; advances the counter.
    pub fn next_round(&mut self) -> MfRound {
        let c = self.counter as usize;
        self.counter += 1;
        let k = (c / 2) % self.rank;
        let factor = if c % 2 == 0 { Factor::W } else { Factor::H };
        MfRound { factor, k }
    }

    /// Rounds for one full CCD sweep (both factors, all ranks).
    pub fn rounds_per_sweep(&self) -> usize {
        2 * self.rank
    }

    pub fn round(&self) -> u64 {
        self.counter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{ensure, prop_check};

    #[test]
    fn alternates_factors_and_cycles_ranks() {
        let mut s = RoundRobinScheduler::new(3);
        let seq: Vec<MfRound> = (0..6).map(|_| s.next_round()).collect();
        assert_eq!(seq[0], MfRound { factor: Factor::W, k: 0 });
        assert_eq!(seq[1], MfRound { factor: Factor::H, k: 0 });
        assert_eq!(seq[2], MfRound { factor: Factor::W, k: 1 });
        assert_eq!(seq[5], MfRound { factor: Factor::H, k: 2 });
    }

    #[test]
    fn sweep_covers_every_rank_twice() {
        let rank = 5;
        let mut s = RoundRobinScheduler::new(rank);
        let mut w_seen = vec![0; rank];
        let mut h_seen = vec![0; rank];
        for _ in 0..s.rounds_per_sweep() {
            let r = s.next_round();
            match r.factor {
                Factor::W => w_seen[r.k] += 1,
                Factor::H => h_seen[r.k] += 1,
            }
        }
        assert!(w_seen.iter().all(|&c| c == 1), "{w_seen:?}");
        assert!(h_seen.iter().all(|&c| c == 1), "{h_seen:?}");
    }

    #[test]
    fn prop_k_always_in_range() {
        prop_check("round robin k range", 100, |g| {
            let rank = g.usize_in(1, 256);
            let mut s = RoundRobinScheduler::new(rank);
            for _ in 0..g.usize_in(1, 100) {
                let r = s.next_round();
                if r.k >= rank {
                    return crate::testing::Prop::Fail(format!(
                        "k={} rank={rank}",
                        r.k
                    ));
                }
            }
            ensure(true, "")
        });
    }
}
