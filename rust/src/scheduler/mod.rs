//! The **schedule** primitive (paper §2): strategies that pick which model
//! variables each worker updates next.
//!
//! * [`rotation`] — LDA's word-rotation schedule: U ≥ P disjoint word
//!   subsets rotate among P workers (⌈U/P⌉-slice queues per worker per
//!   round), every worker touching every subset within U rounds (paper
//!   §3.1, Fig 4; over-decomposition + skew-aware ring placement per
//!   Zheng et al. and Lee et al.).
//! * [`round_robin`] — MF's block round-robin over factor rows (paper §3.2).
//! * [`priority`] — Lasso's dynamic schedule: sample U′ candidates from
//!   c_j ∝ |δβ_j| + η, then dependency-filter to a set with pairwise
//!   |x_j^T x_k| < ρ (paper §3.3).
//! * [`random`] — uniform random U coefficients (the Shotgun-imitating
//!   Lasso-RR baseline).
//! * [`dependency`] — the pairwise-correlation filter used by `priority`.

pub mod debt;
pub mod dependency;
pub mod priority;
pub mod random;
pub mod rotation;
pub mod round_robin;

pub use debt::CoverageDebtLedger;
pub use dependency::DependencyChecker;
pub use priority::PriorityScheduler;
pub use random::RandomScheduler;
pub use rotation::{GrantLeg, QueueOrder, RotationScheduler, SkipPolicy};
pub use round_robin::RoundRobinScheduler;
