//! Pairwise-dependency filter (paper §3.3): given candidate coefficients C,
//! select a subset B ⊆ C with |x_j^T x_k| < ρ for all j,k ∈ B.
//!
//! Bradley et al. showed parallel CD diverges when correlated coordinates
//! update together; this filter is what lets STRADS Lasso run |B| = U
//! concurrent updates safely.  Cost is |C|² = U′² sparse dot products,
//! *not* J² (the paper's complexity argument).

use crate::sparse::CscMatrix;

/// Correlation oracle: exact sparse column dots against the design matrix.
pub struct DependencyChecker<'a> {
    x: &'a CscMatrix,
    rho: f32,
    /// Dot products evaluated since construction (perf accounting).
    checks: u64,
}

impl<'a> DependencyChecker<'a> {
    pub fn new(x: &'a CscMatrix, rho: f32) -> Self {
        assert!(rho > 0.0, "rho must be in (0, 1]");
        DependencyChecker { x, rho, checks: 0 }
    }

    /// |x_j^T x_k| (columns assumed standardized, so this is the
    /// correlation).
    pub fn correlation(&mut self, j: usize, k: usize) -> f32 {
        self.checks += 1;
        self.x.col_dot_col(j, k).abs()
    }

    /// Greedy filter: scan candidates in order, keep those compatible with
    /// everything already kept (paper's f_2).  Always keeps the first
    /// candidate — the highest-priority one under priority sampling.
    pub fn filter(&mut self, candidates: &[usize], max_keep: usize) -> Vec<usize> {
        let mut kept: Vec<usize> = Vec::with_capacity(max_keep);
        'outer: for &j in candidates {
            if kept.len() >= max_keep {
                break;
            }
            if kept.contains(&j) {
                continue;
            }
            for &k in &kept {
                if self.correlation(j, k) >= self.rho {
                    continue 'outer;
                }
            }
            kept.push(j);
        }
        kept
    }

    pub fn checks(&self) -> u64 {
        self.checks
    }

    pub fn rho(&self) -> f32 {
        self.rho
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CscMatrix;

    /// Matrix with two identical columns (0,1) and two orthogonal (2,3).
    fn fixture() -> CscMatrix {
        CscMatrix::from_triplets(
            4,
            4,
            &[
                (0, 0, 1.0),
                (0, 1, 1.0), // col1 == col0  (correlation 1)
                (1, 2, 1.0),
                (2, 3, 1.0),
            ],
        )
    }

    #[test]
    fn filter_drops_correlated_candidates() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.5);
        let kept = c.filter(&[0, 1, 2, 3], 4);
        assert_eq!(kept, vec![0, 2, 3]); // 1 conflicts with 0
    }

    #[test]
    fn filter_respects_max_keep() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.5);
        assert_eq!(c.filter(&[2, 3, 0], 2), vec![2, 3]);
    }

    #[test]
    fn filter_keeps_first_candidate() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.01);
        // even with a tiny rho the head of the list survives
        assert_eq!(c.filter(&[1, 0], 4), vec![1]);
    }

    #[test]
    fn filter_dedupes() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.5);
        assert_eq!(c.filter(&[2, 2, 2, 3], 4), vec![2, 3]);
    }

    #[test]
    fn pairwise_invariant_holds_on_output() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.5);
        let kept = c.filter(&[0, 1, 2, 3], 4);
        for i in 0..kept.len() {
            for j in (i + 1)..kept.len() {
                assert!(c.correlation(kept[i], kept[j]) < 0.5);
            }
        }
    }

    #[test]
    fn check_count_is_quadratic_in_candidates_not_features() {
        let x = fixture();
        let mut c = DependencyChecker::new(&x, 0.5);
        c.filter(&[0, 2, 3], 3);
        // at most C(3,2)*... <= 3+2+1 checks, far below any J² notion
        assert!(c.checks() <= 6, "{}", c.checks());
    }
}
