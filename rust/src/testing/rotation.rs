//! Shared drivers for rotation-invariant property tests.
//!
//! Every rotation feature so far — pipelined handoffs (PR 2), slice
//! over-decomposition (PR 3), availability ordering (PR 4), and now
//! dynamic ordering + coverage-debt skipping — must preserve the same
//! four invariants: per-round lease **disjointness**, bounded-horizon
//! **coverage**, fork-free **version chains**, and (at the app level)
//! token **conservation**.  The per-feature test files used to each carry
//! their own copy of the grant→take→forward→settle protocol loop; this
//! module is the one shared implementation, parameterized over the skip
//! policy, the availability signal, and the within-round service order,
//! so `tests/rotation_properties.rs` can sweep the whole mode matrix and
//! the per-feature files (`rotation_handoff.rs`,
//! `availability_rotation.rs`) reduce to thin wrappers.
//!
//! **The availability signal is backend-supplied.**  In the engine the
//! skip-capable schedule polls the *live* data plane
//! ([`crate::kvstore::rotation_availability`]), so what "available" means
//! depends on the execution backend
//! ([`crate::cluster::exec::ExecBackend`]): under the sim backend the
//! single-threaded driver services rounds between dispatches and the
//! signal is a deterministic function of the replayed timeline, while
//! under `--backend threads` it reflects how far real worker threads have
//! physically progressed.  [`drive_protocol`] therefore takes the signal
//! as a caller-supplied closure (any pattern is exercisable,
//! deterministically), and [`drive_protocol_threaded`] reads the live
//! router exactly as the threaded engine does — between them the property
//! sweeps cover both regimes.

use crate::kvstore::{rotation_availability, LeaseLedger, LeaseToken, SliceRouter};
use crate::scheduler::rotation::{QueueOrder, SkipPolicy};
use crate::scheduler::RotationScheduler;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// What a [`drive_protocol`] run observed (for callers to assert coverage
/// or chain-depth properties beyond the built-in checks).
pub struct ProtocolOutcome {
    /// `seen[worker][slice]`: the worker was granted the slice at least
    /// once.
    pub seen: Vec<Vec<bool>>,
    /// Grants per slice over the run (`rounds` each under
    /// [`SkipPolicy::Never`]; at least `rounds - debt_limit` under
    /// `Defer`).
    pub grants: Vec<u64>,
    /// Slice-legs skipped over the run.
    pub skipped: u64,
    pub rounds: u64,
}

impl ProtocolOutcome {
    /// Every worker was granted every slice at least once.
    pub fn full_coverage(&self) -> bool {
        self.seen.iter().all(|row| row.iter().all(|&b| b))
    }
}

/// Drive the full grant→take→forward→settle rotation protocol
/// single-threaded over a `u`-slice, `p`-worker ring for `rounds` rounds,
/// checking the protocol invariants as it goes:
///
/// * each round's grants are **disjoint** (no slice granted twice), and
///   under [`SkipPolicy::Never`] they are a full partition of the slices;
/// * each granted lease's `try_take` finds exactly the granted version
///   parked (every slice is between rounds when the driver services it),
///   and each `forward`/`settle` advances the chain by exactly one — the
///   router/ledger panics on any fork double as checks;
/// * at the end no lease is outstanding and every slice's chain head
///   equals its grant count.
///
/// `available(slice, round)` is the simulated in-flight signal a
/// skip-capable schedule consults ([`SkipPolicy::Defer`] skips
/// unavailable slices within budget; the signal is decoupled from the
/// single-threaded data plane, where everything is parked, so *any*
/// availability pattern is exercisable).  `pick(pending)` chooses which
/// pending `(slice, version)` leg to service next — grant order, random
/// permutations, mass-weighted: the service order is a free knob of the
/// rotation primitive and the invariants must hold for every choice.
///
/// Slice `a`'s payload is `vec![a as u32; a + 1]` — distinct
/// [`crate::kvstore::SliceMass`] masses, so mass-based `pick` closures
/// have something to rank.
///
/// Returns `Err(message)` on the first violation (callers inside
/// `prop_check` map it to `Prop::Fail` so the failing seed is reported).
pub fn drive_protocol(
    p: usize,
    u: usize,
    rounds: u64,
    skip: SkipPolicy,
    mut available: impl FnMut(usize, u64) -> bool,
    mut pick: impl FnMut(&[(usize, u64)]) -> usize,
) -> Result<ProtocolOutcome, String> {
    let router: SliceRouter<Vec<u32>> = SliceRouter::new(u);
    let mut ledger = LeaseLedger::new(u);
    for a in 0..u {
        router.seed(a, vec![a as u32; a + 1], 0);
        ledger.seed(a, 0);
    }
    let mut sched = RotationScheduler::with_workers(u, p);
    sched.set_skip_policy(skip);
    let mut seen = vec![vec![false; u]; p];
    let mut grants_per_slice = vec![0u64; u];
    let mut skipped_total = 0u64;
    for r in 0..rounds {
        let grants = sched.next_round_grants(|a| available(a, r));
        let mut granted: Vec<usize> =
            grants.iter().flatten().map(|l| l.slice_id).collect();
        let n_granted = granted.len();
        granted.sort_unstable();
        granted.dedup();
        if granted.len() != n_granted {
            return Err(format!(
                "round {r}: a slice was granted twice (u={u}, p={p})"
            ));
        }
        let skipped = u - n_granted;
        skipped_total += skipped as u64;
        if skip == SkipPolicy::Never && skipped != 0 {
            return Err(format!(
                "round {r}: {skipped} slices missing from a Never round"
            ));
        }
        // grant every leg, then service them in the picked order through
        // the non-blocking poll (a leg is serviceable only while its
        // version is parked — exactly the reordered worker's view)
        let mut pending: Vec<(usize, u64)> = Vec::new();
        for (w, q) in grants.iter().enumerate() {
            for leg in q {
                if leg.dest_worker >= p {
                    return Err(format!(
                        "round {r}: slice {} forwarded to nonexistent \
                         worker {}",
                        leg.slice_id, leg.dest_worker
                    ));
                }
                seen[w][leg.slice_id] = true;
                grants_per_slice[leg.slice_id] += 1;
                pending.push((leg.slice_id, ledger.grant(leg.slice_id)));
            }
        }
        while !pending.is_empty() {
            let at = pick(&pending).min(pending.len() - 1);
            let (slice_id, version) = pending.remove(at);
            let (data, consumed) = match router.try_take(slice_id, version) {
                Some(got) => got,
                None => {
                    return Err(format!(
                        "slice {slice_id} v{version} not parked (every \
                         slice is between rounds here)"
                    ))
                }
            };
            if consumed != version {
                return Err(format!(
                    "slice {slice_id}: granted v{version}, router handed \
                     over v{consumed}"
                ));
            }
            router.forward(slice_id, data, consumed + 1);
            ledger
                .settle(&LeaseToken { slice_id, version: consumed })
                .map_err(|z| format!("unexpected zombie settle: {z:?}"))?;
        }
    }
    if ledger.max_outstanding() != 0 {
        return Err(format!(
            "{} leases left outstanding",
            ledger.max_outstanding()
        ));
    }
    for a in 0..u {
        if router.version(a) != grants_per_slice[a] {
            return Err(format!(
                "slice {a}: chain head {} after {} grants",
                router.version(a),
                grants_per_slice[a]
            ));
        }
    }
    Ok(ProtocolOutcome {
        seen,
        grants: grants_per_slice,
        skipped: skipped_total,
        rounds,
    })
}

/// The expected (never-mutated) payload of slice `a` — both protocol
/// drivers seed `vec![a as u32; a + 1]` and the threaded driver re-checks
/// it at every take: the handoff plane must move payloads, not transform
/// them, so any corruption under real concurrency is token-mass loss.
fn protocol_payload(a: usize) -> Vec<u32> {
    vec![a as u32; a + 1]
}

/// [`drive_protocol`] with **real OS worker threads**: each round spawns
/// one thread per granted worker, the threads exchange slices through the
/// shared [`SliceRouter`] under the given service `order` (Strict blocks
/// per leg in queue order via `take_for`; Availability/Dynamic sweep via
/// `take_earliest`/`take_heaviest`), and up to `depth` rounds run
/// concurrently (the oldest is joined + settled once the window fills) —
/// the same grant→take→forward→settle windowing the threaded engine runs,
/// minus the app math.
///
/// Checks, on top of [`drive_protocol`]'s invariants: every take hands
/// over exactly the granted version (no version forks under any
/// interleaving), every payload is bit-intact at every hop (token-mass
/// conservation), and at the end no lease is outstanding and every chain
/// head equals its grant count.  Under [`SkipPolicy::Defer`] the
/// availability signal is the **live** router
/// ([`rotation_availability`]), so skips are genuinely timing-dependent —
/// the invariants must hold for whatever interleaving this host produces.
///
/// Returns `Err(message)` on the first violation, including a worker
/// thread panic (joined and stringified).
pub fn drive_protocol_threaded(
    p: usize,
    u: usize,
    rounds: u64,
    depth: u64,
    skip: SkipPolicy,
    order: QueueOrder,
) -> Result<ProtocolOutcome, String> {
    assert!(depth >= 1, "window depth must be at least 1");
    let router: Arc<SliceRouter<Vec<u32>>> = Arc::new(SliceRouter::new(u));
    let mut ledger = LeaseLedger::new(u);
    for a in 0..u {
        router.seed(a, protocol_payload(a), 0);
        ledger.seed(a, 0);
    }
    let mut sched = RotationScheduler::with_workers(u, p);
    sched.set_skip_policy(skip);
    sched.set_queue_order(order);
    let mut seen = vec![vec![false; u]; p];
    let mut grants_per_slice = vec![0u64; u];
    let mut skipped_total = 0u64;
    // the per-leg take deadline: generous enough for a loaded CI host,
    // bounded enough that a genuinely lost handoff fails, not hangs
    let take_timeout = Duration::from_secs(30);

    type RoundHandles =
        Vec<std::thread::JoinHandle<Result<Vec<LeaseToken>, String>>>;
    let mut window: VecDeque<RoundHandles> = VecDeque::new();

    // join the oldest in-flight round's workers and settle their leases
    fn collect_oldest(
        window: &mut VecDeque<RoundHandles>,
        ledger: &mut LeaseLedger,
    ) -> Result<(), String> {
        let handles = window.pop_front().expect("window not empty");
        let mut errs = Vec::new();
        let mut tokens = Vec::new();
        for h in handles {
            match h.join() {
                Ok(Ok(t)) => tokens.extend(t),
                Ok(Err(e)) => errs.push(e),
                Err(panic) => errs.push(format!(
                    "worker thread panicked: {:?}",
                    panic
                        .downcast_ref::<String>()
                        .map(String::as_str)
                        .or_else(|| panic.downcast_ref::<&str>().copied())
                        .unwrap_or("<non-string payload>")
                )),
            }
        }
        if let Some(e) = errs.into_iter().next() {
            return Err(e);
        }
        for token in tokens {
            ledger
                .settle(&token)
                .map_err(|z| format!("unexpected zombie settle: {z:?}"))?;
        }
        Ok(())
    }

    for r in 0..rounds {
        let avail = match skip {
            SkipPolicy::Never => vec![true; u],
            SkipPolicy::Defer { .. } => {
                rotation_availability(Some(router.as_ref()), &ledger)
            }
        };
        let grants = sched.next_round_grants(|a| avail[a]);
        let mut granted: Vec<usize> =
            grants.iter().flatten().map(|l| l.slice_id).collect();
        let n_granted = granted.len();
        granted.sort_unstable();
        granted.dedup();
        if granted.len() != n_granted {
            return Err(format!(
                "round {r}: a slice was granted twice (u={u}, p={p})"
            ));
        }
        let skipped = u - n_granted;
        skipped_total += skipped as u64;
        if skip == SkipPolicy::Never && skipped != 0 {
            return Err(format!(
                "round {r}: {skipped} slices missing from a Never round"
            ));
        }
        let mut handles: RoundHandles = Vec::with_capacity(p);
        for (w, q) in grants.iter().enumerate() {
            let mut legs: Vec<(usize, u64)> = Vec::with_capacity(q.len());
            for leg in q {
                if leg.dest_worker >= p {
                    return Err(format!(
                        "round {r}: slice {} forwarded to nonexistent \
                         worker {}",
                        leg.slice_id, leg.dest_worker
                    ));
                }
                seen[w][leg.slice_id] = true;
                grants_per_slice[leg.slice_id] += 1;
                legs.push((leg.slice_id, ledger.grant(leg.slice_id)));
            }
            if legs.is_empty() {
                continue;
            }
            let router = Arc::clone(&router);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("strads-prot-{w}"))
                    .spawn(move || {
                        worker_round(&router, legs, order, take_timeout)
                    })
                    .expect("spawn protocol worker"),
            );
        }
        window.push_back(handles);
        while window.len() as u64 >= depth {
            collect_oldest(&mut window, &mut ledger)?;
        }
    }
    while !window.is_empty() {
        collect_oldest(&mut window, &mut ledger)?;
    }

    if ledger.max_outstanding() != 0 {
        return Err(format!(
            "{} leases left outstanding",
            ledger.max_outstanding()
        ));
    }
    for a in 0..u {
        if router.version(a) != grants_per_slice[a] {
            return Err(format!(
                "slice {a}: chain head {} after {} grants",
                router.version(a),
                grants_per_slice[a]
            ));
        }
        // final conservation check: the payload survived every hop intact
        let ok = router.with_slice(a, |s| s == Some(&protocol_payload(a)));
        if !ok {
            return Err(format!(
                "slice {a}: payload corrupted across {} handoffs",
                grants_per_slice[a]
            ));
        }
    }
    Ok(ProtocolOutcome {
        seen,
        grants: grants_per_slice,
        skipped: skipped_total,
        rounds,
    })
}

/// One worker thread's round under [`drive_protocol_threaded`]: take each
/// granted leg per the service discipline, verify version + payload, and
/// forward to the ring successor.  Returns the consumed lease tokens for
/// the driver to settle at collect time.
fn worker_round(
    router: &SliceRouter<Vec<u32>>,
    legs: Vec<(usize, u64)>,
    order: QueueOrder,
    take_timeout: Duration,
) -> Result<Vec<LeaseToken>, String> {
    let mut tokens = Vec::with_capacity(legs.len());
    let mut serve = |slice_id: usize,
                     data: Vec<u32>,
                     consumed: u64,
                     version: u64|
     -> Result<(), String> {
        if consumed != version {
            return Err(format!(
                "slice {slice_id}: granted v{version}, router handed over \
                 v{consumed}"
            ));
        }
        if data != protocol_payload(slice_id) {
            return Err(format!(
                "slice {slice_id} v{version}: payload corrupted in flight"
            ));
        }
        router.forward(slice_id, data, consumed + 1);
        tokens.push(LeaseToken { slice_id, version: consumed });
        Ok(())
    };
    match order {
        QueueOrder::Strict => {
            for (slice_id, version) in legs {
                let (data, consumed) = router
                    .take_for(slice_id, version, take_timeout)
                    .map_err(|e| e.to_string())?;
                serve(slice_id, data, consumed, version)?;
            }
        }
        QueueOrder::Availability | QueueOrder::Dynamic => {
            let mut remaining = legs;
            while !remaining.is_empty() {
                let (pick, data, consumed) = match order {
                    QueueOrder::Dynamic => {
                        router.take_heaviest(&remaining, take_timeout)
                    }
                    _ => router.take_earliest(&remaining, take_timeout),
                }
                .map_err(|e| e.to_string())?;
                let (slice_id, version) = remaining.remove(pick);
                serve(slice_id, data, consumed, version)?;
            }
        }
    }
    Ok(tokens)
}

/// The full {order} × {skip} mode matrix the acceptance criteria sweep.
/// Depth and over-decomposition factors are the caller's cross product —
/// this just enumerates the discipline combinations so no test file
/// hand-maintains the list.
pub fn mode_matrix(debt_limit: u64) -> Vec<(QueueOrder, SkipPolicy)> {
    let orders = [
        QueueOrder::Strict,
        QueueOrder::Availability,
        QueueOrder::Dynamic,
    ];
    let skips = [SkipPolicy::Never, SkipPolicy::Defer { debt_limit }];
    let mut out = Vec::new();
    for &order in &orders {
        for &skip in &skips {
            out.push((order, skip));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drive_protocol_runs_the_never_matrix_cleanly() {
        let out = drive_protocol(
            3,
            7,
            7,
            SkipPolicy::Never,
            |_, _| true,
            |_| 0, // grant order
        )
        .expect("clean protocol run");
        assert!(out.full_coverage(), "U rounds cover every worker×slice");
        assert_eq!(out.skipped, 0);
        assert!(out.grants.iter().all(|&g| g == 7));
    }

    #[test]
    fn mode_matrix_enumerates_all_six_combinations() {
        let m = mode_matrix(2);
        assert_eq!(m.len(), 6);
        assert!(m.contains(&(QueueOrder::Dynamic, SkipPolicy::Never)));
        assert!(m.contains(&(
            QueueOrder::Strict,
            SkipPolicy::Defer { debt_limit: 2 }
        )));
    }
}
