//! Shared drivers for rotation-invariant property tests.
//!
//! Every rotation feature so far — pipelined handoffs (PR 2), slice
//! over-decomposition (PR 3), availability ordering (PR 4), and now
//! dynamic ordering + coverage-debt skipping — must preserve the same
//! four invariants: per-round lease **disjointness**, bounded-horizon
//! **coverage**, fork-free **version chains**, and (at the app level)
//! token **conservation**.  The per-feature test files used to each carry
//! their own copy of the grant→take→forward→settle protocol loop; this
//! module is the one shared implementation, parameterized over the skip
//! policy, the availability signal, and the within-round service order,
//! so `tests/rotation_properties.rs` can sweep the whole mode matrix and
//! the per-feature files (`rotation_handoff.rs`,
//! `availability_rotation.rs`) reduce to thin wrappers.

use crate::kvstore::{LeaseLedger, LeaseToken, SliceRouter};
use crate::scheduler::rotation::{QueueOrder, SkipPolicy};
use crate::scheduler::RotationScheduler;

/// What a [`drive_protocol`] run observed (for callers to assert coverage
/// or chain-depth properties beyond the built-in checks).
pub struct ProtocolOutcome {
    /// `seen[worker][slice]`: the worker was granted the slice at least
    /// once.
    pub seen: Vec<Vec<bool>>,
    /// Grants per slice over the run (`rounds` each under
    /// [`SkipPolicy::Never`]; at least `rounds - debt_limit` under
    /// `Defer`).
    pub grants: Vec<u64>,
    /// Slice-legs skipped over the run.
    pub skipped: u64,
    pub rounds: u64,
}

impl ProtocolOutcome {
    /// Every worker was granted every slice at least once.
    pub fn full_coverage(&self) -> bool {
        self.seen.iter().all(|row| row.iter().all(|&b| b))
    }
}

/// Drive the full grant→take→forward→settle rotation protocol
/// single-threaded over a `u`-slice, `p`-worker ring for `rounds` rounds,
/// checking the protocol invariants as it goes:
///
/// * each round's grants are **disjoint** (no slice granted twice), and
///   under [`SkipPolicy::Never`] they are a full partition of the slices;
/// * each granted lease's `try_take` finds exactly the granted version
///   parked (every slice is between rounds when the driver services it),
///   and each `forward`/`settle` advances the chain by exactly one — the
///   router/ledger panics on any fork double as checks;
/// * at the end no lease is outstanding and every slice's chain head
///   equals its grant count.
///
/// `available(slice, round)` is the simulated in-flight signal a
/// skip-capable schedule consults ([`SkipPolicy::Defer`] skips
/// unavailable slices within budget; the signal is decoupled from the
/// single-threaded data plane, where everything is parked, so *any*
/// availability pattern is exercisable).  `pick(pending)` chooses which
/// pending `(slice, version)` leg to service next — grant order, random
/// permutations, mass-weighted: the service order is a free knob of the
/// rotation primitive and the invariants must hold for every choice.
///
/// Slice `a`'s payload is `vec![a as u32; a + 1]` — distinct
/// [`crate::kvstore::SliceMass`] masses, so mass-based `pick` closures
/// have something to rank.
///
/// Returns `Err(message)` on the first violation (callers inside
/// `prop_check` map it to `Prop::Fail` so the failing seed is reported).
pub fn drive_protocol(
    p: usize,
    u: usize,
    rounds: u64,
    skip: SkipPolicy,
    mut available: impl FnMut(usize, u64) -> bool,
    mut pick: impl FnMut(&[(usize, u64)]) -> usize,
) -> Result<ProtocolOutcome, String> {
    let router: SliceRouter<Vec<u32>> = SliceRouter::new(u);
    let mut ledger = LeaseLedger::new(u);
    for a in 0..u {
        router.seed(a, vec![a as u32; a + 1], 0);
        ledger.seed(a, 0);
    }
    let mut sched = RotationScheduler::with_workers(u, p);
    sched.set_skip_policy(skip);
    let mut seen = vec![vec![false; u]; p];
    let mut grants_per_slice = vec![0u64; u];
    let mut skipped_total = 0u64;
    for r in 0..rounds {
        let grants = sched.next_round_grants(|a| available(a, r));
        let mut granted: Vec<usize> =
            grants.iter().flatten().map(|l| l.slice_id).collect();
        let n_granted = granted.len();
        granted.sort_unstable();
        granted.dedup();
        if granted.len() != n_granted {
            return Err(format!(
                "round {r}: a slice was granted twice (u={u}, p={p})"
            ));
        }
        let skipped = u - n_granted;
        skipped_total += skipped as u64;
        if skip == SkipPolicy::Never && skipped != 0 {
            return Err(format!(
                "round {r}: {skipped} slices missing from a Never round"
            ));
        }
        // grant every leg, then service them in the picked order through
        // the non-blocking poll (a leg is serviceable only while its
        // version is parked — exactly the reordered worker's view)
        let mut pending: Vec<(usize, u64)> = Vec::new();
        for (w, q) in grants.iter().enumerate() {
            for leg in q {
                if leg.dest_worker >= p {
                    return Err(format!(
                        "round {r}: slice {} forwarded to nonexistent \
                         worker {}",
                        leg.slice_id, leg.dest_worker
                    ));
                }
                seen[w][leg.slice_id] = true;
                grants_per_slice[leg.slice_id] += 1;
                pending.push((leg.slice_id, ledger.grant(leg.slice_id)));
            }
        }
        while !pending.is_empty() {
            let at = pick(&pending).min(pending.len() - 1);
            let (slice_id, version) = pending.remove(at);
            let (data, consumed) = match router.try_take(slice_id, version) {
                Some(got) => got,
                None => {
                    return Err(format!(
                        "slice {slice_id} v{version} not parked (every \
                         slice is between rounds here)"
                    ))
                }
            };
            if consumed != version {
                return Err(format!(
                    "slice {slice_id}: granted v{version}, router handed \
                     over v{consumed}"
                ));
            }
            router.forward(slice_id, data, consumed + 1);
            ledger.settle(&LeaseToken { slice_id, version: consumed });
        }
    }
    if ledger.max_outstanding() != 0 {
        return Err(format!(
            "{} leases left outstanding",
            ledger.max_outstanding()
        ));
    }
    for a in 0..u {
        if router.version(a) != grants_per_slice[a] {
            return Err(format!(
                "slice {a}: chain head {} after {} grants",
                router.version(a),
                grants_per_slice[a]
            ));
        }
    }
    Ok(ProtocolOutcome {
        seen,
        grants: grants_per_slice,
        skipped: skipped_total,
        rounds,
    })
}

/// The full {order} × {skip} mode matrix the acceptance criteria sweep.
/// Depth and over-decomposition factors are the caller's cross product —
/// this just enumerates the discipline combinations so no test file
/// hand-maintains the list.
pub fn mode_matrix(debt_limit: u64) -> Vec<(QueueOrder, SkipPolicy)> {
    let orders = [
        QueueOrder::Strict,
        QueueOrder::Availability,
        QueueOrder::Dynamic,
    ];
    let skips = [SkipPolicy::Never, SkipPolicy::Defer { debt_limit }];
    let mut out = Vec::new();
    for &order in &orders {
        for &skip in &skips {
            out.push((order, skip));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drive_protocol_runs_the_never_matrix_cleanly() {
        let out = drive_protocol(
            3,
            7,
            7,
            SkipPolicy::Never,
            |_, _| true,
            |_| 0, // grant order
        )
        .expect("clean protocol run");
        assert!(out.full_coverage(), "U rounds cover every worker×slice");
        assert_eq!(out.skipped, 0);
        assert!(out.grants.iter().all(|&g| g == 7));
    }

    #[test]
    fn mode_matrix_enumerates_all_six_combinations() {
        let m = mode_matrix(2);
        assert_eq!(m.len(), 6);
        assert!(m.contains(&(QueueOrder::Dynamic, SkipPolicy::Never)));
        assert!(m.contains(&(
            QueueOrder::Strict,
            SkipPolicy::Defer { debt_limit: 2 }
        )));
    }
}
