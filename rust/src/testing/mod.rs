//! Minimal property-testing framework (proptest is unavailable offline).
//!
//! `prop_check` runs a property over `n` seeded random cases; on failure it
//! re-runs with progressively simpler generator sizes to report a smaller
//! counterexample seed, then panics with the failing seed so the case can
//! be replayed deterministically:
//!
//! ```ignore
//! prop_check("rotation covers all slices", 200, |g| {
//!     let u = g.usize_in(1, 32);
//!     ...
//! });
//! ```

pub mod rotation;

use crate::util::Rng;

/// Generator handle passed to properties: seeded random primitives plus a
/// size knob used for shrinking.
pub struct Gen {
    rng: Rng,
    /// Scale factor in (0, 1]; shrink passes lower it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Integer in [lo, hi], scaled toward lo as `size` shrinks.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.below(span.max(1).min(hi - lo + 1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn f32_std(&mut self) -> f32 {
        self.rng.normal_f32()
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Vector of f32 normals with length in [lo, hi] (size-scaled).
    pub fn vec_f32(&mut self, lo: usize, hi: usize) -> Vec<f32> {
        let n = self.usize_in(lo, hi);
        (0..n).map(|_| self.rng.normal_f32()).collect()
    }

    /// Borrow the underlying RNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a single property case.
pub enum Prop {
    Ok,
    /// Failed with a message describing the violation.
    Fail(String),
    /// Case rejected (precondition unmet) — does not count toward n.
    Discard,
}

/// Convenience: turn a bool + message into a Prop.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Prop {
    if cond {
        Prop::Ok
    } else {
        Prop::Fail(msg.into())
    }
}

/// Run `prop` over `n` seeded cases (master seed fixed for repeatability —
/// override with STRADS_PROP_SEED).  On failure, tries smaller sizes to
/// find a simpler counterexample, then panics with seed + message.
pub fn prop_check<F: FnMut(&mut Gen) -> Prop>(name: &str, n: usize, mut prop: F) {
    let master: u64 = std::env::var("STRADS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5712AD5);
    let mut meta = Rng::new(master);
    let mut executed = 0usize;
    let mut attempts = 0usize;
    while executed < n && attempts < n * 10 {
        attempts += 1;
        let seed = meta.next_u64();
        match prop(&mut Gen::new(seed, 1.0)) {
            Prop::Ok => executed += 1,
            Prop::Discard => {}
            Prop::Fail(msg) => {
                // shrink: retry the same seed at smaller sizes and report
                // the smallest size that still fails
                let mut worst = (1.0, msg);
                for &size in &[0.5, 0.25, 0.1, 0.02] {
                    if let Prop::Fail(m) = prop(&mut Gen::new(seed, size)) {
                        worst = (size, m);
                    }
                }
                panic!(
                    "property {name:?} failed (seed={seed:#x}, size={}): {}",
                    worst.0, worst.1
                );
            }
        }
    }
    assert!(
        executed >= n / 2,
        "property {name:?}: too many discards ({executed}/{n} executed)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("tautology", 50, |g| {
            count += 1;
            let x = g.usize_in(0, 100);
            ensure(x <= 100, "in range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "failed")]
    fn failing_property_panics_with_seed() {
        prop_check("always false", 10, |g| {
            let _ = g.usize_in(0, 10);
            ensure(false, "nope")
        });
    }

    #[test]
    fn discards_do_not_count() {
        let mut ok_cases = 0;
        prop_check("half discarded", 20, |g| {
            if g.bool_with(0.5) {
                return Prop::Discard;
            }
            ok_cases += 1;
            Prop::Ok
        });
        assert!(ok_cases >= 20);
    }

    #[test]
    fn gen_ranges_respected() {
        prop_check("usize_in bounds", 100, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            ensure(x >= lo && x <= hi, format!("{x} in [{lo},{hi}]"))
        });
    }
}
