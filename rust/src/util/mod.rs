//! Offline-environment substrates: PRNG, CLI parsing, JSON emit, stats,
//! and a small dense-linalg kit.  These replace `rand`, `clap`, `serde`,
//! and `nalgebra`, which are unavailable in this build environment.

pub mod alias;
pub mod args;
pub mod config;
pub mod fenwick;
pub mod json;
pub mod linalg;
pub mod rng;
pub mod stats;
pub mod wire;

pub use alias::AliasTable;
pub use args::Args;
pub use config::Config;
pub use fenwick::FenwickTree;
pub use json::JsonValue;
pub use rng::Rng;
pub use wire::{Unwire, Wire};
