//! Summary statistics and timing helpers for the bench harnesses.

use std::time::Instant;

/// Online mean/min/max/stddev accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Welford update.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Median (copies; fine for bench-sized inputs).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

/// Percentile via nearest-rank (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Measure `f` repeatedly: warmup runs then `iters` timed runs; returns
/// per-iteration seconds. The simple core of our criterion replacement.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn time_it_returns_iters() {
        let runs = time_it(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(runs.len(), 5);
        assert!(runs.iter().all(|&t| t >= 0.0));
    }
}
