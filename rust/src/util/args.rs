//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters parse on demand and report readable errors.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    flags.insert(body.to_string(), v);
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { flags, positional }
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Raw string flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed flag with default; panics with a readable message on a
    /// malformed value (CLI boundary, so panicking is the right behavior).
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|e| {
                panic!("--{key}: cannot parse {v:?}: {e}")
            }),
        }
    }

    /// Boolean flag: present (or `=true`) means true.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().unwrap_or_else(|e| {
                        panic!("--{key}: bad element {s:?}: {e}")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = args(&["--workers", "8", "--app=lasso"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("app"), Some("lasso"));
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = args(&["train", "--verbose", "--n", "10", "extra"]);
        assert_eq!(a.positional(), &["train", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or("n", 0usize), 10);
    }

    #[test]
    fn typed_defaults() {
        let a = args(&[]);
        assert_eq!(a.parse_or("rho", 0.1f64), 0.1);
        assert_eq!(a.str_or("out", "results"), "results");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--sizes", "10,20,30"]);
        assert_eq!(a.list_or::<usize>("sizes", &[]), vec![10, 20, 30]);
        assert_eq!(a.list_or("other", &[1usize]), vec![1]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_value_panics() {
        args(&["--n", "abc"]).parse_or("n", 0usize);
    }
}
