//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! All experiment randomness in the crate flows through this generator so
//! runs are exactly reproducible from a single seed (required for the
//! paper-figure harnesses and the property-testing framework).

/// xoshiro256++ generator (Blackman & Vigna), seeded with SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per worker) from this seed.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Raw generator state (KV checkpointing): restoring via
    /// [`Rng::from_state`] resumes the exact stream position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a previously captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased for n << 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates on an
    /// index map; O(k) memory when k << n via a hashmap of displaced slots).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut displaced = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vi = *displaced.get(&i).unwrap_or(&i);
            let vj = *displaced.get(&j).unwrap_or(&j);
            out.push(vj);
            displaced.insert(j, vi);
        }
        out
    }

    /// Weighted index sample: draws from the (unnormalized, non-negative)
    /// weight vector by inverse CDF.  O(n); callers with tight loops should
    /// keep their weights in a [`crate::util::FenwickTree`] instead.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed integer in [0, n) with exponent `alpha`, via
    /// precomputed-free rejection-less inverse CDF over a harmonic bound.
    /// Accurate enough for corpus synthesis.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // inverse-CDF on the continuous approximation
        let u = self.next_f64();
        if (alpha - 1.0).abs() < 1e-9 {
            let hn = (n as f64).ln();
            return ((u * hn).exp() - 1.0).min((n - 1) as f64) as usize;
        }
        let a = 1.0 - alpha;
        let hn = ((n as f64).powf(a) - 1.0) / a;
        let x = (1.0 + u * hn * a).powf(1.0 / a) - 1.0;
        (x as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_stays_in_range() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(13);
        for (n, k) in [(10, 10), (100, 7), (5, 3), (1, 1), (1000, 64)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k.min(n));
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), idx.len(), "duplicates for n={n} k={k}");
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Rng::new(17);
        let w = [0.01, 0.01, 10.0, 0.01];
        let hits = (0..1000).filter(|_| r.weighted(&w) == 2).count();
        assert!(hits > 900, "hits={hits}");
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(19);
        let n = 10_000;
        let lows = (0..n).filter(|_| r.zipf(1000, 1.1) < 10).count();
        assert!(lows > n / 4, "lows={lows}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
