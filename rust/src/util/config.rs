//! Experiment configuration files: a TOML-subset parser (serde/toml are
//! unavailable offline) supporting `[sections]`, `key = value` with
//! strings, numbers, booleans and comma lists, plus `#` comments.
//!
//! Used by the CLI's `--config` flag; configs/*.toml ship the canonical
//! experiment setups recorded in EXPERIMENTS.md.

use anyhow::{bail, Context};
use std::collections::BTreeMap;

/// Parsed configuration: section → key → raw value string.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut sections = BTreeMap::new();
        let mut current = String::new();
        sections.insert(String::new(), BTreeMap::new());
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("config line {}: {raw:?}", lineno + 1);
            if let Some(body) = line.strip_prefix('[') {
                let name = body.strip_suffix(']').with_context(ctx)?.trim();
                current = name.to_string();
                sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let value = v.trim().trim_matches('"').to_string();
                sections
                    .get_mut(&current)
                    .unwrap()
                    .insert(k.trim().to_string(), value);
            } else {
                bail!("{}: expected `key = value` or `[section]`", ctx());
            }
        }
        Ok(Config { sections })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    /// Section names (the unnamed root section is "").
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Raw lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn parse_or<T: std::str::FromStr>(
        &self,
        section: &str,
        key: &str,
        default: T,
    ) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|e| {
                panic!("config [{section}] {key}: cannot parse {v:?}: {e}")
            }),
        }
    }

    /// Boolean lookup.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("config [{section}] {key}: bad bool {v:?}"),
        }
    }

    /// Comma-list lookup.
    pub fn list_or<T: std::str::FromStr>(
        &self,
        section: &str,
        key: &str,
        default: &[T],
    ) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().unwrap_or_else(|e| {
                        panic!("config [{section}] {key}: bad item {s:?}: {e}")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment setup
app = "lasso"

[lasso]
features = 100000
lambda = 0.05
priority = true
sizes = 10, 20, 30

[cluster]
workers = 8
net = "40g"
"#;

    #[test]
    fn parses_sections_and_root() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("", "app"), Some("lasso"));
        assert_eq!(c.get("lasso", "features"), Some("100000"));
        assert_eq!(c.get("cluster", "net"), Some("40g"));
        assert_eq!(c.get("nope", "x"), None);
    }

    #[test]
    fn typed_lookups() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.parse_or("lasso", "features", 0usize), 100_000);
        assert_eq!(c.parse_or("lasso", "lambda", 0.0f32), 0.05);
        assert!(c.bool_or("lasso", "priority", false));
        assert_eq!(c.parse_or("lasso", "missing", 7u32), 7);
        assert_eq!(
            c.list_or::<usize>("lasso", "sizes", &[]),
            vec![10, 20, 30]
        );
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = Config::parse("# only comments\n\n  \n").unwrap();
        assert_eq!(c.sections().count(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("[unclosed").is_err());
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_typed_value_panics() {
        let c = Config::parse("x = abc").unwrap();
        c.parse_or("", "x", 0usize);
    }
}
