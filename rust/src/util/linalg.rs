//! Small dense linear algebra: column-major matrices, Cholesky solve.
//!
//! Needed by the GraphLab-ALS baseline (each ALS update solves a K×K
//! normal-equations system per row/column) — the O(K²)–O(K³) cost that
//! makes ALS collapse at large rank in the paper's Figure 8 (center).

/// Solve (A + lam I) x = b for symmetric positive-definite A (K×K,
/// row-major), in place via Cholesky.  Returns None if not SPD.
pub fn cholesky_solve(a: &[f64], lam: f64, b: &[f64]) -> Option<Vec<f64>> {
    let k = b.len();
    debug_assert_eq!(a.len(), k * k);
    // factor L L^T = A + lam I  (lower triangular, row-major)
    let mut l = vec![0.0f64; k * k];
    for i in 0..k {
        for j in 0..=i {
            let mut sum = a[i * k + j] + if i == j { lam } else { 0.0 };
            for p in 0..j {
                sum -= l[i * k + p] * l[j * k + p];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * k + j] = sum.sqrt();
            } else {
                l[i * k + j] = sum / l[j * k + j];
            }
        }
    }
    // forward solve L y = b
    let mut y = vec![0.0f64; k];
    for i in 0..k {
        let mut sum = b[i];
        for p in 0..i {
            sum -= l[i * k + p] * y[p];
        }
        y[i] = sum / l[i * k + i];
    }
    // back solve L^T x = y
    let mut x = vec![0.0f64; k];
    for i in (0..k).rev() {
        let mut sum = y[i];
        for p in (i + 1)..k {
            sum -= l[p * k + i] * x[p];
        }
        x[i] = sum / l[i * k + i];
    }
    Some(x)
}

/// Rank-1 accumulate: A += w w^T (row-major K×K).
pub fn syr(a: &mut [f64], w: &[f64]) {
    let k = w.len();
    debug_assert_eq!(a.len(), k * k);
    for i in 0..k {
        let wi = w[i];
        if wi == 0.0 {
            continue;
        }
        let row = &mut a[i * k..(i + 1) * k];
        for (j, &wj) in w.iter().enumerate() {
            row[j] += wi * wj;
        }
    }
}

/// Dense dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: better ILP and deterministic order.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = cholesky_solve(&a, 0.0, &[3.0, 4.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = M^T M + I for random-ish M is SPD
        let m = [1.0, 2.0, 0.5, -1.0, 0.3, 2.0, 1.5, 0.2, -0.7];
        let k = 3;
        let mut a = vec![0.0; 9];
        for i in 0..k {
            for j in 0..k {
                for p in 0..k {
                    a[i * k + j] += m[p * k + i] * m[p * k + j];
                }
            }
        }
        let x_true = [1.0, -2.0, 0.5];
        let mut b = vec![0.0; 3];
        for i in 0..k {
            for j in 0..k {
                b[i] += (a[i * k + j] + if i == j { 0.1 } else { 0.0 })
                    * x_true[j];
            }
        }
        let x = cholesky_solve(&a, 0.1, &b).unwrap();
        for (xi, ti) in x.iter().zip(x_true.iter()) {
            assert!((xi - ti).abs() < 1e-9, "{x:?}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![0.0, 2.0, 2.0, 0.0]; // indefinite
        assert!(cholesky_solve(&a, 0.0, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn syr_accumulates_outer_product() {
        let mut a = vec![0.0; 4];
        syr(&mut a, &[2.0, 3.0]);
        assert_eq!(a, vec![4.0, 6.0, 6.0, 9.0]);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.25).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }

    #[test]
    fn axpy_updates() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        axpy(2.0, &[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, 2.0, 1.0]);
    }
}
