//! Minimal little-endian wire format for KV checkpoints.
//!
//! Checkpoints are process-internal artifacts (taken and restored by the
//! same binary), so the format optimizes for exactness and simplicity:
//! fixed-width little-endian scalars, length-prefixed vectors, floats as
//! raw bit patterns (restores are bit-identical — the checkpoint
//! round-trip fingerprint test depends on it).  [`Unwire`] panics on
//! truncated or trailing bytes: a malformed checkpoint is a corrupted
//! artifact, not a user error to recover from.

/// Append-only checkpoint encoder.
#[derive(Debug, Default)]
pub struct Wire {
    buf: Vec<u8>,
}

impl Wire {
    pub fn new() -> Self {
        Wire { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Length-prefixed f32 vector (bit patterns, restore is bit-exact).
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }

    /// Length-prefixed u64 vector.
    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v);
        }
    }

    /// Length-prefixed u32 vector.
    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u32(v);
        }
    }

    /// Length-prefixed opaque blob (nesting sub-checkpoints).
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }
}

/// Checkpoint decoder over a byte slice; panics on malformed input.
#[derive(Debug)]
pub struct Unwire<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Unwire<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Unwire { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> &'a [u8] {
        assert!(
            self.pos + n <= self.buf.len(),
            "truncated checkpoint: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        out
    }

    pub fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    pub fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub fn f64(&mut self) -> f64 {
        f64::from_bits(self.u64())
    }

    pub fn f32(&mut self) -> f32 {
        f32::from_bits(self.u32())
    }

    pub fn f32s(&mut self) -> Vec<f32> {
        let n = self.u64() as usize;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn u64s(&mut self) -> Vec<u64> {
        let n = self.u64() as usize;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn u32s(&mut self) -> Vec<u32> {
        let n = self.u64() as usize;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn bytes(&mut self) -> &'a [u8] {
        let n = self.u64() as usize;
        self.take(n)
    }

    /// Assert every byte was consumed (trailing garbage = corruption).
    pub fn done(&self) {
        assert_eq!(
            self.pos,
            self.buf.len(),
            "checkpoint has {} trailing bytes",
            self.buf.len() - self.pos
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars_and_vectors() {
        let mut w = Wire::new();
        w.put_u64(u64::MAX);
        w.put_u32(7);
        w.put_f64(-0.0);
        w.put_f32(f32::MIN_POSITIVE);
        w.put_f32s(&[1.5, -2.25, 0.1]);
        w.put_u64s(&[3, 1, 4]);
        w.put_u32s(&[]);
        w.put_bytes(b"blob");
        let bytes = w.into_bytes();
        let mut r = Unwire::new(&bytes);
        assert_eq!(r.u64(), u64::MAX);
        assert_eq!(r.u32(), 7);
        assert_eq!(r.f64().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f32(), f32::MIN_POSITIVE);
        assert_eq!(
            r.f32s().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            [1.5f32, -2.25, 0.1].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(r.u64s(), vec![3, 1, 4]);
        assert_eq!(r.u32s(), Vec::<u32>::new());
        assert_eq!(r.bytes(), b"blob");
        r.done();
    }

    #[test]
    #[should_panic(expected = "truncated checkpoint")]
    fn truncation_panics() {
        let mut w = Wire::new();
        w.put_u64(1);
        let bytes = w.into_bytes();
        let mut r = Unwire::new(&bytes[..4]);
        let _ = r.u64();
    }

    #[test]
    #[should_panic(expected = "trailing bytes")]
    fn trailing_bytes_panic() {
        let mut w = Wire::new();
        w.put_u64(1);
        w.put_u64(2);
        let bytes = w.into_bytes();
        let mut r = Unwire::new(&bytes);
        let _ = r.u64();
        r.done();
    }
}
