//! Minimal JSON value + writer (serde is unavailable offline).
//!
//! Used by the metrics recorders and figure harnesses to emit structured
//! results that downstream tooling (or a human) can consume.  Writing only —
//! the one structured input we parse (the artifact manifest) uses a simpler
//! line format handled in [`crate::runtime::manifest`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (ordered maps for deterministic output).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Object builder entry point.
    pub fn obj() -> JsonObjBuilder {
        JsonObjBuilder(BTreeMap::new())
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            JsonValue::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            JsonValue::Str(s) => Self::write_escaped(s, out),
            JsonValue::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_escaped(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }
}

impl From<f64> for JsonValue {
    fn from(x: f64) -> Self {
        JsonValue::Num(x)
    }
}
impl From<usize> for JsonValue {
    fn from(x: usize) -> Self {
        JsonValue::Num(x as f64)
    }
}
impl From<u64> for JsonValue {
    fn from(x: u64) -> Self {
        JsonValue::Num(x as f64)
    }
}
impl From<i64> for JsonValue {
    fn from(x: i64) -> Self {
        JsonValue::Num(x as f64)
    }
}
impl From<bool> for JsonValue {
    fn from(x: bool) -> Self {
        JsonValue::Bool(x)
    }
}
impl From<&str> for JsonValue {
    fn from(x: &str) -> Self {
        JsonValue::Str(x.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(x: String) -> Self {
        JsonValue::Str(x)
    }
}
impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(xs: Vec<T>) -> Self {
        JsonValue::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Fluent object builder: `JsonValue::obj().field("a", 1).build()`.
pub struct JsonObjBuilder(BTreeMap<String, JsonValue>);

impl JsonObjBuilder {
    pub fn field<V: Into<JsonValue>>(mut self, key: &str, v: V) -> Self {
        self.0.insert(key.to_string(), v.into());
        self
    }

    pub fn build(self) -> JsonValue {
        JsonValue::Obj(self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(JsonValue::Num(3.0).to_json(), "3");
        assert_eq!(JsonValue::Num(3.5).to_json(), "3.5");
        assert_eq!(JsonValue::Bool(true).to_json(), "true");
        assert_eq!(JsonValue::Null.to_json(), "null");
        assert_eq!(JsonValue::Num(f64::NAN).to_json(), "null");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(
            JsonValue::Str("a\"b\\c\nd".into()).to_json(),
            r#""a\"b\\c\nd""#
        );
    }

    #[test]
    fn nested_object() {
        let v = JsonValue::obj()
            .field("name", "fig8")
            .field("sizes", vec![10usize, 20])
            .field(
                "inner",
                JsonValue::obj().field("ok", true).build(),
            )
            .build();
        assert_eq!(
            v.to_json(),
            r#"{"inner":{"ok":true},"name":"fig8","sizes":[10,20]}"#
        );
    }
}
