//! Fenwick (binary indexed) tree over non-negative weights with O(log n)
//! point updates and O(log n) weighted sampling by prefix-sum search.
//!
//! This is the data structure behind the dynamic Lasso scheduler: the
//! paper's c_j ∝ |δβ_j| + η distribution changes at every pull, and the
//! naive O(J) inverse-CDF draw was the coordinator's top hot spot at
//! J = 10⁴–10⁸ (see EXPERIMENTS.md §Perf).

/// Fenwick tree storing f64 weights, 0-indexed externally.
#[derive(Debug, Clone)]
pub struct FenwickTree {
    tree: Vec<f64>,
    values: Vec<f64>,
    /// Largest power of two ≤ len (for the descend-search; 1 when the
    /// tree is empty, but `sample` guards the empty case before using it).
    top: usize,
}

impl FenwickTree {
    /// Build from initial weights (O(n)).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        let mut tree = vec![0.0; n + 1];
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            tree[i + 1] += w;
            let parent = (i + 1) + ((i + 1) & (i + 1).wrapping_neg());
            if parent <= n {
                let v = tree[i + 1];
                tree[parent] += v;
            }
        }
        let mut top = 1;
        while top * 2 <= n {
            top *= 2;
        }
        FenwickTree { tree, values: weights.to_vec(), top }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Current weight of index i.
    pub fn get(&self, i: usize) -> f64 {
        self.values[i]
    }

    /// Set index i to weight w (O(log n)).
    pub fn set(&mut self, i: usize, w: f64) {
        debug_assert!(w >= 0.0);
        let delta = w - self.values[i];
        self.values[i] = w;
        let mut idx = i + 1;
        while idx < self.tree.len() {
            self.tree[idx] += delta;
            idx += idx & idx.wrapping_neg();
        }
    }

    /// Total weight (O(1)).
    pub fn total(&self) -> f64 {
        self.prefix_sum(self.len())
    }

    /// Sum of weights [0, i) (O(log n)).
    pub fn prefix_sum(&self, i: usize) -> f64 {
        let mut idx = i.min(self.len());
        let mut sum = 0.0;
        while idx > 0 {
            sum += self.tree[idx];
            idx -= idx & idx.wrapping_neg();
        }
        sum
    }

    /// Find the smallest index i with prefix_sum(i+1) > target — i.e. draw
    /// from the categorical distribution when `target ∈ [0, total)`.
    /// O(log n) descend.
    ///
    /// A `target >= total` (possible upstream via f64 rounding in
    /// `rng.next_f64() * total`, especially after a without-replacement
    /// draw has zeroed weights) lands past the end; instead of blindly
    /// clamping to `len()-1` — which may be a zero-weight bucket and, in
    /// the scheduler, an already-drawn candidate — we walk back to the
    /// nearest positive-weight index.  With all weights zero, returns 0;
    /// an empty tree also returns 0 (there is nothing to index, and
    /// `len() - 1` would underflow).
    pub fn sample(&self, target: f64) -> usize {
        if self.is_empty() {
            return 0;
        }
        let mut idx = 0usize; // 1-based cursor into tree
        let mut remaining = target;
        let mut mask = self.top;
        while mask > 0 {
            let next = idx + mask;
            if next < self.tree.len() && self.tree[next] <= remaining {
                remaining -= self.tree[next];
                idx = next;
            }
            mask >>= 1;
        }
        let mut i = idx.min(self.len() - 1); // idx is 0-based result
        while i > 0 && self.values[i] <= 0.0 {
            i -= 1;
        }
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prefix_sums_match_naive() {
        let w = [1.0, 2.0, 0.0, 4.0, 0.5, 3.0, 1.5];
        let t = FenwickTree::new(&w);
        let mut acc = 0.0;
        for i in 0..=w.len() {
            assert!((t.prefix_sum(i) - acc).abs() < 1e-12, "prefix {i}");
            if i < w.len() {
                acc += w[i];
            }
        }
        assert!((t.total() - acc).abs() < 1e-12);
    }

    #[test]
    fn set_updates_sums() {
        let mut t = FenwickTree::new(&[1.0; 8]);
        t.set(3, 5.0);
        t.set(0, 0.0);
        // [0,1,1,5,1,1,1,1] sums to 11
        assert!((t.total() - 11.0).abs() < 1e-12);
        assert!((t.prefix_sum(4) - 7.0).abs() < 1e-12);
        assert_eq!(t.get(3), 5.0);
    }

    #[test]
    fn sample_hits_correct_bucket() {
        let t = FenwickTree::new(&[1.0, 2.0, 3.0]);
        assert_eq!(t.sample(0.0), 0);
        assert_eq!(t.sample(0.99), 0);
        assert_eq!(t.sample(1.0), 1);
        assert_eq!(t.sample(2.99), 1);
        assert_eq!(t.sample(3.0), 2);
        assert_eq!(t.sample(5.99), 2);
    }

    #[test]
    fn sample_skips_zero_weight_buckets() {
        let t = FenwickTree::new(&[0.0, 0.0, 1.0, 0.0]);
        for target in [0.0, 0.5, 0.999] {
            assert_eq!(t.sample(target), 2);
        }
    }

    #[test]
    fn sample_overshoot_lands_on_positive_weight() {
        // regression: after without-replacement draws zero some weights,
        // target == total (f64 rounding upper edge) used to clamp to the
        // last index even when that bucket had zero weight — returning an
        // already-drawn candidate.
        let mut t = FenwickTree::new(&[2.0, 3.0, 4.0, 1.0]);
        t.set(3, 0.0); // "drawn" candidate
        let total = t.total();
        assert_eq!(t.sample(total), 2, "must walk back past the zero bucket");
        assert_eq!(t.sample(total + 1.0), 2);
        // trailing run of zeros
        let t = FenwickTree::new(&[0.0, 5.0, 0.0, 0.0]);
        assert_eq!(t.sample(t.total()), 1);
        // all-zero tree: degenerate draw pins to 0 instead of len-1
        let t = FenwickTree::new(&[0.0; 4]);
        assert_eq!(t.sample(0.0), 0);
        assert_eq!(t.sample(1.0), 0);
    }

    #[test]
    fn empty_tree_sample_does_not_underflow() {
        // regression: `idx.min(self.len() - 1)` underflowed on an empty
        // tree; sample must return 0 for any target instead of panicking
        let t = FenwickTree::new(&[]);
        assert_eq!(t.len(), 0);
        assert!(t.is_empty());
        assert_eq!(t.sample(0.0), 0);
        assert_eq!(t.sample(1.0), 0);
        assert_eq!(t.total(), 0.0);
        assert_eq!(t.prefix_sum(0), 0.0);
    }

    #[test]
    fn top_is_largest_power_of_two_at_most_len() {
        // regression: the `top` doc comment claimed the *smallest* power
        // of two ≥ len; the descend-search actually needs the largest
        // power of two ≤ len (a too-large top would step past the tree)
        for (n, want) in
            [(1usize, 1usize), (2, 2), (3, 2), (4, 4), (5, 4), (8, 8), (9, 8)]
        {
            let w = vec![1.0; n];
            let t = FenwickTree::new(&w);
            assert_eq!(t.top, want, "n={n}");
            assert!(t.top <= n);
            assert!(t.top * 2 > n);
        }
    }

    #[test]
    fn sample_distribution_matches_weights() {
        let w = [1.0, 4.0, 0.0, 5.0];
        let t = FenwickTree::new(&w);
        let mut rng = Rng::new(7);
        let n = 40_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[t.sample(rng.next_f64() * t.total())] += 1;
        }
        assert_eq!(counts[2], 0);
        for (i, &wi) in w.iter().enumerate() {
            let want = wi / 10.0;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn non_power_of_two_sizes() {
        for n in [1usize, 2, 3, 5, 17, 100, 1023] {
            let w: Vec<f64> = (0..n).map(|i| (i % 7) as f64 + 0.5).collect();
            let t = FenwickTree::new(&w);
            let total = t.total();
            let naive: f64 = w.iter().sum();
            assert!((total - naive).abs() < 1e-9, "n={n}");
            // last bucket reachable
            assert_eq!(t.sample(total - 1e-9), n - 1);
        }
    }

    #[test]
    fn matches_linear_weighted_sampling() {
        // same RNG stream, same draws as Rng::weighted
        let w: Vec<f64> = (0..257).map(|i| ((i * 31) % 11) as f64).collect();
        let t = FenwickTree::new(&w);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let target = rng.next_f64() * t.total();
            let idx = t.sample(target);
            // verify bracketing
            assert!(t.prefix_sum(idx) <= target + 1e-9);
            assert!(t.prefix_sum(idx + 1) > target - 1e-9);
        }
    }
}
