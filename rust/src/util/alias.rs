//! Alias tables (Vose's method): O(n) build, O(1) categorical draws.
//!
//! This is the sampling primitive behind the Metropolis–Hastings LDA
//! kernel (`--sampler mh`): LightLDA-style proposal distributions are
//! frozen into alias tables once per slice lease, then each token draws
//! from them in constant time regardless of K (PAPERS.md: *LightLDA*,
//! *Model-Parallel Inference for Big Topic Models*).

use crate::util::Rng;

/// A frozen categorical distribution supporting O(1) draws.
///
/// Built with Vose's alias method: every bucket i holds a threshold
/// `prob[i]` and an alias; a draw picks a uniform bucket, then returns
/// either the bucket or its alias depending on a uniform threshold test.
/// Weight normalization happens at build time, so draws never divide.
#[derive(Debug, Clone, Default)]
pub struct AliasTable {
    /// Per-bucket acceptance threshold in [0, 1].
    prob: Vec<f32>,
    /// Per-bucket alias target (the overfull donor that topped it up).
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).  The
    /// total weight must be positive unless `weights` is empty; callers
    /// with a possibly-zero-mass component guard with mass checks before
    /// drawing (an all-zero table has no valid categorical to draw from).
    pub fn new(weights: &[f32]) -> Self {
        let n = weights.len();
        if n == 0 {
            return AliasTable { prob: Vec::new(), alias: Vec::new() };
        }
        // f64 accumulation: the table is built once per lease over up to
        // K (or nnz) weights, and a drifted total skews every threshold
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        assert!(
            total > 0.0,
            "alias table needs positive total weight (got {total})"
        );
        let scale = n as f64 / total;
        let mut prob = vec![0.0f32; n];
        let mut alias: Vec<u32> = (0..n as u32).collect();
        // scaled weights: mean exactly 1 by construction
        let mut scaled: Vec<f64> =
            weights.iter().map(|&w| w as f64 * scale).collect();
        // Vose worklists: indices below / at-or-above the mean
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            prob[s as usize] = scaled[s as usize] as f32;
            alias[s as usize] = l;
            // donor keeps its remainder after topping the small bucket up
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // leftovers (either list) sit at exactly 1 up to rounding: they
        // self-alias with threshold 1
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one index from the frozen categorical (O(1): one bounded
    /// uniform + one f32 uniform against the bucket threshold).
    pub fn draw(&self, rng: &mut Rng) -> usize {
        debug_assert!(!self.is_empty(), "draw from an empty alias table");
        let i = rng.below(self.prob.len() as u64) as usize;
        if rng.next_f32() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Total-variation distance between the empirical draw distribution
    /// and the normalized weights.
    fn tv_distance(weights: &[f32], seed: u64, n_draws: usize) -> f64 {
        let table = AliasTable::new(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0u64; weights.len()];
        for _ in 0..n_draws {
            counts[table.draw(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        0.5 * weights
            .iter()
            .zip(&counts)
            .map(|(&w, &c)| {
                (w as f64 / total - c as f64 / n_draws as f64).abs()
            })
            .sum::<f64>()
    }

    #[test]
    fn draws_match_weights_in_tv_distance() {
        // the ISSUE's distributional-equivalence bound: alias draws vs the
        // exact categorical across seeded trials, including zero-weight
        // buckets and a heavy head (the LDA sparse-proposal shape)
        let weights = [
            5.0f32, 0.0, 1.0, 0.25, 8.0, 0.0, 2.5, 1.0, 0.5, 3.0, 0.0, 7.25,
        ];
        for seed in [3u64, 17, 91] {
            let tv = tv_distance(&weights, seed, 200_000);
            assert!(tv < 0.01, "seed {seed}: tv distance {tv}");
        }
    }

    #[test]
    fn zero_weight_buckets_are_never_drawn() {
        let weights = [0.0f32, 4.0, 0.0, 1.0, 0.0];
        let table = AliasTable::new(&weights);
        let mut rng = Rng::new(11);
        for _ in 0..50_000 {
            let i = table.draw(&mut rng);
            assert!(weights[i] > 0.0, "drew zero-weight bucket {i}");
        }
    }

    #[test]
    fn single_bucket_always_drawn() {
        let table = AliasTable::new(&[0.125f32]);
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(table.draw(&mut rng), 0);
        }
    }

    #[test]
    fn empty_table_builds_and_reports_empty() {
        let table = AliasTable::new(&[]);
        assert!(table.is_empty());
        assert_eq!(table.len(), 0);
    }

    #[test]
    #[should_panic(expected = "positive total weight")]
    fn all_zero_weights_panic() {
        let _ = AliasTable::new(&[0.0f32, 0.0]);
    }

    #[test]
    fn uniform_weights_stay_uniform() {
        let weights = vec![1.0f32; 400];
        let tv = tv_distance(&weights, 23, 400_000);
        assert!(tv < 0.05, "tv distance {tv}");
    }

    #[test]
    fn draws_are_deterministic_given_the_seed() {
        let weights = [1.0f32, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let a: Vec<usize> = {
            let mut rng = Rng::new(77);
            (0..64).map(|_| table.draw(&mut rng)).collect()
        };
        let b: Vec<usize> = {
            let mut rng = Rng::new(77);
            (0..64).map(|_| table.draw(&mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}
