//! Simulated-cluster substrate.
//!
//! The paper evaluates on two physical clusters (128× 2-core @1Gbps, 9×
//! 16-core @40Gbps).  We simulate: each STRADS worker is an OS thread with
//! a mailbox, the star topology's communication cost is modelled by
//! [`network::NetworkModel`] and charged to a **virtual cluster clock**
//! ([`clock::VirtualClock`]), and per-machine model-memory residency is
//! tracked by [`memory::MemoryTracker`] (paper Fig 3).
//!
//! The virtual clock is what the figure harnesses report: per-round time =
//! max over workers of (measured compute time + modelled link time).  This
//! makes the scalability curves (Fig 10) independent of how many physical
//! cores this build machine happens to have.
//!
//! Timing is pluggable ([`exec::ExecBackend`]): the default
//! [`exec::SimBackend`] models the cluster clock as above, while
//! [`exec::ThreadBackend`] (`--backend threads`) realizes stragglers as
//! real worker-thread sleeps and reports measured wall-clock instead —
//! same protocol, same app calls, physically-real concurrency.

pub mod clock;
pub mod exec;
pub mod memory;
pub mod network;
pub mod pool;

pub use clock::{StragglerModel, VirtualClock};
pub use exec::{make_backend, BackendKind, ExecBackend};
pub use memory::MemoryTracker;
pub use network::{HandoffJitter, NetFaultPlan, NetworkConfig, NetworkModel};
pub use pool::{router_spin_ms, ForwardQueue, PendingRound, WorkerPool};
