//! Per-machine model-memory accounting (paper Fig 3).
//!
//! Workers report the resident bytes of their *model state* (word-topic
//! slices, factor panels, coefficient caches — not the immutable data
//! shard, which both STRADS and the data-parallel baselines partition the
//! same way).  A configurable per-machine capacity reproduces the paper's
//! "baseline could not run this model size" failures.

/// Tracks per-worker model bytes and enforces an optional capacity.
#[derive(Debug, Clone)]
pub struct MemoryTracker {
    per_worker: Vec<u64>,
    capacity: Option<u64>,
}

/// Error raised when a worker would exceed machine memory.
#[derive(Debug, Clone, PartialEq)]
pub struct OutOfMemory {
    pub worker: usize,
    pub needed: u64,
    pub capacity: u64,
}

impl std::fmt::Display for OutOfMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "worker {} needs {} bytes of model memory (capacity {})",
            self.worker, self.needed, self.capacity
        )
    }
}

impl std::error::Error for OutOfMemory {}

impl MemoryTracker {
    pub fn new(n_workers: usize, capacity: Option<u64>) -> Self {
        MemoryTracker { per_worker: vec![0; n_workers], capacity }
    }

    /// Set worker p's current model residency (absolute, not delta).
    pub fn set(&mut self, worker: usize, bytes: u64) -> Result<(), OutOfMemory> {
        self.per_worker[worker] = bytes;
        match self.capacity {
            Some(cap) if bytes > cap => {
                Err(OutOfMemory { worker, needed: bytes, capacity: cap })
            }
            _ => Ok(()),
        }
    }

    pub fn get(&self, worker: usize) -> u64 {
        self.per_worker[worker]
    }

    /// Largest per-machine residency — the Fig 3 y-axis.
    pub fn max_per_machine(&self) -> u64 {
        self.per_worker.iter().copied().max().unwrap_or(0)
    }

    /// Mean per-machine residency.
    pub fn mean_per_machine(&self) -> f64 {
        if self.per_worker.is_empty() {
            0.0
        } else {
            self.per_worker.iter().sum::<u64>() as f64
                / self.per_worker.len() as f64
        }
    }

    pub fn total(&self) -> u64 {
        self.per_worker.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_max_and_mean() {
        let mut m = MemoryTracker::new(3, None);
        m.set(0, 100).unwrap();
        m.set(1, 300).unwrap();
        m.set(2, 200).unwrap();
        assert_eq!(m.max_per_machine(), 300);
        assert!((m.mean_per_machine() - 200.0).abs() < 1e-12);
        assert_eq!(m.total(), 600);
    }

    #[test]
    fn capacity_enforced() {
        let mut m = MemoryTracker::new(2, Some(250));
        assert!(m.set(0, 200).is_ok());
        let err = m.set(1, 300).unwrap_err();
        assert_eq!(err.worker, 1);
        assert_eq!(err.capacity, 250);
    }

    #[test]
    fn set_is_absolute_not_delta() {
        let mut m = MemoryTracker::new(1, None);
        m.set(0, 500).unwrap();
        m.set(0, 100).unwrap();
        assert_eq!(m.get(0), 100);
    }
}
