//! Pluggable execution backends: how one engine round loop turns measured
//! worker compute into *run time*.
//!
//! The coordinator's round pipelines (BSP / SSP / rotation,
//! `coordinator::engine`) are written once against [`ExecBackend`].  The
//! backend decides two things:
//!
//! * **Physical realization** — whether a worker's push runs for its
//!   natural CPU time ([`SimBackend`]) or is *physically* slowed down to
//!   its straggler multiple by sleeping on the worker thread
//!   ([`ThreadBackend`]): under threads a 4× straggler really does hold
//!   its round 4× longer, so the blocking data plane
//!   ([`crate::kvstore::SliceRouter`] / [`crate::cluster::ForwardQueue`])
//!   experiences true contention and real condvar waits.
//! * **Time resolution** — how the run clock advances per collected
//!   round.  [`SimBackend`] replays the measured seconds through the
//!   virtual-time model (per-worker availability, per-slice handoff
//!   gates, [`replay_queue`]); [`ThreadBackend`] reads the wall clock —
//!   the pipeline overlap is physically real, so no model is needed.
//!
//! **Equivalence contract** (README, execution-mode section): both
//! backends drive the *same* app calls through the *same* grant → take →
//! forward → settle protocol.  At `depth: 1` / `QueueOrder::Strict` /
//! `SkipPolicy::Never` the call sequence is timing-independent, so a
//! threaded run produces **bit-identical model state** to the simulated
//! run on the same seed (asserted in `tests/threads_backend.rs`); deeper
//! or reordered runs stay invariant-identical (disjointness, fork-free
//! chains, token conservation) while their timing-dependent choices may
//! legitimately differ.  Only the meaning of the reported times changes:
//! `virtual_secs` is modelled under `Sim` and tracks `wall_secs` under
//! `Threads`.
//!
//! Workers are real OS threads under *both* backends (see
//! [`crate::cluster::WorkerPool`]); what `Sim` simulates is only the
//! cluster's timing.  Compute is always measured as per-thread CPU time,
//! so injected straggler sleeps never contaminate the measured seconds —
//! the stats stay comparable across backends.

use crate::cluster::{HandoffJitter, NetFaultPlan, StragglerModel};
use crate::scheduler::rotation::QueueOrder;
use crate::trace::{Event, TraceBuffer};
use std::sync::Arc;

/// Which execution backend a run uses (`RunConfig::backend`,
/// CLI `--backend sim|threads`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Virtual-time simulator (default): timing is modelled, trajectories
    /// are bit-identical to the pre-backend engine.
    #[default]
    Sim,
    /// Real concurrency: stragglers are realized as worker-thread sleeps
    /// and the run clock is the wall clock.
    Threads,
}

impl std::str::FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "sim" => Ok(BackendKind::Sim),
            "threads" => Ok(BackendKind::Threads),
            other => Err(format!("unknown backend '{other}' (sim|threads)")),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Sim => write!(f, "sim"),
            BackendKind::Threads => write!(f, "threads"),
        }
    }
}

/// What the engine observed collecting one SSP/BSP-shaped round.
pub struct RoundObs<'a> {
    pub round: u64,
    /// Run-clock timestamp of the round's dispatch
    /// ([`ExecBackend::on_dispatch`]'s return value).
    pub dispatched_at: f64,
    /// Per-worker compute seconds, already passed through
    /// [`ExecBackend::account_compute`].
    pub compute_secs: &'a [f64],
    /// Network seconds charged since the previous collect.
    pub comm_secs: f64,
    /// Measured coordinator `pull` seconds.
    pub pull_secs: f64,
    /// Wall seconds since the run began (threaded resolution).
    pub wall_now: f64,
}

/// What the engine observed collecting one rotation round: per-worker
/// queues of `(slice_id, seconds)` legs in granted order, plus the
/// discipline and jitter the virtual replay needs.
pub struct RotObs<'a> {
    pub round: u64,
    pub dispatched_at: f64,
    pub timed_legs: &'a [Vec<(usize, f64)>],
    pub comm_secs: f64,
    pub pull_secs: f64,
    pub order: QueueOrder,
    pub jitter: &'a HandoffJitter,
    /// The run's lossy-transport plan: the sim backend charges each leg's
    /// forward the latency the redelivery protocol *would* pay to mask
    /// the plan's drops/delays ([`NetFaultPlan::virtual_latency`]), so
    /// virtual time degrades with the fault rates just as wall time does
    /// under threads.  An empty plan charges exactly 0.0 (bit-identical).
    pub net: &'a NetFaultPlan,
    /// Wall seconds since the run began (threaded resolution).
    pub wall_now: f64,
}

/// One resolved round: where the run clock lands and how much barrier
/// wait the pipeline hid relative to BSP (recorded into
/// [`crate::metrics::SspStats`]; negative values clamp there).
pub struct RoundOutcome {
    pub now: f64,
    pub wait_saved_secs: f64,
}

/// One execution backend: physical realization of straggler skew on the
/// worker threads plus per-round time resolution.  Constructed per run
/// via [`make_backend`]; all state (the run clock, per-worker/per-slice
/// availability) lives behind `&mut self`.
///
/// # Examples
///
/// The simulated backend replays the SSP availability model — a dispatch
/// at 0.5s with workers computing 1s and 3s, 0.25s of comm and 0.25s of
/// pull resolves to `0.5 + 3.0 + 0.25 + 0.25`:
///
/// ```
/// use strads::cluster::exec::{make_backend, BackendKind, RoundObs};
/// use strads::cluster::StragglerModel;
///
/// let mut b = make_backend(BackendKind::Sim, StragglerModel::None, 0.0);
/// b.begin_run(0.0, 2, 0);
/// let at = b.on_dispatch(0.5, 0.0);
/// assert_eq!(at, 0.5);
/// let out = b.resolve_round(&RoundObs {
///     round: 0,
///     dispatched_at: at,
///     compute_secs: &[1.0, 3.0],
///     comm_secs: 0.25,
///     pull_secs: 0.25,
///     wall_now: 0.0,
/// });
/// assert!((out.now - 4.0).abs() < 1e-12);
/// // a BSP barrier would have charged exactly the same here, so the
/// // pipeline hid nothing:
/// assert!(out.wait_saved_secs.abs() < 1e-12);
/// ```
///
/// The threaded backend realizes skew physically and resolves against the
/// wall clock instead:
///
/// ```
/// use strads::cluster::exec::{make_backend, BackendKind, RoundObs};
/// use strads::cluster::StragglerModel;
///
/// let mut b = make_backend(
///     BackendKind::Threads,
///     StragglerModel::Fixed(vec![4.0, 1.0]),
///     0.0,
/// );
/// b.begin_run(10.0, 2, 0);
/// // worker 0's push really sleeps to 4x its measured time:
/// assert_eq!(b.physical_slowdown(0, 0, 2), 4.0);
/// let at = b.on_dispatch(0.0, 0.125);
/// let out = b.resolve_round(&RoundObs {
///     round: 0,
///     dispatched_at: at,
///     compute_secs: &[0.4, 0.1],
///     comm_secs: 0.0,
///     pull_secs: 0.0,
///     wall_now: 0.5,
/// });
/// // the run clock continues from where the virtual clock stood and
/// // advances by measured wall time:
/// assert!((out.now - 10.5).abs() < 1e-12);
/// ```
pub trait ExecBackend {
    fn kind(&self) -> BackendKind;

    /// Reset the backend's clock state at the top of a run: `now` is the
    /// engine's virtual-clock reading (runs accumulate), `n_workers` /
    /// `n_slices` size the availability timelines (`n_slices` is 0 for
    /// non-rotation runs).
    fn begin_run(&mut self, now: f64, n_workers: usize, n_slices: usize);

    /// Factor by which worker `worker`'s push is physically slowed this
    /// round (the push sleeps until `measured × factor` has elapsed).
    /// 1.0 under [`SimBackend`] — skew there is applied to the *accounted*
    /// seconds only, never to the physical threads.
    fn physical_slowdown(&self, worker: usize, round: u64, n_workers: usize) -> f64;

    /// Minimum physical seconds one push occupies under the threaded
    /// backend (0.0 = off).  Benches set this so wall-clock arm orderings
    /// rest on hundreds of milliseconds of injected compute rather than
    /// scheduler noise at smoke scale.
    fn pace_floor_secs(&self) -> f64 {
        0.0
    }

    /// Fold the straggler model into the *accounted* per-worker seconds
    /// (both backends apply the same scaling, so stats stay comparable:
    /// the simulator models the skew it never ran, the threaded backend
    /// re-applies the skew its sleeps realized but its CPU-time
    /// measurement deliberately excluded).
    fn account_compute(&self, secs: &mut [f64], round: u64);

    /// Advance the run clock over one dispatch (`schedule_secs` of
    /// coordinator work) and return the timestamp the dispatched tasks
    /// cannot start before.
    fn on_dispatch(&mut self, schedule_secs: f64, wall_now: f64) -> f64;

    /// Resolve one collected SSP-shaped round to a new run-clock time.
    fn resolve_round(&mut self, obs: &RoundObs) -> RoundOutcome;

    /// Resolve one collected rotation round.  Pushes each worker's
    /// handoff-wait seconds (idle time on not-yet-landed slices) into
    /// `handoff_waits`, worker-indexed — zeros under [`ThreadBackend`],
    /// where blocking is measured on the data plane instead
    /// ([`crate::kvstore::SliceRouter::block_secs`] →
    /// `SspStats::router_block_secs`).
    fn resolve_rot_round(
        &mut self,
        obs: &RotObs,
        handoff_waits: &mut Vec<f64>,
    ) -> RoundOutcome;

    /// Current run-clock reading.
    fn now(&self) -> f64;

    /// Install a trace sink for this run: each resolved round then emits a
    /// [`Event::Resolve`] with the backend's clock reading.  Resolve
    /// events are timing diagnostics — excluded from fingerprints (wall
    /// time is never bit-reproducible) and never replayed.  Default: drop
    /// the sink (backends without clock-trace support).
    fn install_trace(&mut self, _sink: Arc<TraceBuffer>) {}
}

/// Construct the backend for one run.  `pace_floor_secs` is the threaded
/// pacing floor (ignored by `Sim`); the `STRADS_THREADS_PACE_MS` env var
/// raises it for CLI runs.
pub fn make_backend(
    kind: BackendKind,
    straggler: StragglerModel,
    pace_floor_secs: f64,
) -> Box<dyn ExecBackend> {
    match kind {
        BackendKind::Sim => Box::new(SimBackend::new(straggler)),
        BackendKind::Threads => {
            Box::new(ThreadBackend::new(straggler, pace_floor_secs))
        }
    }
}

/// The virtual-time simulator: the engine's original clock arithmetic,
/// extracted verbatim — per-worker availability timestamps for SSP, plus
/// the per-slice handoff timeline ([`replay_queue`]) for rotation.
/// Trajectories and reported virtual times are bit-identical to the
/// pre-backend engine.
pub struct SimBackend {
    straggler: StragglerModel,
    /// Coordinator's absolute virtual time.
    coord_now: f64,
    /// Per-worker availability timestamps.
    worker_free: Vec<f64>,
    /// Per-slice availability (rotation): when the slice's most recent
    /// sweep finished — i.e. when its holder forwarded it.  A worker's
    /// sweep of slice `a` cannot start before `slice_ready[a]`; other
    /// slices of the same queue are *not* gated on it, which is what lets
    /// a U > P worker sample one slice while another is still in flight.
    slice_ready: Vec<f64>,
    /// Trace sink for per-round `Resolve` events (None = tracing off).
    trace: Option<Arc<TraceBuffer>>,
}

impl SimBackend {
    pub fn new(straggler: StragglerModel) -> Self {
        SimBackend {
            straggler,
            coord_now: 0.0,
            worker_free: Vec::new(),
            slice_ready: Vec::new(),
            trace: None,
        }
    }
}

impl ExecBackend for SimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Sim
    }

    fn begin_run(&mut self, now: f64, n_workers: usize, n_slices: usize) {
        self.coord_now = now;
        self.worker_free = vec![now; n_workers];
        self.slice_ready = vec![now; n_slices];
    }

    fn physical_slowdown(&self, _worker: usize, _round: u64, _n: usize) -> f64 {
        1.0
    }

    fn account_compute(&self, secs: &mut [f64], round: u64) {
        self.straggler.scale(secs, round);
    }

    fn on_dispatch(&mut self, schedule_secs: f64, _wall_now: f64) -> f64 {
        self.coord_now += schedule_secs;
        self.coord_now
    }

    fn resolve_round(&mut self, obs: &RoundObs) -> RoundOutcome {
        // a worker started this round as soon as both it and the dispatch
        // were ready
        let mut finish_max = 0.0f64;
        let mut compute_max = 0.0f64;
        for (p, &secs) in obs.compute_secs.iter().enumerate() {
            let start = self.worker_free[p].max(obs.dispatched_at);
            let finish = start + secs;
            self.worker_free[p] = finish;
            finish_max = finish_max.max(finish);
            compute_max = compute_max.max(secs);
        }
        let before = self.coord_now;
        self.coord_now = self.coord_now.max(finish_max + obs.comm_secs) + obs.pull_secs;
        if let Some(sink) = &self.trace {
            sink.push(Event::Resolve {
                round: obs.round,
                now_bits: self.coord_now.to_bits(),
            });
        }
        // what a BSP barrier would have added on top of the pipeline
        let bsp_increment = compute_max + obs.comm_secs + obs.pull_secs;
        RoundOutcome {
            now: self.coord_now,
            wait_saved_secs: bsp_increment - (self.coord_now - before),
        }
    }

    fn resolve_rot_round(
        &mut self,
        obs: &RotObs,
        handoff_waits: &mut Vec<f64>,
    ) -> RoundOutcome {
        // replay each worker's queue against the per-slice availability
        // timeline: a leg starts when the worker reaches it AND the
        // slice's previous holder's handoff has landed.  All gates read
        // the previous round's timeline (every slice moves every round),
        // so updates land in a fresh copy.
        let mut next_ready = self.slice_ready.clone();
        let mut finish_max = 0.0f64;
        let mut compute_max = 0.0f64;
        for (p, legs) in obs.timed_legs.iter().enumerate() {
            let start = self.worker_free[p].max(obs.dispatched_at);
            let (finish, total, wait) = replay_queue(
                obs.order,
                start,
                legs,
                &self.slice_ready,
                &mut next_ready,
                obs.round,
                obs.jitter,
            );
            handoff_waits.push(wait);
            self.worker_free[p] = finish;
            finish_max = finish_max.max(finish);
            compute_max = compute_max.max(total);
        }
        if !obs.net.is_empty() {
            // lossy transport: each forwarded slice lands downstream late
            // by the expected retransmit/delay-hold cost of masking the
            // plan's faults — deterministic per (slice, version), matching
            // the retry schedule the threaded backend physically waits out
            for legs in obs.timed_legs {
                for &(slice, secs) in legs {
                    next_ready[slice] +=
                        obs.net.virtual_latency(slice, obs.round + 1, secs);
                }
            }
        }
        self.slice_ready = next_ready;
        let before = self.coord_now;
        self.coord_now = self.coord_now.max(finish_max + obs.comm_secs) + obs.pull_secs;
        if let Some(sink) = &self.trace {
            sink.push(Event::Resolve {
                round: obs.round,
                now_bits: self.coord_now.to_bits(),
            });
        }
        let bsp_increment = compute_max + obs.comm_secs + obs.pull_secs;
        RoundOutcome {
            now: self.coord_now,
            wait_saved_secs: bsp_increment - (self.coord_now - before),
        }
    }

    fn now(&self) -> f64 {
        self.coord_now
    }

    fn install_trace(&mut self, sink: Arc<TraceBuffer>) {
        self.trace = Some(sink);
    }
}

/// Real-concurrency backend: P worker threads exchange slices through the
/// blocking data plane, straggler skew is realized as on-thread sleeps
/// (push runs to `max(measured, pace_floor) × multiplier` wall seconds),
/// and the run clock is the wall clock offset by where the virtual clock
/// stood when the run began — so `virtual_secs ≈ wall_secs` for threaded
/// runs and cross-run accumulation still works.
pub struct ThreadBackend {
    straggler: StragglerModel,
    /// Virtual-clock reading at `begin_run` (the run-clock origin).
    base: f64,
    coord_now: f64,
    n_workers: usize,
    pace_floor_secs: f64,
    /// Trace sink for per-round `Resolve` events (None = tracing off).
    trace: Option<Arc<TraceBuffer>>,
}

/// Env override for the threaded pacing floor, in milliseconds
/// (`STRADS_THREADS_PACE_MS`; 0 = off).  Read per backend construction so
/// benches can set it between runs.
fn env_pace_floor_secs() -> f64 {
    std::env::var("STRADS_THREADS_PACE_MS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|ms| ms.max(0.0) * 1e-3)
        .unwrap_or(0.0)
}

impl ThreadBackend {
    pub fn new(straggler: StragglerModel, pace_floor_secs: f64) -> Self {
        ThreadBackend {
            straggler,
            base: 0.0,
            coord_now: 0.0,
            n_workers: 0,
            pace_floor_secs: pace_floor_secs.max(env_pace_floor_secs()),
            trace: None,
        }
    }

    /// Pin the run clock to the wall clock (monotone: collects never move
    /// it backwards past a later dispatch).
    fn to_wall(&mut self, wall_now: f64) -> f64 {
        self.coord_now = self.coord_now.max(self.base + wall_now);
        self.coord_now
    }
}

impl ExecBackend for ThreadBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Threads
    }

    fn begin_run(&mut self, now: f64, n_workers: usize, _n_slices: usize) {
        self.base = now;
        self.coord_now = now;
        self.n_workers = n_workers;
    }

    fn physical_slowdown(&self, worker: usize, round: u64, n_workers: usize) -> f64 {
        self.straggler.multiplier(worker, round, n_workers)
    }

    fn pace_floor_secs(&self) -> f64 {
        self.pace_floor_secs
    }

    fn account_compute(&self, secs: &mut [f64], round: u64) {
        // same scaling as Sim: the sleeps realized the skew physically,
        // but the CPU-time measurement excludes them by design
        self.straggler.scale(secs, round);
    }

    fn on_dispatch(&mut self, _schedule_secs: f64, wall_now: f64) -> f64 {
        self.to_wall(wall_now)
    }

    fn resolve_round(&mut self, obs: &RoundObs) -> RoundOutcome {
        let compute_max =
            obs.compute_secs.iter().copied().fold(0.0f64, f64::max);
        let before = self.coord_now;
        let now = self.to_wall(obs.wall_now);
        if let Some(sink) = &self.trace {
            sink.push(Event::Resolve {
                round: obs.round,
                now_bits: now.to_bits(),
            });
        }
        let bsp_increment = compute_max + obs.comm_secs + obs.pull_secs;
        RoundOutcome {
            now,
            wait_saved_secs: bsp_increment - (now - before),
        }
    }

    fn resolve_rot_round(
        &mut self,
        obs: &RotObs,
        handoff_waits: &mut Vec<f64>,
    ) -> RoundOutcome {
        // blocking is physical here: the per-worker wait shows up in the
        // router's block counter, not in a modelled timeline
        handoff_waits.resize(obs.timed_legs.len(), 0.0);
        let compute_max = obs
            .timed_legs
            .iter()
            .map(|legs| legs.iter().map(|&(_, s)| s).sum::<f64>())
            .fold(0.0f64, f64::max);
        let before = self.coord_now;
        let now = self.to_wall(obs.wall_now);
        if let Some(sink) = &self.trace {
            sink.push(Event::Resolve {
                round: obs.round,
                now_bits: now.to_bits(),
            });
        }
        let bsp_increment = compute_max + obs.comm_secs + obs.pull_secs;
        RoundOutcome {
            now,
            wait_saved_secs: bsp_increment - (now - before),
        }
    }

    fn now(&self) -> f64 {
        self.coord_now
    }

    fn install_trace(&mut self, sink: Arc<TraceBuffer>) {
        self.trace = Some(sink);
    }
}

/// Replay one worker's rotation queue against the per-slice availability
/// timeline for one round.  `legs` are `(slice_id, seconds)` in granted
/// (ring-position) order; each leg starts at
/// `max(worker time, slice_ready[slice])` and runs for its seconds, and
/// its handoff lands downstream at `finish + jitter latency`.  A queue
/// emptied by [`crate::scheduler::rotation::SkipPolicy::Defer`] replays
/// to `(start, 0, 0)` and leaves every skipped slice's readiness
/// untouched.
///
/// [`QueueOrder::Strict`] services the legs as given — arithmetic
/// identical, term for term, to the fixed-order engine.
/// [`QueueOrder::Availability`] services them earliest-ready-first (ties
/// broken by queue position): with per-leg durations independent of
/// order, sequencing a single machine's jobs by release time minimizes
/// its makespan, so a worker's round never finishes later than under any
/// fixed order — the opportunistic reordering is pure win in the model,
/// exactly as `try_take` polling is on the data plane.
/// [`QueueOrder::Dynamic`] services, among the legs whose slices have
/// already landed, the one with the most compute first (seconds proxy
/// token mass; ties toward the earlier release, then queue position),
/// waiting only when nothing is ready.  Both reordering disciplines are
/// *non-idling*, so a worker's round finishes at the same time under
/// either — Dynamic changes only **when each slice's handoff releases**,
/// front-loading the heavy slices so the sweeps that gate the most
/// downstream compute land earliest (the mass × downstream-benefit
/// score; property-tested against Availability's finish in
/// `tests/rotation_properties.rs`).
///
/// Public so the regression/property suites can pin the model itself
/// (golden replays, never-worse properties) without driving a full
/// engine.
///
/// Returns `(finish time, total compute seconds, handoff wait seconds)`;
/// the wait is the idle time the worker spent blocked on not-yet-landed
/// slices (the slack the reordering disciplines exist to reclaim).
pub fn replay_queue(
    order: QueueOrder,
    start: f64,
    legs: &[(usize, f64)],
    slice_ready: &[f64],
    next_ready: &mut [f64],
    round: u64,
    jitter: &HandoffJitter,
) -> (f64, f64, f64) {
    if order == QueueOrder::Dynamic {
        return replay_queue_dynamic(
            start, legs, slice_ready, next_ready, round, jitter,
        );
    }
    let mut idx: Vec<usize> = (0..legs.len()).collect();
    if order == QueueOrder::Availability {
        idx.sort_by(|&a, &b| {
            slice_ready[legs[a].0]
                .partial_cmp(&slice_ready[legs[b].0])
                .expect("slice_ready is never NaN")
                .then(a.cmp(&b))
        });
    }
    let mut t = start;
    let mut total = 0.0f64;
    let mut wait = 0.0f64;
    for &i in &idx {
        let (slice, secs) = legs[i];
        wait += (slice_ready[slice] - t).max(0.0);
        let leg_start = t.max(slice_ready[slice]);
        t = leg_start + secs;
        next_ready[slice] = t + jitter.latency(slice, round, secs);
        total += secs;
    }
    (t, total, wait)
}

/// The [`QueueOrder::Dynamic`] half of [`replay_queue`]: event-driven —
/// the ready set depends on the worker's own progress, so the order
/// cannot be fixed up front the way Availability's earliest-release sort
/// can.
fn replay_queue_dynamic(
    start: f64,
    legs: &[(usize, f64)],
    slice_ready: &[f64],
    next_ready: &mut [f64],
    round: u64,
    jitter: &HandoffJitter,
) -> (f64, f64, f64) {
    let mut remaining: Vec<usize> = (0..legs.len()).collect();
    let mut t = start;
    let mut total = 0.0f64;
    let mut wait = 0.0f64;
    while !remaining.is_empty() {
        let ready_at = |i: usize| slice_ready[legs[i].0];
        if remaining.iter().all(|&i| ready_at(i) > t) {
            // nothing parked: wait for the earliest release
            let tmin = remaining
                .iter()
                .map(|&i| ready_at(i))
                .fold(f64::INFINITY, f64::min);
            wait += tmin - t;
            t = tmin;
        }
        // heaviest ready leg first; ties toward the earlier release, then
        // queue position (mirrors SliceRouter::take_heaviest's data-plane
        // tie-break: arrival stamp, then grant index)
        let (at, _) = remaining
            .iter()
            .enumerate()
            .filter(|&(_, &i)| ready_at(i) <= t)
            .max_by(|&(_, &a), &(_, &b)| {
                legs[a]
                    .1
                    .partial_cmp(&legs[b].1)
                    .expect("leg seconds are never NaN")
                    .then(
                        ready_at(b)
                            .partial_cmp(&ready_at(a))
                            .expect("slice_ready is never NaN"),
                    )
                    .then(b.cmp(&a))
            })
            .expect("a leg is ready after waiting");
        let i = remaining.swap_remove(at);
        let (slice, secs) = legs[i];
        t += secs;
        next_ready[slice] = t + jitter.latency(slice, round, secs);
        total += secs;
    }
    (t, total, wait)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_and_displays() {
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert_eq!(
            "threads".parse::<BackendKind>().unwrap(),
            BackendKind::Threads
        );
        assert!("virtual".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Threads.to_string(), "threads");
        assert_eq!(BackendKind::default(), BackendKind::Sim);
    }

    #[test]
    fn sim_backend_matches_the_ssp_clock_arithmetic() {
        let mut b = SimBackend::new(StragglerModel::None);
        b.begin_run(100.0, 2, 0);
        let at = b.on_dispatch(1.0, 0.0);
        assert_eq!(at, 101.0);
        let out = b.resolve_round(&RoundObs {
            round: 0,
            dispatched_at: at,
            compute_secs: &[2.0, 5.0],
            comm_secs: 0.5,
            pull_secs: 0.25,
            wall_now: 0.0,
        });
        // coord = max(101, 101 + 5 + 0.5) + 0.25
        assert!((out.now - 106.75).abs() < 1e-12);
        assert!((b.now() - 106.75).abs() < 1e-12);
        // BSP would charge 5 + 0.5 + 0.25 = 5.75, exactly what the
        // just-dispatched pipeline paid: nothing hidden on round one
        assert!(out.wait_saved_secs.abs() < 1e-12);
    }

    #[test]
    fn sim_backend_rotation_gates_on_slice_readiness() {
        let mut b = SimBackend::new(StragglerModel::None);
        b.begin_run(0.0, 2, 2);
        let at = b.on_dispatch(0.0, 0.0);
        let legs = vec![vec![(0usize, 1.0f64)], vec![(1usize, 3.0f64)]];
        let mut waits = Vec::new();
        let out = b.resolve_rot_round(
            &RotObs {
                round: 0,
                dispatched_at: at,
                timed_legs: &legs,
                comm_secs: 0.0,
                pull_secs: 0.0,
                order: QueueOrder::Strict,
                jitter: &HandoffJitter::None,
                net: &NetFaultPlan::default(),
                wall_now: 0.0,
            },
            &mut waits,
        );
        assert_eq!(waits, vec![0.0, 0.0]);
        assert!((out.now - 3.0).abs() < 1e-12);
        // slice 0's next sweep is gated at 1.0, slice 1's at 3.0
        assert_eq!(b.slice_ready, vec![1.0, 3.0]);
    }

    #[test]
    fn sim_backend_charges_virtual_net_latency_to_slice_readiness() {
        let resolve = |net: &NetFaultPlan| {
            let mut b = SimBackend::new(StragglerModel::None);
            b.begin_run(0.0, 2, 2);
            let at = b.on_dispatch(0.0, 0.0);
            let legs = vec![vec![(0usize, 1.0f64)], vec![(1usize, 3.0f64)]];
            let mut waits = Vec::new();
            b.resolve_rot_round(
                &RotObs {
                    round: 0,
                    dispatched_at: at,
                    timed_legs: &legs,
                    comm_secs: 0.0,
                    pull_secs: 0.0,
                    order: QueueOrder::Strict,
                    jitter: &HandoffJitter::None,
                    net,
                    wall_now: 0.0,
                },
                &mut waits,
            );
            b.slice_ready.clone()
        };
        // an all-zero plan charges exactly nothing (bit-identical)
        assert_eq!(resolve(&NetFaultPlan::default()), vec![1.0, 3.0]);
        // a lossy plan gates every forwarded slice's next sweep strictly
        // later — the modelled cost of masking its drops and delays
        let lossy = NetFaultPlan {
            drop_rate: 0.4,
            delay_rate: 0.5,
            seed: 17,
            ..NetFaultPlan::default()
        };
        let ready = resolve(&lossy);
        assert!(
            ready[0] >= 1.0 && ready[1] >= 3.0,
            "latency never rewinds readiness: {ready:?}"
        );
        assert!(
            ready[0] > 1.0 || ready[1] > 3.0,
            "a 40%/50% plan must charge some leg: {ready:?}"
        );
    }

    #[test]
    fn thread_backend_tracks_the_wall_clock_monotonically() {
        let mut b = ThreadBackend::new(StragglerModel::None, 0.0);
        b.begin_run(50.0, 3, 0);
        assert_eq!(b.on_dispatch(123.0, 0.25), 50.25); // schedule secs ignored
        let out = b.resolve_round(&RoundObs {
            round: 0,
            dispatched_at: 50.25,
            compute_secs: &[0.1, 0.1, 0.1],
            comm_secs: 0.0,
            pull_secs: 0.0,
            wall_now: 1.0,
        });
        assert!((out.now - 51.0).abs() < 1e-12);
        // a stale (earlier) wall reading never rewinds the clock
        assert_eq!(b.on_dispatch(0.0, 0.5), 51.0);
    }

    #[test]
    fn thread_backend_realizes_straggler_skew_physically() {
        let b = ThreadBackend::new(
            StragglerModel::Fixed(vec![3.0, 1.0]),
            0.002,
        );
        assert_eq!(b.physical_slowdown(0, 7, 2), 3.0);
        assert_eq!(b.physical_slowdown(1, 7, 2), 1.0);
        assert_eq!(b.pace_floor_secs(), 0.002);
        let mut secs = vec![1.0, 1.0];
        b.account_compute(&mut secs, 0);
        assert_eq!(secs, vec![3.0, 1.0], "accounting mirrors the sleeps");
    }

    #[test]
    fn thread_backend_rot_resolution_reports_zero_handoff_waits() {
        let mut b = ThreadBackend::new(StragglerModel::None, 0.0);
        b.begin_run(0.0, 2, 4);
        let legs = vec![vec![(0usize, 0.5f64)], vec![(1usize, 0.25f64)]];
        let mut waits = Vec::new();
        let out = b.resolve_rot_round(
            &RotObs {
                round: 0,
                dispatched_at: 0.0,
                timed_legs: &legs,
                comm_secs: 0.0,
                pull_secs: 0.0,
                order: QueueOrder::Strict,
                jitter: &HandoffJitter::None,
                net: &NetFaultPlan::default(),
                wall_now: 0.75,
            },
            &mut waits,
        );
        assert_eq!(waits, vec![0.0, 0.0]);
        assert!((out.now - 0.75).abs() < 1e-12);
    }
}
