//! Virtual cluster clock.
//!
//! Rounds on the simulated cluster advance by
//! `max_p(compute_p) + comm_round`: workers run in parallel in the modelled
//! cluster even when this build machine executes them on fewer cores.  All
//! figure harnesses report this clock (plus wall-clock for reference).

/// Accumulates simulated elapsed time for one experiment run.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    elapsed_s: f64,
    rounds: u64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by one BSP round: slowest worker's compute + modelled comm
    /// + coordinator-side work (schedule + pull).
    pub fn advance_round(
        &mut self,
        worker_compute_s: &[f64],
        comm_s: f64,
        coordinator_s: f64,
    ) {
        let slowest = worker_compute_s.iter().cloned().fold(0.0, f64::max);
        self.elapsed_s += slowest + comm_s + coordinator_s;
        self.rounds += 1;
    }

    /// Advance by a raw amount (setup phases etc.).
    pub fn advance(&mut self, secs: f64) {
        self.elapsed_s += secs;
    }

    pub fn seconds(&self) -> f64 {
        self.elapsed_s
    }
    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_takes_max_worker_time() {
        let mut c = VirtualClock::new();
        c.advance_round(&[0.1, 0.5, 0.2], 0.05, 0.01);
        assert!((c.seconds() - 0.56).abs() < 1e-12);
        assert_eq!(c.rounds(), 1);
    }

    #[test]
    fn rounds_accumulate() {
        let mut c = VirtualClock::new();
        c.advance_round(&[0.1], 0.0, 0.0);
        c.advance_round(&[0.2], 0.0, 0.0);
        c.advance(1.0);
        assert!((c.seconds() - 1.3).abs() < 1e-12);
        assert_eq!(c.rounds(), 2);
    }

    #[test]
    fn empty_worker_list_is_zero_compute() {
        let mut c = VirtualClock::new();
        c.advance_round(&[], 0.5, 0.0);
        assert!((c.seconds() - 0.5).abs() < 1e-12);
    }
}
