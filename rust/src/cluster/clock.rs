//! Virtual cluster clock.
//!
//! Rounds on the simulated cluster advance by
//! `max_p(compute_p) + comm_round`: workers run in parallel in the modelled
//! cluster even when this build machine executes them on fewer cores.  All
//! figure harnesses report this clock (plus wall-clock for reference).

/// Models compute-speed skew across the simulated machines: measured
/// per-worker compute seconds are scaled before they are charged to the
/// virtual clock.  This is how the straggler experiments (fig9 BSP-vs-SSP
/// arm) inject slow machines deterministically.
#[derive(Debug, Clone, Default)]
pub enum StragglerModel {
    /// Homogeneous cluster — measured times pass through untouched
    /// (bit-identical to the pre-straggler engine behaviour).
    #[default]
    None,
    /// Static per-worker multipliers (index = worker id; missing entries
    /// default to 1.0).  `Fixed(vec![4.0, 1.0, 1.0, 1.0])` is a persistent
    /// 4x straggler on worker 0.
    Fixed(Vec<f64>),
    /// One worker is `factor`x slow each round, rotating round-robin:
    /// worker `round % n_workers` lags in round `round`.  The i.i.d.-ish
    /// skew where SSP's pipeline shines (every worker is sometimes the
    /// straggler, so bounded lag lets the fast ones run ahead).
    Rotating { factor: f64 },
}

impl StragglerModel {
    /// Multiplier for `worker` in `round` on an `n_workers` cluster.
    pub fn multiplier(&self, worker: usize, round: u64, n_workers: usize) -> f64 {
        match self {
            StragglerModel::None => 1.0,
            StragglerModel::Fixed(m) => m.get(worker).copied().unwrap_or(1.0),
            StragglerModel::Rotating { factor } => {
                if n_workers > 0 && round % n_workers as u64 == worker as u64 {
                    *factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Mean relative speed per worker over the first `horizon` rounds
    /// (1 / mean multiplier; higher = faster) — the skew-aware ring
    /// placement's summary view of the cluster
    /// ([`crate::scheduler::rotation::skew_aware_placement`]).  `None` is
    /// all-ones; `Rotating` averages out to uniform over a full period;
    /// `Fixed` reports the persistent skew the placement can exploit.
    pub fn mean_speeds(&self, n_workers: usize, horizon: u64) -> Vec<f64> {
        let h = horizon.max(1);
        (0..n_workers)
            .map(|p| {
                let total: f64 =
                    (0..h).map(|r| self.multiplier(p, r, n_workers)).sum();
                h as f64 / total
            })
            .collect()
    }

    /// Scale measured per-worker seconds in place.  `None` is a strict
    /// no-op so default runs stay bit-identical.
    pub fn scale(&self, secs: &mut [f64], round: u64) {
        if matches!(self, StragglerModel::None) {
            return;
        }
        let n = secs.len();
        for (p, s) in secs.iter_mut().enumerate() {
            *s *= self.multiplier(p, round, n);
        }
    }
}

/// Accumulates simulated elapsed time for one experiment run.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    elapsed_s: f64,
    rounds: u64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by one BSP round: slowest worker's compute + modelled comm
    /// + coordinator-side work (schedule + pull).
    pub fn advance_round(
        &mut self,
        worker_compute_s: &[f64],
        comm_s: f64,
        coordinator_s: f64,
    ) {
        let slowest = worker_compute_s.iter().cloned().fold(0.0, f64::max);
        self.elapsed_s += slowest + comm_s + coordinator_s;
        self.rounds += 1;
    }

    /// Advance by a raw amount (setup phases etc.).
    pub fn advance(&mut self, secs: f64) {
        self.elapsed_s += secs;
    }

    /// Advance one *pipelined* round (SSP mode): the caller has already
    /// resolved per-worker start times against the dispatch window, so the
    /// clock simply jumps to the supplied absolute timestamp (monotone —
    /// a timestamp in the past is ignored) and counts the round.
    pub fn advance_round_to(&mut self, timestamp_s: f64) {
        self.elapsed_s = self.elapsed_s.max(timestamp_s);
        self.rounds += 1;
    }

    pub fn seconds(&self) -> f64 {
        self.elapsed_s
    }
    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_takes_max_worker_time() {
        let mut c = VirtualClock::new();
        c.advance_round(&[0.1, 0.5, 0.2], 0.05, 0.01);
        assert!((c.seconds() - 0.56).abs() < 1e-12);
        assert_eq!(c.rounds(), 1);
    }

    #[test]
    fn rounds_accumulate() {
        let mut c = VirtualClock::new();
        c.advance_round(&[0.1], 0.0, 0.0);
        c.advance_round(&[0.2], 0.0, 0.0);
        c.advance(1.0);
        assert!((c.seconds() - 1.3).abs() < 1e-12);
        assert_eq!(c.rounds(), 2);
    }

    #[test]
    fn straggler_models_scale_compute() {
        let mut s = [1.0, 1.0, 1.0];
        StragglerModel::None.scale(&mut s, 7);
        assert_eq!(s, [1.0, 1.0, 1.0]);

        StragglerModel::Fixed(vec![4.0]).scale(&mut s, 0);
        assert_eq!(s, [4.0, 1.0, 1.0]); // missing entries default to 1.0

        let rot = StragglerModel::Rotating { factor: 4.0 };
        let mut a = [1.0, 1.0, 1.0];
        rot.scale(&mut a, 1);
        assert_eq!(a, [1.0, 4.0, 1.0]);
        assert_eq!(rot.multiplier(1, 4, 3), 4.0); // 4 % 3 == 1
        assert_eq!(rot.multiplier(0, 4, 3), 1.0);
    }

    #[test]
    fn mean_speeds_summarize_the_skew() {
        assert_eq!(StragglerModel::None.mean_speeds(3, 8), vec![1.0; 3]);
        let fixed = StragglerModel::Fixed(vec![4.0, 1.0]);
        assert_eq!(fixed.mean_speeds(2, 5), vec![0.25, 1.0]);
        // a rotating straggler is uniform over a full period
        let rot = StragglerModel::Rotating { factor: 4.0 };
        let s = rot.mean_speeds(2, 2);
        assert!((s[0] - s[1]).abs() < 1e-12);
        assert!((s[0] - 2.0 / 5.0).abs() < 1e-12); // 2 / (1 + 4)
    }

    #[test]
    fn advance_round_to_is_monotone_and_counts() {
        let mut c = VirtualClock::new();
        c.advance_round_to(2.5);
        c.advance_round_to(1.0); // stale timestamp: time must not go back
        assert!((c.seconds() - 2.5).abs() < 1e-12);
        assert_eq!(c.rounds(), 2);
    }

    #[test]
    fn empty_worker_list_is_zero_compute() {
        let mut c = VirtualClock::new();
        c.advance_round(&[], 0.5, 0.0);
        assert!((c.seconds() - 0.5).abs() < 1e-12);
    }
}
