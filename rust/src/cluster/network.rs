//! Star-topology network cost model.
//!
//! STRADS uses a star topology: scheduler/coordinator machines in the
//! middle, workers on the points (paper §5 notes the scheduler eventually
//! bottlenecks).  We model each coordinator↔worker link with a fixed
//! per-message latency plus bytes/bandwidth, and the coordinator's shared
//! NIC as a serialization point — reproducing that bottleneck.

/// Link parameters.  Defaults model the paper's 1 Gbps cluster.
#[derive(Debug, Clone, Copy)]
pub struct NetworkConfig {
    /// One-way per-message latency (seconds).
    pub latency_s: f64,
    /// Worker link bandwidth (bytes/second).
    pub bandwidth_bps: f64,
    /// Coordinator NIC aggregate bandwidth (bytes/second). All worker
    /// traffic shares this — the star-topology serialization point.
    pub hub_bandwidth_bps: f64,
}

impl NetworkConfig {
    /// Paper's LDA cluster: 1 Gbps, commodity latency.
    pub fn gbps1() -> Self {
        NetworkConfig {
            latency_s: 100e-6,
            bandwidth_bps: 125e6,
            hub_bandwidth_bps: 125e6,
        }
    }

    /// Paper's Lasso/MF cluster: 40 Gbps low-latency.
    pub fn gbps40() -> Self {
        NetworkConfig {
            latency_s: 10e-6,
            bandwidth_bps: 5e9,
            hub_bandwidth_bps: 5e9,
        }
    }

    /// Zero-cost network (ablation: isolate compute scaling).
    pub fn ideal() -> Self {
        NetworkConfig {
            latency_s: 0.0,
            bandwidth_bps: f64::INFINITY,
            hub_bandwidth_bps: f64::INFINITY,
        }
    }
}

/// Per-handoff latency model for the rotation pipeline's virtual-time
/// gates: the delay between a holder *finishing* a slice's sweep and the
/// slice becoming available at its next holder.
///
/// Latencies are expressed as a **fraction of the forwarding sweep's
/// compute seconds** — slice transfer bytes and sweep work both scale
/// with the slice's token mass, and a relative knob stays meaningful
/// across corpus scales and build-machine speeds (absolute seconds would
/// dwarf or vanish against the measured compute depending on both).
/// `None` is the PR-3 behaviour: handoffs land the instant the sweep
/// finishes (bit-identical timelines).  `Jittered` draws a deterministic
/// per-(slice, round) uniform variate, so two runs over the same schedule
/// see the same latency field — arrival-order inversions included, which
/// is exactly what [`crate::scheduler::rotation::QueueOrder::Availability`]
/// exploits.
#[derive(Debug, Clone, Default)]
pub enum HandoffJitter {
    /// Handoffs are instantaneous (default; pre-jitter behaviour).
    #[default]
    None,
    /// Every handoff takes `frac` × the forwarding sweep's seconds.
    Uniform { frac: f64 },
    /// Handoff takes `(base_frac + jitter_frac · u)` × sweep seconds,
    /// with `u ∈ [0, 1)` hashed deterministically from (slice, round,
    /// seed).
    Jittered { base_frac: f64, jitter_frac: f64, seed: u64 },
}

impl HandoffJitter {
    /// Deterministic u ∈ [0, 1) per (slice, round, seed) — splitmix64
    /// finalizer over the mixed key.
    fn u01(slice: usize, round: u64, seed: u64) -> f64 {
        let mut x = (slice as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(round.wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(seed);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        (x >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Latency (virtual seconds) for the handoff of `slice` forwarded in
    /// `round` by a sweep that took `sweep_secs`.  `None` returns exactly
    /// 0.0, keeping default timelines bit-identical.
    pub fn latency(&self, slice: usize, round: u64, sweep_secs: f64) -> f64 {
        match self {
            HandoffJitter::None => 0.0,
            HandoffJitter::Uniform { frac } => frac * sweep_secs,
            HandoffJitter::Jittered { base_frac, jitter_frac, seed } => {
                (base_frac + jitter_frac * Self::u01(slice, round, *seed))
                    * sweep_secs
            }
        }
    }
}

/// Per-round traffic accounting and time modelling.
#[derive(Debug)]
pub struct NetworkModel {
    cfg: NetworkConfig,
    n_workers: usize,
    /// Total bytes sent coordinator→worker p this round.
    down_bytes: Vec<u64>,
    /// Total bytes sent worker p→coordinator this round.
    up_bytes: Vec<u64>,
    /// Worker↔worker bytes this round (rotation slice passing): these
    /// traverse the worker links in parallel, NOT the coordinator hub.
    p2p_bytes: Vec<u64>,
    /// Lifetime counters.
    total_bytes: u64,
    total_msgs: u64,
    /// Lifetime bytes that moved worker↔worker (subset of `total_bytes`):
    /// rotation slice handoffs and KV-shard serving, which never cross
    /// the coordinator hub.
    total_p2p_bytes: u64,
    /// Lifetime count of worker↔worker transfers (rotation slice
    /// handoffs): one per [`NetworkModel::send_p2p`] between distinct
    /// endpoints.
    total_p2p_msgs: u64,
}

impl NetworkModel {
    pub fn new(cfg: NetworkConfig, n_workers: usize) -> Self {
        NetworkModel {
            cfg,
            n_workers,
            down_bytes: vec![0; n_workers],
            up_bytes: vec![0; n_workers],
            p2p_bytes: vec![0; n_workers],
            total_bytes: 0,
            total_msgs: 0,
            total_p2p_bytes: 0,
            total_p2p_msgs: 0,
        }
    }

    pub fn config(&self) -> NetworkConfig {
        self.cfg
    }

    /// Record a coordinator→worker message of `bytes` payload.
    pub fn send_down(&mut self, worker: usize, bytes: usize) {
        self.down_bytes[worker] += bytes as u64;
        self.total_bytes += bytes as u64;
        self.total_msgs += 1;
    }

    /// Record a worker→coordinator message of `bytes` payload.
    pub fn send_up(&mut self, worker: usize, bytes: usize) {
        self.up_bytes[worker] += bytes as u64;
        self.total_bytes += bytes as u64;
        self.total_msgs += 1;
    }

    /// Record a worker↔worker transfer (e.g. LDA's rotating word-topic
    /// slices, or a worker's KV-shard fetch served by a peer).  These run
    /// on the point links in parallel and bypass the hub, but the payload
    /// occupies *both* endpoints' links: the sender serializes it out and
    /// the receiver serializes it in.  (Charging only one side — the old
    /// behaviour — underestimated rotation-round comm time whenever the
    /// uncharged endpoint was otherwise idle.)  A self-transfer (`from ==
    /// to`) is a local move and costs nothing.
    pub fn send_p2p(&mut self, from: usize, to: usize, bytes: usize) {
        if from == to {
            return;
        }
        self.p2p_bytes[from] += bytes as u64;
        self.p2p_bytes[to] += bytes as u64;
        self.total_bytes += bytes as u64; // one payload on the wire
        self.total_p2p_bytes += bytes as u64;
        self.total_msgs += 1;
        self.total_p2p_msgs += 1;
    }

    /// Modelled communication time for the round, then reset round
    /// counters.  Round comm time = per-link max(latency + bytes/bw) for
    /// the parallel links, plus hub serialization of the aggregate bytes.
    pub fn round_time_and_reset(&mut self) -> f64 {
        let mut link_max = 0.0f64;
        let mut hub_bytes = 0u64;
        for p in 0..self.n_workers {
            let b = self.down_bytes[p] + self.up_bytes[p];
            let link_b = b + self.p2p_bytes[p];
            if link_b > 0 {
                let t = 2.0 * self.cfg.latency_s
                    + link_b as f64 / self.cfg.bandwidth_bps;
                link_max = link_max.max(t);
            }
            hub_bytes += b; // p2p traffic does not cross the hub
            self.down_bytes[p] = 0;
            self.up_bytes[p] = 0;
            self.p2p_bytes[p] = 0;
        }
        let hub_time = if self.cfg.hub_bandwidth_bps.is_finite() {
            hub_bytes as f64 / self.cfg.hub_bandwidth_bps
        } else {
            0.0
        };
        link_max.max(hub_time)
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }
    pub fn total_msgs(&self) -> u64 {
        self.total_msgs
    }
    /// Lifetime worker↔worker bytes (hub-bypassing traffic).
    pub fn total_p2p_bytes(&self) -> u64 {
        self.total_p2p_bytes
    }
    /// Lifetime worker↔worker transfer count (rotation slice handoffs).
    pub fn total_p2p_msgs(&self) -> u64 {
        self.total_p2p_msgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_includes_latency_and_bandwidth() {
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 1e-3, bandwidth_bps: 1e6, hub_bandwidth_bps: f64::INFINITY },
            2,
        );
        n.send_down(0, 1_000_000); // 1 s of bandwidth
        let t = n.round_time_and_reset();
        assert!((t - (2e-3 + 1.0)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn round_counters_reset() {
        let mut n = NetworkModel::new(NetworkConfig::gbps1(), 1);
        n.send_up(0, 1000);
        let t1 = n.round_time_and_reset();
        let t2 = n.round_time_and_reset();
        assert!(t1 > 0.0);
        assert_eq!(t2, 0.0);
        assert_eq!(n.total_bytes(), 1000);
    }

    #[test]
    fn hub_serializes_aggregate_traffic() {
        // 4 workers × 1MB each in parallel on 1MB/s links = ~1s per link,
        // but a 1MB/s hub must serialize 4MB = 4s.
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 0.0, bandwidth_bps: 1e6, hub_bandwidth_bps: 1e6 },
            4,
        );
        for p in 0..4 {
            n.send_up(p, 1_000_000);
        }
        let t = n.round_time_and_reset();
        assert!((t - 4.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn p2p_charges_both_endpoints_but_not_the_hub() {
        // 1MB peer transfer on 1MB/s links: either endpoint alone would be
        // busy 1s.  Loading the *receiver* with another 1MB of hub traffic
        // must make its link the 2s bottleneck — under one-sided charging
        // the receiver's link looked empty and the round cost only 1s.
        let cfg = NetworkConfig {
            latency_s: 0.0,
            bandwidth_bps: 1e6,
            hub_bandwidth_bps: f64::INFINITY,
        };
        let mut n = NetworkModel::new(cfg, 3);
        n.send_p2p(0, 1, 1_000_000);
        n.send_down(1, 1_000_000);
        let t = n.round_time_and_reset();
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
        // the payload itself is counted once, and tracked as p2p traffic
        assert_eq!(n.total_bytes(), 2_000_000);
        assert_eq!(n.total_p2p_bytes(), 1_000_000);
        assert_eq!(n.total_p2p_msgs(), 1);

        // hub-bound check: p2p bytes never serialize through the hub
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 0.0, bandwidth_bps: 1e6, hub_bandwidth_bps: 1e6 },
            3,
        );
        n.send_p2p(0, 1, 1_000_000);
        let t = n.round_time_and_reset();
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn p2p_self_transfer_is_free() {
        let mut n = NetworkModel::new(NetworkConfig::gbps1(), 1);
        n.send_p2p(0, 0, 123_456);
        assert_eq!(n.round_time_and_reset(), 0.0);
        assert_eq!(n.total_bytes(), 0);
        assert_eq!(n.total_p2p_msgs(), 0);
    }

    #[test]
    fn ideal_network_is_free() {
        let mut n = NetworkModel::new(NetworkConfig::ideal(), 3);
        n.send_down(1, 123456);
        assert_eq!(n.round_time_and_reset(), 0.0);
    }

    #[test]
    fn handoff_jitter_is_deterministic_scaled_and_bounded() {
        assert_eq!(HandoffJitter::None.latency(3, 7, 0.5), 0.0);
        let u = HandoffJitter::Uniform { frac: 0.5 };
        assert!((u.latency(3, 7, 0.4) - 0.2).abs() < 1e-15);
        let j = HandoffJitter::Jittered {
            base_frac: 0.2,
            jitter_frac: 1.5,
            seed: 9,
        };
        let a = j.latency(3, 7, 1.0);
        assert_eq!(a, j.latency(3, 7, 1.0), "same key, same latency");
        assert!((0.2..0.2 + 1.5).contains(&a), "latency {a} out of band");
        assert_ne!(a, j.latency(4, 7, 1.0), "slice varies the draw");
        assert_ne!(a, j.latency(3, 8, 1.0), "round varies the draw");
        // scales linearly with the sweep
        assert!((j.latency(3, 7, 2.0) - 2.0 * a).abs() < 1e-12);
    }

    #[test]
    fn faster_fabric_is_faster() {
        let mk = |cfg: NetworkConfig| {
            let mut n = NetworkModel::new(cfg, 1);
            n.send_down(0, 10_000_000);
            n.round_time_and_reset()
        };
        assert!(mk(NetworkConfig::gbps40()) < mk(NetworkConfig::gbps1()));
    }
}
