//! Star-topology network cost model.
//!
//! STRADS uses a star topology: scheduler/coordinator machines in the
//! middle, workers on the points (paper §5 notes the scheduler eventually
//! bottlenecks).  We model each coordinator↔worker link with a fixed
//! per-message latency plus bytes/bandwidth, and the coordinator's shared
//! NIC as a serialization point — reproducing that bottleneck.

/// Link parameters.  Defaults model the paper's 1 Gbps cluster.
#[derive(Debug, Clone, Copy)]
pub struct NetworkConfig {
    /// One-way per-message latency (seconds).
    pub latency_s: f64,
    /// Worker link bandwidth (bytes/second).
    pub bandwidth_bps: f64,
    /// Coordinator NIC aggregate bandwidth (bytes/second). All worker
    /// traffic shares this — the star-topology serialization point.
    pub hub_bandwidth_bps: f64,
}

impl NetworkConfig {
    /// Paper's LDA cluster: 1 Gbps, commodity latency.
    pub fn gbps1() -> Self {
        NetworkConfig {
            latency_s: 100e-6,
            bandwidth_bps: 125e6,
            hub_bandwidth_bps: 125e6,
        }
    }

    /// Paper's Lasso/MF cluster: 40 Gbps low-latency.
    pub fn gbps40() -> Self {
        NetworkConfig {
            latency_s: 10e-6,
            bandwidth_bps: 5e9,
            hub_bandwidth_bps: 5e9,
        }
    }

    /// Zero-cost network (ablation: isolate compute scaling).
    pub fn ideal() -> Self {
        NetworkConfig {
            latency_s: 0.0,
            bandwidth_bps: f64::INFINITY,
            hub_bandwidth_bps: f64::INFINITY,
        }
    }
}

/// Per-handoff latency model for the rotation pipeline's virtual-time
/// gates: the delay between a holder *finishing* a slice's sweep and the
/// slice becoming available at its next holder.
///
/// Latencies are expressed as a **fraction of the forwarding sweep's
/// compute seconds** — slice transfer bytes and sweep work both scale
/// with the slice's token mass, and a relative knob stays meaningful
/// across corpus scales and build-machine speeds (absolute seconds would
/// dwarf or vanish against the measured compute depending on both).
/// `None` is the PR-3 behaviour: handoffs land the instant the sweep
/// finishes (bit-identical timelines).  `Jittered` draws a deterministic
/// per-(slice, round) uniform variate, so two runs over the same schedule
/// see the same latency field — arrival-order inversions included, which
/// is exactly what [`crate::scheduler::rotation::QueueOrder::Availability`]
/// exploits.
#[derive(Debug, Clone, Default)]
pub enum HandoffJitter {
    /// Handoffs are instantaneous (default; pre-jitter behaviour).
    #[default]
    None,
    /// Every handoff takes `frac` × the forwarding sweep's seconds.
    Uniform { frac: f64 },
    /// Handoff takes `(base_frac + jitter_frac · u)` × sweep seconds,
    /// with `u ∈ [0, 1)` hashed deterministically from (slice, round,
    /// seed).
    Jittered { base_frac: f64, jitter_frac: f64, seed: u64 },
}

impl HandoffJitter {
    /// Deterministic u ∈ [0, 1) per (slice, round, seed) — splitmix64
    /// finalizer over the mixed key.
    fn u01(slice: usize, round: u64, seed: u64) -> f64 {
        let mut x = (slice as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(round.wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(seed);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        (x >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Latency (virtual seconds) for the handoff of `slice` forwarded in
    /// `round` by a sweep that took `sweep_secs`.  `None` returns exactly
    /// 0.0, keeping default timelines bit-identical.
    pub fn latency(&self, slice: usize, round: u64, sweep_secs: f64) -> f64 {
        match self {
            HandoffJitter::None => 0.0,
            HandoffJitter::Uniform { frac } => frac * sweep_secs,
            HandoffJitter::Jittered { base_frac, jitter_frac, seed } => {
                (base_frac + jitter_frac * Self::u01(slice, round, *seed))
                    * sweep_secs
            }
        }
    }
}

/// Seeded message-level fault plan for the rotation data plane: the
/// probability that a slice forward is dropped, duplicated, or delayed in
/// flight.  All decisions are **stateless hashes** of (seed, stream,
/// slice, version, attempt) — two runs with the same plan see the same
/// fault schedule regardless of wall-clock interleaving, and the
/// virtual-time model ([`NetFaultPlan::virtual_latency`]) can replay the
/// same decisions the real link makes.
///
/// The default plan (all rates 0) is inert: every decision returns
/// false, [`NetFaultPlan::virtual_latency`] returns exactly `0.0`, and a
/// run with the fault layer compiled in is bit-identical to one without.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetFaultPlan {
    /// P(a transmission attempt is dropped in flight).
    pub drop_rate: f64,
    /// P(a forward is duplicated — the copy races the original and is
    /// discarded idempotently at the receiver).
    pub dup_rate: f64,
    /// P(a delivery is held back for a seeded sub-sweep delay, possibly
    /// reordering it past later forwards).
    pub delay_rate: f64,
    /// Seed for every fault decision stream.
    pub seed: u64,
}

impl Default for NetFaultPlan {
    fn default() -> Self {
        NetFaultPlan { drop_rate: 0.0, dup_rate: 0.0, delay_rate: 0.0, seed: 0 }
    }
}

/// Decision-stream tags: each fault kind hashes an independent stream so
/// e.g. raising `drop_rate` never perturbs which forwards get duplicated.
const STREAM_DROP: u64 = 1;
const STREAM_DUP: u64 = 2;
const STREAM_DELAY: u64 = 3;
const STREAM_BACKOFF: u64 = 4;
const STREAM_DELAY_FRAC: u64 = 5;

impl NetFaultPlan {
    /// True when every rate is zero — the layer makes no decisions and
    /// charges no virtual time.
    pub fn is_empty(&self) -> bool {
        self.drop_rate == 0.0 && self.dup_rate == 0.0 && self.delay_rate == 0.0
    }

    /// Rates must be finite probabilities in `[0, 1]`.
    pub fn validate(&self) -> Result<(), String> {
        for (name, r) in [
            ("drop_rate", self.drop_rate),
            ("dup_rate", self.dup_rate),
            ("delay_rate", self.delay_rate),
        ] {
            if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                return Err(format!(
                    "net fault {name} must be a probability in [0, 1], got {r}"
                ));
            }
        }
        Ok(())
    }

    /// Deterministic u ∈ [0, 1) per (seed, stream, slice, version,
    /// attempt) — splitmix64 finalizer over the mixed key (the
    /// [`HandoffJitter::u01`] recipe with per-stream decorrelation).
    fn u01(&self, stream: u64, slice: usize, version: u64, attempt: u64) -> f64 {
        let mut x = self
            .seed
            .wrapping_add(stream.wrapping_mul(0xA0761D6478BD642F))
            .wrapping_add((slice as u64).wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add(version.wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(attempt.wrapping_mul(0x94D049BB133111EB));
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        (x >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Does transmission `attempt` (1-based) of `slice`'s version
    /// `version` forward get dropped in flight?
    pub fn drops(&self, slice: usize, version: u64, attempt: u64) -> bool {
        self.drop_rate > 0.0
            && self.u01(STREAM_DROP, slice, version, attempt) < self.drop_rate
    }

    /// Is this forward duplicated (a second copy injected on the link)?
    pub fn duplicates(&self, slice: usize, version: u64) -> bool {
        self.dup_rate > 0.0
            && self.u01(STREAM_DUP, slice, version, 0) < self.dup_rate
    }

    /// Is the delivery of `attempt` held back by an in-flight delay?
    pub fn delayed(&self, slice: usize, version: u64, attempt: u64) -> bool {
        self.delay_rate > 0.0
            && self.u01(STREAM_DELAY, slice, version, attempt) < self.delay_rate
    }

    /// Seeded delay magnitude u ∈ [0, 1) for a delayed delivery — scales
    /// both the real link's hold duration and the virtual-time charge.
    pub fn delay_frac(&self, slice: usize, version: u64) -> f64 {
        self.u01(STREAM_DELAY_FRAC, slice, version, 0)
    }

    /// Real-link retransmit backoff before attempt `attempt + 1`:
    /// exponential from ~1 ms, capped at ~16 ms, with seeded jitter (full
    /// jitter keeps retransmit storms decorrelated across slices).
    pub fn backoff(
        &self,
        slice: usize,
        version: u64,
        attempt: u64,
    ) -> std::time::Duration {
        let base_us = 500u64 << attempt.min(5);
        let jitter = self.u01(STREAM_BACKOFF, slice, version, attempt);
        std::time::Duration::from_micros(
            base_us + (jitter * base_us as f64) as u64,
        )
    }

    /// Real-link hold duration for a delayed delivery (a few ms, seeded).
    pub fn delay_hold(
        &self,
        slice: usize,
        version: u64,
    ) -> std::time::Duration {
        std::time::Duration::from_micros(
            1_000 + (self.delay_frac(slice, version) * 3_000.0) as u64,
        )
    }

    /// Extra virtual seconds the fault layer charges the handoff of
    /// `slice` at `version`, for a forwarding sweep of `sweep_secs`:
    /// each modelled drop costs a retransmit round-trip
    /// (`RETX_FRAC`x sweep), and a delayed delivering attempt adds its
    /// seeded hold.  Mirrors the decisions the real link makes for the
    /// same (slice, version) keys; an empty plan returns exactly 0.0 so
    /// default-plan timelines stay bit-identical.
    pub fn virtual_latency(
        &self,
        slice: usize,
        version: u64,
        sweep_secs: f64,
    ) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        /// Retransmit cost as a fraction of the forwarding sweep.
        const RETX_FRAC: f64 = 0.25;
        /// Max hold fraction for a delayed delivery.
        const DELAY_FRAC: f64 = 0.5;
        /// Liveness bound for the *model*: past this many modelled
        /// drops the real link would have wedged into the recovery path,
        /// whose cost the engine accounts separately.
        const MAX_MODELED_RETRIES: u64 = 16;
        let mut extra = 0.0;
        let mut attempt = 1u64;
        while attempt <= MAX_MODELED_RETRIES
            && self.drops(slice, version, attempt)
        {
            extra += RETX_FRAC * sweep_secs;
            attempt += 1;
        }
        if self.delayed(slice, version, attempt) {
            extra += DELAY_FRAC * self.delay_frac(slice, version) * sweep_secs;
        }
        extra
    }
}

/// Per-round traffic accounting and time modelling.
#[derive(Debug)]
pub struct NetworkModel {
    cfg: NetworkConfig,
    n_workers: usize,
    /// Total bytes sent coordinator→worker p this round.
    down_bytes: Vec<u64>,
    /// Total bytes sent worker p→coordinator this round.
    up_bytes: Vec<u64>,
    /// Worker↔worker bytes this round (rotation slice passing): these
    /// traverse the worker links in parallel, NOT the coordinator hub.
    p2p_bytes: Vec<u64>,
    /// Lifetime counters.
    total_bytes: u64,
    total_msgs: u64,
    /// Lifetime bytes that moved worker↔worker (subset of `total_bytes`):
    /// rotation slice handoffs and KV-shard serving, which never cross
    /// the coordinator hub.
    total_p2p_bytes: u64,
    /// Lifetime count of worker↔worker transfers (rotation slice
    /// handoffs): one per [`NetworkModel::send_p2p`] between distinct
    /// endpoints.
    total_p2p_msgs: u64,
}

impl NetworkModel {
    pub fn new(cfg: NetworkConfig, n_workers: usize) -> Self {
        NetworkModel {
            cfg,
            n_workers,
            down_bytes: vec![0; n_workers],
            up_bytes: vec![0; n_workers],
            p2p_bytes: vec![0; n_workers],
            total_bytes: 0,
            total_msgs: 0,
            total_p2p_bytes: 0,
            total_p2p_msgs: 0,
        }
    }

    pub fn config(&self) -> NetworkConfig {
        self.cfg
    }

    /// Record a coordinator→worker message of `bytes` payload.
    pub fn send_down(&mut self, worker: usize, bytes: usize) {
        self.down_bytes[worker] += bytes as u64;
        self.total_bytes += bytes as u64;
        self.total_msgs += 1;
    }

    /// Record a worker→coordinator message of `bytes` payload.
    pub fn send_up(&mut self, worker: usize, bytes: usize) {
        self.up_bytes[worker] += bytes as u64;
        self.total_bytes += bytes as u64;
        self.total_msgs += 1;
    }

    /// Record a worker↔worker transfer (e.g. LDA's rotating word-topic
    /// slices, or a worker's KV-shard fetch served by a peer).  These run
    /// on the point links in parallel and bypass the hub, but the payload
    /// occupies *both* endpoints' links: the sender serializes it out and
    /// the receiver serializes it in.  (Charging only one side — the old
    /// behaviour — underestimated rotation-round comm time whenever the
    /// uncharged endpoint was otherwise idle.)  A self-transfer (`from ==
    /// to`) is a local move and costs nothing.
    pub fn send_p2p(&mut self, from: usize, to: usize, bytes: usize) {
        if from == to {
            return;
        }
        self.p2p_bytes[from] += bytes as u64;
        self.p2p_bytes[to] += bytes as u64;
        self.total_bytes += bytes as u64; // one payload on the wire
        self.total_p2p_bytes += bytes as u64;
        self.total_msgs += 1;
        self.total_p2p_msgs += 1;
    }

    /// Modelled communication time for the round, then reset round
    /// counters.  Round comm time = per-link max(latency + bytes/bw) for
    /// the parallel links, plus hub serialization of the aggregate bytes.
    pub fn round_time_and_reset(&mut self) -> f64 {
        let mut link_max = 0.0f64;
        let mut hub_bytes = 0u64;
        for p in 0..self.n_workers {
            let b = self.down_bytes[p] + self.up_bytes[p];
            let link_b = b + self.p2p_bytes[p];
            if link_b > 0 {
                let t = 2.0 * self.cfg.latency_s
                    + link_b as f64 / self.cfg.bandwidth_bps;
                link_max = link_max.max(t);
            }
            hub_bytes += b; // p2p traffic does not cross the hub
            self.down_bytes[p] = 0;
            self.up_bytes[p] = 0;
            self.p2p_bytes[p] = 0;
        }
        let hub_time = if self.cfg.hub_bandwidth_bps.is_finite() {
            hub_bytes as f64 / self.cfg.hub_bandwidth_bps
        } else {
            0.0
        };
        link_max.max(hub_time)
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }
    pub fn total_msgs(&self) -> u64 {
        self.total_msgs
    }
    /// Lifetime worker↔worker bytes (hub-bypassing traffic).
    pub fn total_p2p_bytes(&self) -> u64 {
        self.total_p2p_bytes
    }
    /// Lifetime worker↔worker transfer count (rotation slice handoffs).
    pub fn total_p2p_msgs(&self) -> u64 {
        self.total_p2p_msgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_includes_latency_and_bandwidth() {
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 1e-3, bandwidth_bps: 1e6, hub_bandwidth_bps: f64::INFINITY },
            2,
        );
        n.send_down(0, 1_000_000); // 1 s of bandwidth
        let t = n.round_time_and_reset();
        assert!((t - (2e-3 + 1.0)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn round_counters_reset() {
        let mut n = NetworkModel::new(NetworkConfig::gbps1(), 1);
        n.send_up(0, 1000);
        let t1 = n.round_time_and_reset();
        let t2 = n.round_time_and_reset();
        assert!(t1 > 0.0);
        assert_eq!(t2, 0.0);
        assert_eq!(n.total_bytes(), 1000);
    }

    #[test]
    fn hub_serializes_aggregate_traffic() {
        // 4 workers × 1MB each in parallel on 1MB/s links = ~1s per link,
        // but a 1MB/s hub must serialize 4MB = 4s.
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 0.0, bandwidth_bps: 1e6, hub_bandwidth_bps: 1e6 },
            4,
        );
        for p in 0..4 {
            n.send_up(p, 1_000_000);
        }
        let t = n.round_time_and_reset();
        assert!((t - 4.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn p2p_charges_both_endpoints_but_not_the_hub() {
        // 1MB peer transfer on 1MB/s links: either endpoint alone would be
        // busy 1s.  Loading the *receiver* with another 1MB of hub traffic
        // must make its link the 2s bottleneck — under one-sided charging
        // the receiver's link looked empty and the round cost only 1s.
        let cfg = NetworkConfig {
            latency_s: 0.0,
            bandwidth_bps: 1e6,
            hub_bandwidth_bps: f64::INFINITY,
        };
        let mut n = NetworkModel::new(cfg, 3);
        n.send_p2p(0, 1, 1_000_000);
        n.send_down(1, 1_000_000);
        let t = n.round_time_and_reset();
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
        // the payload itself is counted once, and tracked as p2p traffic
        assert_eq!(n.total_bytes(), 2_000_000);
        assert_eq!(n.total_p2p_bytes(), 1_000_000);
        assert_eq!(n.total_p2p_msgs(), 1);

        // hub-bound check: p2p bytes never serialize through the hub
        let mut n = NetworkModel::new(
            NetworkConfig { latency_s: 0.0, bandwidth_bps: 1e6, hub_bandwidth_bps: 1e6 },
            3,
        );
        n.send_p2p(0, 1, 1_000_000);
        let t = n.round_time_and_reset();
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn p2p_self_transfer_is_free() {
        let mut n = NetworkModel::new(NetworkConfig::gbps1(), 1);
        n.send_p2p(0, 0, 123_456);
        assert_eq!(n.round_time_and_reset(), 0.0);
        assert_eq!(n.total_bytes(), 0);
        assert_eq!(n.total_p2p_msgs(), 0);
    }

    #[test]
    fn ideal_network_is_free() {
        let mut n = NetworkModel::new(NetworkConfig::ideal(), 3);
        n.send_down(1, 123456);
        assert_eq!(n.round_time_and_reset(), 0.0);
    }

    #[test]
    fn handoff_jitter_is_deterministic_scaled_and_bounded() {
        assert_eq!(HandoffJitter::None.latency(3, 7, 0.5), 0.0);
        let u = HandoffJitter::Uniform { frac: 0.5 };
        assert!((u.latency(3, 7, 0.4) - 0.2).abs() < 1e-15);
        let j = HandoffJitter::Jittered {
            base_frac: 0.2,
            jitter_frac: 1.5,
            seed: 9,
        };
        let a = j.latency(3, 7, 1.0);
        assert_eq!(a, j.latency(3, 7, 1.0), "same key, same latency");
        assert!((0.2..0.2 + 1.5).contains(&a), "latency {a} out of band");
        assert_ne!(a, j.latency(4, 7, 1.0), "slice varies the draw");
        assert_ne!(a, j.latency(3, 8, 1.0), "round varies the draw");
        // scales linearly with the sweep
        assert!((j.latency(3, 7, 2.0) - 2.0 * a).abs() < 1e-12);
    }

    #[test]
    fn net_fault_plan_default_is_inert() {
        let p = NetFaultPlan::default();
        assert!(p.is_empty());
        assert!(p.validate().is_ok());
        for v in 0..64u64 {
            assert!(!p.drops(3, v, 1));
            assert!(!p.duplicates(3, v));
            assert!(!p.delayed(3, v, 1));
            assert_eq!(p.virtual_latency(3, v, 1.0), 0.0, "exact zero");
        }
    }

    #[test]
    fn net_fault_decisions_are_deterministic_and_seeded() {
        let p = NetFaultPlan {
            drop_rate: 0.3,
            dup_rate: 0.3,
            delay_rate: 0.3,
            seed: 17,
        };
        // same key -> same decision, every call
        for v in 0..32u64 {
            assert_eq!(p.drops(2, v, 1), p.drops(2, v, 1));
            assert_eq!(p.duplicates(2, v), p.duplicates(2, v));
            assert_eq!(p.virtual_latency(2, v, 1.0), p.virtual_latency(2, v, 1.0));
        }
        // a different seed reshuffles the schedule
        let q = NetFaultPlan { seed: 18, ..p };
        let differs = (0..256u64).any(|v| p.drops(2, v, 1) != q.drops(2, v, 1));
        assert!(differs, "seed must vary the drop schedule");
        // observed rates land near the configured probability
        let hits = (0..4096u64).filter(|&v| p.drops(2, v, 1)).count();
        let rate = hits as f64 / 4096.0;
        assert!((rate - 0.3).abs() < 0.05, "drop rate {rate} far from 0.3");
    }

    #[test]
    fn net_fault_streams_are_independent() {
        // raising drop_rate must not change which forwards duplicate
        let p = NetFaultPlan {
            drop_rate: 0.0,
            dup_rate: 0.4,
            delay_rate: 0.0,
            seed: 5,
        };
        let q = NetFaultPlan { drop_rate: 0.9, ..p };
        for v in 0..256u64 {
            assert_eq!(p.duplicates(7, v), q.duplicates(7, v));
        }
    }

    #[test]
    fn net_fault_validation_rejects_bad_rates() {
        let bad = |d, u, l| NetFaultPlan {
            drop_rate: d,
            dup_rate: u,
            delay_rate: l,
            seed: 0,
        };
        assert!(bad(1.5, 0.0, 0.0).validate().is_err());
        assert!(bad(0.0, -0.1, 0.0).validate().is_err());
        assert!(bad(0.0, 0.0, f64::NAN).validate().is_err());
        assert!(bad(1.0, 1.0, 1.0).validate().is_ok());
    }

    #[test]
    fn net_fault_virtual_latency_charges_drops_and_delays() {
        let p = NetFaultPlan {
            drop_rate: 0.5,
            dup_rate: 0.0,
            delay_rate: 0.5,
            seed: 23,
        };
        // some leg in the first few hundred versions must pay a charge,
        // and every charge scales linearly with the sweep
        let mut any = false;
        for v in 0..256u64 {
            let c1 = p.virtual_latency(4, v, 1.0);
            assert!(c1 >= 0.0 && c1.is_finite());
            assert!((p.virtual_latency(4, v, 2.0) - 2.0 * c1).abs() < 1e-12);
            any |= c1 > 0.0;
        }
        assert!(any, "50% drop + 50% delay charged nothing in 256 legs");
        // a total-loss plan is still finite (the model caps retransmits;
        // the real link wedges into the engine's recovery path instead)
        let wedge = NetFaultPlan {
            drop_rate: 1.0,
            dup_rate: 0.0,
            delay_rate: 0.0,
            seed: 1,
        };
        assert!(wedge.virtual_latency(0, 0, 1.0).is_finite());
    }

    #[test]
    fn net_fault_backoff_grows_and_caps() {
        let p = NetFaultPlan {
            drop_rate: 0.5,
            dup_rate: 0.0,
            delay_rate: 0.0,
            seed: 3,
        };
        let b1 = p.backoff(0, 1, 1);
        let b4 = p.backoff(0, 1, 4);
        assert!(b1 >= std::time::Duration::from_micros(500));
        assert!(b4 > b1, "backoff must grow with the attempt");
        // cap: attempt 50 stays in the same band as attempt 5
        assert!(p.backoff(0, 1, 50) <= std::time::Duration::from_millis(32));
        assert!(p.delay_hold(0, 1) >= std::time::Duration::from_millis(1));
        assert!(p.delay_hold(0, 1) <= std::time::Duration::from_millis(4));
    }

    #[test]
    fn faster_fabric_is_faster() {
        let mk = |cfg: NetworkConfig| {
            let mut n = NetworkModel::new(cfg, 1);
            n.send_down(0, 10_000_000);
            n.round_time_and_reset()
        };
        assert!(mk(NetworkConfig::gbps40()) < mk(NetworkConfig::gbps1()));
    }
}
