//! Worker thread pool with typed per-worker state and mailbox dispatch.
//!
//! Each simulated machine is an OS thread owning its `S` (data shard +
//! model caches).  The coordinator dispatches closures (push / sync / eval
//! jobs) to specific workers and collects replies together with the
//! *measured on-thread compute time*, which feeds the virtual cluster
//! clock.  Mailboxes are FIFO, so a `sync` enqueued before the next `push`
//! is always applied first — this ordering is what makes the engine's BSP
//! barrier correct (see coordinator::engine).

use crate::kvstore::{LeaseToken, RouterError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Job<S> = Box<dyn FnOnce(&mut S) + Send>;

/// Raw `clock_gettime` binding (the `libc` crate is unavailable offline;
/// the symbol itself is always present in the platform C library).
#[cfg(unix)]
mod sys {
    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    /// Linux value of CLOCK_THREAD_CPUTIME_ID (the build/CI target).
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;

    extern "C" {
        pub fn clock_gettime(clk_id: i32, tp: *mut Timespec) -> i32;
    }
}

/// Per-thread CPU seconds (CLOCK_THREAD_CPUTIME_ID).
///
/// The virtual cluster clock needs each worker's *own* compute time: on a
/// build machine with fewer cores than simulated workers, wall-clock
/// measurements would include preemption by sibling workers and destroy
/// the scaling curves (paper Fig 10).  Thread CPU time is
/// oversubscription-immune.
#[cfg(unix)]
pub fn thread_cpu_secs() -> f64 {
    let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    unsafe {
        sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Non-unix fallback: monotonic wall clock anchored at first use
/// (oversubscription-sensitive, but elapsed differences never go
/// negative the way a steppable system clock could).
#[cfg(not(unix))]
pub fn thread_cpu_secs() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Bounded wait for a [`ForwardQueue::take`] before it gives up, in
/// milliseconds.  Env-tunable (`STRADS_ROUTER_SPIN_MS`, parsed once) so a
/// scheduling bug that loses a handoff fails CI loudly after a bounded
/// condvar-parked wait instead of hanging the job; the default is
/// generous enough for any legitimate predecessor sweep.  (The name is
/// historical: waits used to busy-spin; they now park on per-slot
/// condvars and this is purely the deadline.)
pub fn router_spin_ms() -> u64 {
    use std::sync::OnceLock;
    static MS: OnceLock<u64> = OnceLock::new();
    *MS.get_or_init(|| {
        std::env::var("STRADS_ROUTER_SPIN_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(120_000)
    })
}

/// Slot-keyed, versioned, blocking handoff mailbox — the async forward
/// queue under worker→worker state migration (pipelined rotation,
/// [`crate::coordinator::ExecutionMode::Rotation`]).
///
/// Each slot holds at most one `(item, version)` pair.  A consumer
/// [`ForwardQueue::take`]s a *specific* version, blocking until the
/// producer (its ring predecessor) deposits it; depositing over an
/// unconsumed item panics, as does finding an unexpected version — both
/// are ordering violations in the handoff protocol, not recoverable
/// conditions.  Waits are bounded by [`router_spin_ms`] so a protocol
/// deadlock fails a test run loudly instead of hanging it;
/// [`ForwardQueue::try_take`] is the non-blocking poll availability-ordered
/// consumers use to sweep whichever slice landed first.
///
/// Storage is **sharded per slot** — one mutex + condvar per slice — so
/// under real concurrency (`--backend threads`) P workers touching P
/// different slices never contend on a global lock.  Multi-slot sweeps
/// ([`crate::kvstore::SliceRouter`]'s reordered disciplines) park on a
/// queue-wide deposit **epoch** ([`ForwardQueue::epoch`] /
/// [`ForwardQueue::wait_any_until`]) instead of polling: every deposit
/// bumps the epoch, so "wait until anything lands" is one condvar wait,
/// race-free as long as the epoch is read *before* scanning the slots.
/// All time consumers spend parked is metered
/// ([`ForwardQueue::blocked_secs`] → `SspStats::router_block_secs`).
#[derive(Debug)]
struct Shard<T> {
    slot: Mutex<Option<(T, u64)>>,
    ready: Condvar,
}

#[derive(Debug)]
pub struct ForwardQueue<T> {
    shards: Vec<Shard<T>>,
    /// Queue-wide deposit counter; bumped on every deposit.
    epoch: Mutex<u64>,
    /// Notified on every deposit: the park point for multi-slot sweeps.
    any_ready: Condvar,
    /// Nanoseconds consumers have spent parked on this queue's condvars.
    blocked_nanos: AtomicU64,
    n_slots: usize,
}

impl<T> ForwardQueue<T> {
    pub fn new(n_slots: usize) -> Self {
        ForwardQueue {
            shards: (0..n_slots)
                .map(|_| Shard {
                    slot: Mutex::new(None),
                    ready: Condvar::new(),
                })
                .collect(),
            epoch: Mutex::new(0),
            any_ready: Condvar::new(),
            blocked_nanos: AtomicU64::new(0),
            n_slots,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    fn note_blocked(&self, d: Duration) {
        self.blocked_nanos
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Cumulative seconds consumers have spent parked on this queue
    /// (slot takes and any-deposit sweeps).  ~0 in single-threaded
    /// drivers; the measured handoff contention under `--backend
    /// threads`.
    pub fn blocked_secs(&self) -> f64 {
        self.blocked_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Current deposit epoch.  Read it **before** scanning slots: a
    /// deposit that lands between the scan and a
    /// [`ForwardQueue::wait_any_until`] bumps the epoch, so the wait
    /// returns immediately instead of missing the wakeup.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().expect("forward queue poisoned")
    }

    /// Park until any deposit lands (epoch moves past `seen`) or
    /// `deadline` passes; returns the epoch at wakeup.  The condvar
    /// analogue of one sweep-poll backoff.
    pub fn wait_any_until(&self, seen: u64, deadline: std::time::Instant) -> u64 {
        let mut e = self.epoch.lock().expect("forward queue poisoned");
        while *e == seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .any_ready
                .wait_timeout(e, deadline - now)
                .expect("forward queue poisoned");
            self.note_blocked(now.elapsed());
            e = guard;
        }
        *e
    }

    /// Deposit `(item, version)` into `slot`.  Panics if the slot is
    /// occupied (the previous handoff was never consumed).
    pub fn deposit(&self, slot: usize, item: T, version: u64) {
        {
            let mut held =
                self.shards[slot].slot.lock().expect("forward queue poisoned");
            assert!(
                held.is_none(),
                "forward queue slot {slot} occupied (unconsumed handoff)"
            );
            *held = Some((item, version));
            self.shards[slot].ready.notify_all();
        }
        // shard lock released before the epoch bump: no path holds both
        let mut e = self.epoch.lock().expect("forward queue poisoned");
        *e += 1;
        self.any_ready.notify_all();
    }

    /// Block until `slot` holds exactly `version`, then take it.  Returns
    /// the item together with the version the *producer* deposited (the
    /// consumer's independent evidence of what it consumed).  Panics on a
    /// version mismatch (a protocol fork); a handoff that never arrives
    /// within the [`router_spin_ms`] deadline is a *liveness* fault and
    /// returns a typed [`RouterError`] instead — the queue layer's
    /// `chain_head` is best-effort (the parked version, if any;
    /// [`crate::kvstore::SliceRouter::take`] reports the true chain head).
    pub fn take(&self, slot: usize, version: u64) -> Result<(T, u64), RouterError> {
        let ms = router_spin_ms();
        self.take_for(slot, version, Duration::from_millis(ms))
            .ok_or_else(|| RouterError {
                slice_id: slot,
                version,
                chain_head: self.parked_version(slot).unwrap_or(0),
                suspected_holder: None,
                waited_ms: ms,
            })
    }

    /// Like [`ForwardQueue::take`] with an explicit deadline: `None` after
    /// `timeout` with no consumable deposit (callers add their own
    /// protocol context before failing).
    ///
    /// Version discipline: a parked version **older** than the awaited one
    /// is legitimate pipeline lag — its own consumer (a different, slower
    /// worker) has not collected it yet, and this taker's version can only
    /// be deposited after that happens, so the wait continues.  A parked
    /// version **newer** than the awaited one means the awaited deposit
    /// was consumed by someone else or skipped — an upstream ordering
    /// violation, and it panics.  (The pre-availability code panicked on
    /// *any* mismatch, which could fire spuriously when one worker ran a
    /// full pipelined round ahead of a slice's lagging consumer.)
    pub fn take_for(
        &self,
        slot: usize,
        version: u64,
        timeout: Duration,
    ) -> Option<(T, u64)> {
        let deadline = std::time::Instant::now() + timeout;
        let shard = &self.shards[slot];
        let mut held = shard.slot.lock().expect("forward queue poisoned");
        loop {
            if let Some(v) = held.as_ref().map(|(_, v)| *v) {
                assert!(
                    v <= version,
                    "forward queue slot {slot}: expected version {version}, found {v}"
                );
                if v == version {
                    return held.take();
                }
                // v < version: the older deposit's own consumer is still
                // on its way; our deposit comes after — keep waiting
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = shard
                .ready
                .wait_timeout(held, deadline - now)
                .expect("forward queue poisoned");
            self.note_blocked(now.elapsed());
            held = guard;
        }
    }

    /// Non-blocking poll: take the slot's deposit if (and only if) it
    /// currently holds exactly `version`.  An empty slot — or one parking
    /// an **older** version still awaiting its own consumer — returns
    /// `None` (the handoff is in flight from this taker's point of view);
    /// a **newer** parked version panics, exactly as [`ForwardQueue::take`]
    /// would: the awaited deposit can no longer arrive.
    pub fn try_take(&self, slot: usize, version: u64) -> Option<(T, u64)> {
        let mut held =
            self.shards[slot].slot.lock().expect("forward queue poisoned");
        match held.as_ref().map(|(_, v)| *v) {
            Some(v) => {
                assert!(
                    v <= version,
                    "forward queue slot {slot}: expected version {version}, found {v}"
                );
                if v == version {
                    held.take()
                } else {
                    None
                }
            }
            None => None,
        }
    }

    /// Version of the slot's parked deposit, without consuming it
    /// (`None` while the handoff is in flight).
    pub fn parked_version(&self, slot: usize) -> Option<u64> {
        self.shards[slot]
            .slot
            .lock()
            .expect("forward queue poisoned")
            .as_ref()
            .map(|(_, v)| *v)
    }

    /// Non-blocking removal of whatever the slot currently holds.
    pub fn reclaim(&self, slot: usize) -> Option<(T, u64)> {
        self.shards[slot]
            .slot
            .lock()
            .expect("forward queue poisoned")
            .take()
    }

    /// Inspect a slot without consuming it.
    pub fn with_slot<R>(&self, slot: usize, f: impl FnOnce(Option<&(T, u64)>) -> R) -> R {
        f(self.shards[slot]
            .slot
            .lock()
            .expect("forward queue poisoned")
            .as_ref())
    }
}

/// Pool of worker threads, one per simulated machine.
///
/// Membership is **elastic**: [`WorkerPool::kill`] really stops a worker's
/// OS thread (fault injection, under both execution backends) and parks
/// its state; [`WorkerPool::revive`] respawns the thread from the parked
/// state.  While a worker is down, jobs addressed to it run *inline* on
/// the dispatching (coordinator) thread against the parked state — the
/// frozen shard keeps receiving syncs and being evaluated, so reply
/// arithmetic stays dense (`collect` always sees `n_workers` replies) and
/// the objective stays comparable across a fault.  The engine must only
/// address non-blocking (lease-free) jobs to dead workers, or the inline
/// run would stall the coordinator.
pub struct WorkerPool<S> {
    senders: Vec<Option<mpsc::Sender<Job<S>>>>,
    handles: Vec<Option<JoinHandle<S>>>,
    /// Killed workers' states, frozen after their mailbox drained
    /// (`Mutex` because inline jobs mutate them through `&self`).
    parked: Vec<Mutex<Option<S>>>,
}

impl<S: Send + 'static> WorkerPool<S> {
    /// Spawn one thread per element of `states`.
    pub fn new(states: Vec<S>) -> Self {
        let n = states.len();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (p, state) in states.into_iter().enumerate() {
            let (tx, h) = Self::spawn_worker(p, state);
            senders.push(Some(tx));
            handles.push(Some(h));
        }
        WorkerPool {
            senders,
            handles,
            parked: (0..n).map(|_| Mutex::new(None)).collect(),
        }
    }

    fn spawn_worker(
        p: usize,
        mut state: S,
    ) -> (mpsc::Sender<Job<S>>, JoinHandle<S>) {
        let (tx, rx) = mpsc::channel::<Job<S>>();
        let handle = std::thread::Builder::new()
            .name(format!("strads-worker-{p}"))
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    job(&mut state);
                }
                state // handed back to kill(): the shard outlives its thread
            })
            .expect("spawn worker");
        (tx, handle)
    }

    /// Stop worker `p`'s OS thread (crash injection).  Closing the mailbox
    /// lets the thread drain every already-enqueued job first — no sync or
    /// push dispatched before the kill is lost — then the thread exits and
    /// its state is parked for inline jobs and a later
    /// [`WorkerPool::revive`].  Panics if the worker is already dead.
    pub fn kill(&mut self, p: usize) {
        let tx = self.senders[p]
            .take()
            .unwrap_or_else(|| panic!("worker {p} is already dead"));
        drop(tx); // closes the mailbox; the thread drains it and exits
        let h = self.handles[p].take().expect("live worker has a handle");
        let state = h.join().expect("worker thread panicked");
        *self.parked[p].lock().expect("parked state poisoned") = Some(state);
    }

    /// Restart worker `p` from its parked state (elastic re-join).  The
    /// new OS thread resumes exactly where the dead one stopped — plus
    /// whatever inline jobs ran against the parked state in between.
    /// Panics if the worker is live or was never killed.
    pub fn revive(&mut self, p: usize) {
        assert!(self.senders[p].is_none(), "worker {p} is already live");
        let state = self.parked[p]
            .lock()
            .expect("parked state poisoned")
            .take()
            .unwrap_or_else(|| panic!("worker {p} has no parked state"));
        let (tx, h) = Self::spawn_worker(p, state);
        self.senders[p] = Some(tx);
        self.handles[p] = Some(h);
    }

    /// Whether worker `p`'s OS thread is currently running.
    pub fn is_live(&self, p: usize) -> bool {
        self.senders[p].is_some()
    }

    /// Number of workers with a live OS thread.
    pub fn n_live(&self) -> usize {
        self.senders.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Run one job against a dead worker's parked state on the calling
    /// thread, returning the result and the measured inline CPU seconds.
    fn run_inline<R>(&self, p: usize, job: impl FnOnce(&mut S) -> R) -> (R, f64) {
        let mut parked = self.parked[p].lock().expect("parked state poisoned");
        let state = parked
            .as_mut()
            .unwrap_or_else(|| panic!("worker {p} has no parked state"));
        let t0 = thread_cpu_secs();
        let out = job(state);
        (out, thread_cpu_secs() - t0)
    }

    /// Run `make_job(p)`'s closure on every worker; collect results in
    /// worker order along with per-worker on-thread seconds.
    pub fn run<R, F, G>(&self, make_job: G) -> Vec<(R, f64)>
    where
        R: Send + 'static,
        F: FnOnce(&mut S) -> R + Send + 'static,
        G: Fn(usize) -> F,
    {
        self.dispatch(make_job).collect()
    }

    /// Enqueue `make_job(p)`'s closure on every worker *without waiting*:
    /// the returned handle collects the replies later.  This is the
    /// non-blocking half of the SSP pipeline — the coordinator can dispatch
    /// round t+1 while round t is still computing, and FIFO mailboxes keep
    /// per-worker ordering intact.
    pub fn dispatch<R, F, G>(&self, make_job: G) -> PendingRound<R>
    where
        R: Send + 'static,
        F: FnOnce(&mut S) -> R + Send + 'static,
        G: Fn(usize) -> F,
    {
        let (rtx, rrx) = mpsc::channel::<(usize, R, f64)>();
        for (p, sender) in self.senders.iter().enumerate() {
            let job = make_job(p);
            match sender {
                Some(sender) => {
                    let rtx = rtx.clone();
                    let wrapped: Job<S> = Box::new(move |state: &mut S| {
                        let t0 = thread_cpu_secs();
                        let out = job(state);
                        let secs = thread_cpu_secs() - t0;
                        // receiver never hangs up before collecting
                        let _ = rtx.send((p, out, secs));
                    });
                    sender.send(wrapped).expect("worker thread alive");
                }
                None => {
                    // dead worker: run inline so the round stays dense
                    let (out, secs) = self.run_inline(p, job);
                    let _ = rtx.send((p, out, secs));
                }
            }
        }
        PendingRound { rrx, n_workers: self.senders.len(), leases: Vec::new() }
    }

    /// Run a job on a single worker and wait for its result.
    pub fn run_on<R, F>(&self, p: usize, job: F) -> (R, f64)
    where
        R: Send + 'static,
        F: FnOnce(&mut S) -> R + Send + 'static,
    {
        match &self.senders[p] {
            Some(sender) => {
                let (rtx, rrx) = mpsc::channel::<(R, f64)>();
                let wrapped: Job<S> = Box::new(move |state: &mut S| {
                    let t0 = thread_cpu_secs();
                    let out = job(state);
                    let _ = rtx.send((out, thread_cpu_secs() - t0));
                });
                sender.send(wrapped).expect("worker thread alive");
                rrx.recv().expect("worker reply")
            }
            None => self.run_inline(p, job),
        }
    }

    /// Fire-and-forget broadcast (sync messages): FIFO mailboxes guarantee
    /// application before any later push on the same worker.
    pub fn broadcast<F, G>(&self, make_job: G)
    where
        F: FnOnce(&mut S) + Send + 'static,
        G: Fn(usize) -> F,
    {
        for (p, sender) in self.senders.iter().enumerate() {
            let job = make_job(p);
            match sender {
                Some(sender) => {
                    let wrapped: Job<S> =
                        Box::new(move |state: &mut S| job(state));
                    sender.send(wrapped).expect("worker thread alive");
                }
                // dead worker: apply to the parked state so the frozen
                // shard keeps receiving syncs and stays evaluable
                None => drop(self.run_inline(p, job)),
            }
        }
    }
}

/// In-flight results of one [`WorkerPool::dispatch`] call.
///
/// Holding several `PendingRound`s at once is what pipelines rounds: each
/// carries its own reply channel, so collects can happen strictly in
/// dispatch order (the engine's SSP window) without blocking dispatches.
pub struct PendingRound<R> {
    rrx: mpsc::Receiver<(usize, R, f64)>,
    n_workers: usize,
    /// Rotation mode: the leases each worker's in-flight task consumes, in
    /// sweep order (index-aligned with workers; one lease per slice of the
    /// worker's queue — several when U > P slices rotate over P workers;
    /// empty outside rotation).  The engine cross-checks these against the
    /// legs the collected partials report.
    leases: Vec<Vec<LeaseToken>>,
}

impl<R> PendingRound<R> {
    /// Attach the in-flight lease tokens (one queue per worker,
    /// index-aligned, sweep order).
    pub fn set_leases(&mut self, leases: Vec<Vec<LeaseToken>>) {
        self.leases = leases;
    }

    /// The in-flight lease tokens recorded at dispatch.
    pub fn leases(&self) -> &[Vec<LeaseToken>] {
        &self.leases
    }

    /// Block until every worker has replied; results in worker order with
    /// per-worker on-thread seconds.
    pub fn collect(self) -> Vec<(R, f64)> {
        let mut slots: Vec<Option<(R, f64)>> =
            (0..self.n_workers).map(|_| None).collect();
        for _ in 0..self.n_workers {
            let (p, r, secs) = self.rrx.recv().expect("worker reply");
            slots[p] = Some((r, secs));
        }
        slots.into_iter().map(|s| s.expect("all replied")).collect()
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }
}

impl<S> Drop for WorkerPool<S> {
    fn drop(&mut self) {
        self.senders.clear(); // closes mailboxes; threads exit their loop
        for h in self.handles.drain(..).flatten() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_returns_results_in_worker_order() {
        let pool = WorkerPool::new(vec![10i64, 20, 30]);
        let out = pool.run(|p| move |s: &mut i64| *s + p as i64);
        let values: Vec<i64> = out.iter().map(|(v, _)| *v).collect();
        assert_eq!(values, vec![10, 21, 32]);
        assert!(out.iter().all(|(_, secs)| *secs >= 0.0));
    }

    #[test]
    fn state_persists_across_jobs() {
        let pool = WorkerPool::new(vec![0usize; 2]);
        pool.run(|_| |s: &mut usize| *s += 1);
        pool.run(|_| |s: &mut usize| *s += 1);
        let out = pool.run(|_| |s: &mut usize| *s);
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![2, 2]);
    }

    #[test]
    fn broadcast_applies_before_later_run() {
        let pool = WorkerPool::new(vec![0i64; 4]);
        pool.broadcast(|_| |s: &mut i64| *s = 7);
        let out = pool.run(|_| |s: &mut i64| *s);
        assert!(out.iter().all(|(v, _)| *v == 7));
    }

    #[test]
    fn run_on_targets_one_worker() {
        let pool = WorkerPool::new(vec![1i64, 2]);
        let (v, _) = pool.run_on(1, |s: &mut i64| {
            *s *= 10;
            *s
        });
        assert_eq!(v, 20);
        let all = pool.run(|_| |s: &mut i64| *s);
        assert_eq!(all.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![1, 20]);
    }

    #[test]
    fn pool_drop_joins_threads() {
        let pool = WorkerPool::new(vec![(); 8]);
        drop(pool); // must not deadlock
    }

    #[test]
    fn dispatch_pipelines_two_rounds_in_fifo_order() {
        // two dispatches before any collect: each worker must run job A
        // then job B (FIFO), and each handle must see its own round.
        let pool = WorkerPool::new(vec![Vec::<u32>::new(); 3]);
        let a = pool.dispatch(|_| {
            |s: &mut Vec<u32>| {
                s.push(1);
                s.clone()
            }
        });
        let b = pool.dispatch(|_| {
            |s: &mut Vec<u32>| {
                s.push(2);
                s.clone()
            }
        });
        let ra = a.collect();
        let rb = b.collect();
        assert!(ra.iter().all(|(v, _)| v == &vec![1]));
        assert!(rb.iter().all(|(v, _)| v == &vec![1, 2]));
    }

    #[test]
    fn forward_queue_blocks_until_the_version_arrives() {
        use std::sync::Arc;
        let q = Arc::new(ForwardQueue::new(2));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.take(1, 4));
        std::thread::sleep(Duration::from_millis(20));
        q.deposit(1, "slice".to_string(), 4);
        let (item, v) = h.join().expect("taker thread").expect("deposit landed");
        assert_eq!((item.as_str(), v), ("slice", 4));
        assert!(q.reclaim(1).is_none());
    }

    #[test]
    #[should_panic(expected = "occupied")]
    fn forward_queue_double_deposit_panics() {
        let q = ForwardQueue::new(1);
        q.deposit(0, 1u8, 0);
        q.deposit(0, 2u8, 1);
    }

    #[test]
    #[should_panic(expected = "expected version")]
    fn forward_queue_version_mismatch_panics() {
        let q = ForwardQueue::new(1);
        q.deposit(0, 1u8, 3);
        let _ = q.take(0, 2);
    }

    #[test]
    fn forward_queue_try_take_polls_without_blocking() {
        let q = ForwardQueue::new(2);
        assert!(q.try_take(0, 0).is_none(), "empty slot polls None");
        assert_eq!(q.parked_version(0), None);
        q.deposit(0, 5u8, 3);
        assert_eq!(q.parked_version(0), Some(3));
        assert_eq!(q.try_take(0, 3), Some((5u8, 3)));
        assert!(q.try_take(0, 3).is_none(), "second poll finds it gone");
    }

    #[test]
    #[should_panic(expected = "expected version")]
    fn forward_queue_try_take_version_mismatch_panics() {
        let q = ForwardQueue::new(1);
        q.deposit(0, 1u8, 3);
        let _ = q.try_take(0, 2);
    }

    #[test]
    fn forward_queue_older_parked_version_keeps_taker_waiting() {
        // a pipelined ring can run one consumer a full round ahead of a
        // slice's lagging consumer: the old deposit sits unconsumed, and
        // the future-round taker must WAIT (not panic) until the chain
        // catches up.
        let q = ForwardQueue::new(1);
        q.deposit(0, 7u8, 2);
        assert!(q.try_take(0, 3).is_none(), "older deposit is not ours");
        assert!(
            q.take_for(0, 3, Duration::from_millis(20)).is_none(),
            "older deposit must keep the round-3 taker waiting"
        );
        // the lagging consumer catches up; the chain advances; our take
        // now succeeds
        assert_eq!(q.try_take(0, 2), Some((7u8, 2)));
        q.deposit(0, 8u8, 3);
        assert_eq!(q.take(0, 3).unwrap(), (8u8, 3));
    }

    #[test]
    fn forward_queue_take_for_times_out_cleanly() {
        let q: ForwardQueue<u8> = ForwardQueue::new(1);
        let t0 = std::time::Instant::now();
        assert!(q.take_for(0, 0, Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // a deposit after the timeout is still takeable
        q.deposit(0, 9, 0);
        assert_eq!(q.take_for(0, 0, Duration::from_millis(20)), Some((9, 0)));
    }

    #[test]
    fn forward_queue_epoch_counts_deposits_and_wakes_waiters() {
        use std::sync::Arc;
        let q: Arc<ForwardQueue<u8>> = Arc::new(ForwardQueue::new(3));
        assert_eq!(q.epoch(), 0);
        q.deposit(0, 1, 0);
        q.deposit(2, 2, 0);
        assert_eq!(q.epoch(), 2);
        // a waiter parked on the pre-deposit epoch wakes on the next one
        let q2 = Arc::clone(&q);
        let seen = q.epoch();
        let h = std::thread::spawn(move || {
            q2.wait_any_until(
                seen,
                std::time::Instant::now() + Duration::from_secs(5),
            )
        });
        std::thread::sleep(Duration::from_millis(20));
        q.deposit(1, 3, 0);
        assert_eq!(h.join().expect("waiter"), 3);
    }

    #[test]
    fn forward_queue_wait_any_returns_immediately_on_missed_deposit() {
        // the scan-then-park race: if a deposit landed after the caller
        // read the epoch, the wait must not block at all
        let q: ForwardQueue<u8> = ForwardQueue::new(1);
        let seen = q.epoch();
        q.deposit(0, 9, 0);
        let t0 = std::time::Instant::now();
        let e = q.wait_any_until(seen, t0 + Duration::from_secs(5));
        assert_eq!(e, seen + 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not park");
    }

    #[test]
    fn forward_queue_wait_any_times_out_at_the_deadline() {
        let q: ForwardQueue<u8> = ForwardQueue::new(1);
        let t0 = std::time::Instant::now();
        let e = q.wait_any_until(q.epoch(), t0 + Duration::from_millis(20));
        assert_eq!(e, 0, "no deposit ever landed");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert!(q.blocked_secs() > 0.0, "parked time is metered");
    }

    #[test]
    fn forward_queue_meters_blocked_time_on_slot_takes() {
        let q: ForwardQueue<u8> = ForwardQueue::new(1);
        assert_eq!(q.blocked_secs(), 0.0, "nothing parked yet");
        let _ = q.take_for(0, 0, Duration::from_millis(25));
        assert!(q.blocked_secs() >= 0.02, "the timed-out wait was parked");
    }

    #[test]
    fn kill_stops_the_thread_and_parks_state_for_inline_jobs() {
        let mut pool = WorkerPool::new(vec![0i64; 3]);
        pool.run(|_| |s: &mut i64| *s += 1);
        pool.kill(1);
        assert!(!pool.is_live(1));
        assert_eq!(pool.n_live(), 2);
        // dispatched work still covers the dead worker (inline), so the
        // round stays dense and the frozen shard keeps up with syncs
        let out = pool.run(|_| |s: &mut i64| {
            *s += 1;
            *s
        });
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), [2, 2, 2]);
        pool.broadcast(|_| |s: &mut i64| *s += 10);
        let (v, _) = pool.run_on(1, |s: &mut i64| *s);
        assert_eq!(v, 12, "broadcast reached the parked state");
        // revive: the new OS thread resumes from the parked state
        pool.revive(1);
        assert!(pool.is_live(1));
        assert_eq!(pool.n_live(), 3);
        let out = pool.run(|_| |s: &mut i64| *s);
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), [12, 12, 12]);
    }

    #[test]
    fn kill_drains_the_mailbox_before_parking() {
        // a job already enqueued when the kill lands must be applied to
        // the state before it parks — no dispatched sync is ever lost
        let mut pool = WorkerPool::new(vec![Vec::<u32>::new(); 2]);
        let pending = pool.dispatch(|_| {
            |s: &mut Vec<u32>| {
                s.push(7);
                s.len()
            }
        });
        pool.kill(0);
        let out = pending.collect();
        assert_eq!(out.iter().map(|(n, _)| *n).collect::<Vec<_>>(), [1, 1]);
        let (state, _) = pool.run_on(0, |s: &mut Vec<u32>| s.clone());
        assert_eq!(state, vec![7]);
    }

    #[test]
    #[should_panic(expected = "already dead")]
    fn killing_a_dead_worker_panics() {
        let mut pool = WorkerPool::new(vec![(); 2]);
        pool.kill(0);
        pool.kill(0);
    }

    #[test]
    #[should_panic(expected = "already live")]
    fn reviving_a_live_worker_panics() {
        let mut pool = WorkerPool::new(vec![(); 2]);
        pool.revive(1);
    }

    #[test]
    fn dispatch_interleaves_with_broadcast_in_order() {
        // dispatch(push t) ; broadcast(sync t) ; dispatch(push t+1):
        // the sync must land between the two pushes on every worker.
        let pool = WorkerPool::new(vec![Vec::<u32>::new(); 4]);
        let t0 = pool.dispatch(|_| {
            |s: &mut Vec<u32>| {
                s.push(10);
            }
        });
        pool.broadcast(|_| |s: &mut Vec<u32>| s.push(99));
        let t1 = pool.dispatch(|_| |s: &mut Vec<u32>| s.clone());
        t0.collect();
        let out = t1.collect();
        assert!(out.iter().all(|(v, _)| v == &vec![10, 99]));
    }
}
