//! Sparse matrix substrate (CSC/CSR, f32) for the Lasso and MF workloads.
//!
//! The paper's Lasso design matrix has 25 non-zeros per column out of 50K
//! rows (§4.1), and the Netflix rating matrix is ~1.2% dense; both demand a
//! sparse representation to reach the paper's model sizes.  The native
//! compute backend operates directly on these structures.

pub mod csc;
pub mod csr;
pub mod ops;

pub use csc::{CscBuilder, CscMatrix};
pub use csr::CsrMatrix;
