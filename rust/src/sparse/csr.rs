//! Compressed Sparse Row matrix — the MF workhorse (per-user rating rows)
//! and LDA doc-token access pattern.

/// CSR matrix with f32 values and u32 column indices.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets (need not be sorted).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(u32, u32, f32)],
    ) -> Self {
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); rows];
        for &(r, c, v) in triplets {
            assert!((r as usize) < rows && (c as usize) < cols);
            per_row[r as usize].push((c, v));
        }
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in row.iter() {
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Iterate (col, value) over row i.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Raw slices for row i: (col indices, values).
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        (&self.col_idx[lo..hi], &self.values[lo..hi])
    }

    /// Mutable values of row i (residual maintenance in MF CD).
    pub fn row_values_mut(&mut self, i: usize) -> &mut [f32] {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        &mut self.values[lo..hi]
    }

    /// Restrict to row range [lo, hi) — worker data partitioning.
    pub fn row_slice(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let base = self.row_ptr[lo];
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            row_ptr: self.row_ptr[lo..=hi].iter().map(|p| p - base).collect(),
            col_idx: self.col_idx[self.row_ptr[lo]..self.row_ptr[hi]].to_vec(),
            values: self.values[self.row_ptr[lo]..self.row_ptr[hi]].to_vec(),
        }
    }

    /// Transpose to CSC-like CSR (cols become rows).
    pub fn transpose(&self) -> CsrMatrix {
        let mut trips = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                trips.push((c, i as u32, v));
            }
        }
        CsrMatrix::from_triplets(self.cols, self.rows, &trips)
    }

    /// Dense row-major conversion (tests / XLA staging only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                out[i * self.cols + c as usize] = v;
            }
        }
        out
    }

    /// 0/1 observation mask, dense row-major (XLA staging).
    pub fn to_dense_mask(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for i in 0..self.rows {
            for (c, _) in self.row_iter(i) {
                out[i * self.cols + c as usize] = 1.0;
            }
        }
        out
    }

    /// Resident bytes (memory accounting).
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * 4
            + self.values.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0]]
        CsrMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)])
    }

    #[test]
    fn dims_and_nnz() {
        let m = sample();
        assert_eq!((m.rows(), m.cols(), m.nnz()), (2, 3, 3));
        assert_eq!(m.row_nnz(0), 2);
    }

    #[test]
    fn dense_roundtrip() {
        assert_eq!(sample().to_dense(), vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        assert_eq!(
            sample().to_dense_mask(),
            vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]
        );
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.to_dense(), vec![1.0, 0.0, 0.0, 3.0, 2.0, 0.0]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn row_slice_shards() {
        let m = sample();
        let bottom = m.row_slice(1, 2);
        assert_eq!(bottom.to_dense(), vec![0.0, 3.0, 0.0]);
    }

    #[test]
    fn row_values_mut_edits_in_place() {
        let mut m = sample();
        m.row_values_mut(0)[1] = 9.0;
        assert_eq!(m.to_dense(), vec![1.0, 0.0, 9.0, 0.0, 3.0, 0.0]);
    }
}
