//! Compressed Sparse Column matrix — the Lasso workhorse (column access:
//! x_j^T r dot products, residual updates, pairwise column correlations).

/// CSC matrix with f32 values and u32 row indices (halves memory vs usize —
/// matters at the paper's 100M-feature scale).
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    /// col_ptr[j]..col_ptr[j+1] indexes into row_idx/values for column j.
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f32>,
}

/// Incremental builder: push columns in order.
pub struct CscBuilder {
    rows: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CscBuilder {
    pub fn new(rows: usize) -> Self {
        CscBuilder { rows, col_ptr: vec![0], row_idx: Vec::new(), values: Vec::new() }
    }

    /// Append one column given (row, value) pairs; rows must be strictly
    /// increasing and in range.
    pub fn push_col(&mut self, entries: &[(u32, f32)]) {
        let mut last: i64 = -1;
        for &(r, v) in entries {
            assert!((r as usize) < self.rows, "row {r} out of range");
            assert!((r as i64) > last, "rows must be strictly increasing");
            last = r as i64;
            self.row_idx.push(r);
            self.values.push(v);
        }
        self.col_ptr.push(self.row_idx.len());
    }

    pub fn finish(self) -> CscMatrix {
        CscMatrix {
            rows: self.rows,
            cols: self.col_ptr.len() - 1,
            col_ptr: self.col_ptr,
            row_idx: self.row_idx,
            values: self.values,
        }
    }
}

impl CscMatrix {
    /// Build from (row, col, value) triplets (need not be sorted).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(u32, u32, f32)],
    ) -> Self {
        let mut per_col: Vec<Vec<(u32, f32)>> = vec![Vec::new(); cols];
        for &(r, c, v) in triplets {
            per_col[c as usize].push((r, v));
        }
        let mut b = CscBuilder::new(rows);
        for col in per_col.iter_mut() {
            col.sort_unstable_by_key(|&(r, _)| r);
            b.push_col(col);
        }
        b.finish()
    }

    /// Dense (row-major) conversion — small matrices / tests only.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for j in 0..self.cols {
            for (r, v) in self.col_iter(j) {
                out[r as usize * self.cols + j] = v;
            }
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Number of non-zeros in column j.
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Iterate (row, value) over column j.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Raw slices for column j: (row indices, values).
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        (&self.row_idx[lo..hi], &self.values[lo..hi])
    }

    /// x_j^T v for a dense vector v over this matrix's rows.
    #[inline]
    pub fn col_dot_dense(&self, j: usize, v: &[f32]) -> f32 {
        debug_assert_eq!(v.len(), self.rows);
        let (idx, vals) = self.col(j);
        let mut s = 0.0f32;
        for (r, x) in idx.iter().zip(vals.iter()) {
            s += x * unsafe { *v.get_unchecked(*r as usize) };
        }
        s
    }

    /// v += alpha * x_j (scatter into a dense vector).
    #[inline]
    pub fn col_axpy_dense(&self, j: usize, alpha: f32, v: &mut [f32]) {
        debug_assert_eq!(v.len(), self.rows);
        let (idx, vals) = self.col(j);
        for (r, x) in idx.iter().zip(vals.iter()) {
            unsafe {
                *v.get_unchecked_mut(*r as usize) += alpha * x;
            }
        }
    }

    /// Squared l2 norm of column j.
    pub fn col_norm_sq(&self, j: usize) -> f32 {
        let (_, vals) = self.col(j);
        vals.iter().map(|x| x * x).sum()
    }

    /// Exact sparse dot product x_j^T x_k (sorted-merge intersection).
    pub fn col_dot_col(&self, j: usize, k: usize) -> f32 {
        let (ij, vj) = self.col(j);
        let (ik, vk) = self.col(k);
        let (mut a, mut b, mut s) = (0usize, 0usize, 0.0f32);
        while a < ij.len() && b < ik.len() {
            match ij[a].cmp(&ik[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += vj[a] * vk[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    /// y = A beta (dense result over rows).
    pub fn matvec(&self, beta: &[f32]) -> Vec<f32> {
        debug_assert_eq!(beta.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                self.col_axpy_dense(j, bj, &mut y);
            }
        }
        y
    }

    /// Restrict to a contiguous row range [lo, hi): the data-partitioning
    /// primitive (each worker holds a row shard, paper §2 push).
    pub fn row_slice(&self, lo: usize, hi: usize) -> CscMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let mut b = CscBuilder::new(hi - lo);
        let mut buf = Vec::new();
        for j in 0..self.cols {
            buf.clear();
            for (r, v) in self.col_iter(j) {
                let r = r as usize;
                if r >= lo && r < hi {
                    buf.push(((r - lo) as u32, v));
                }
            }
            b.push_col(&buf);
        }
        b.finish()
    }

    /// Gather selected columns into a dense row-major (rows × sel.len())
    /// block — feeds the fixed-shape XLA artifacts.
    pub fn gather_cols_dense(&self, sel: &[usize]) -> Vec<f32> {
        let u = sel.len();
        let mut out = vec![0.0f32; self.rows * u];
        for (c, &j) in sel.iter().enumerate() {
            for (r, v) in self.col_iter(j) {
                out[r as usize * u + c] = v;
            }
        }
        out
    }

    /// Model+data bytes resident for this matrix (memory accounting).
    pub fn bytes(&self) -> usize {
        self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.row_idx.len() * 4
            + self.values.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn build_and_dims() {
        let m = sample();
        assert_eq!((m.rows(), m.cols(), m.nnz()), (3, 3, 5));
        assert_eq!(m.col_nnz(0), 2);
        assert_eq!(m.col_nnz(1), 1);
    }

    #[test]
    fn to_dense_roundtrip() {
        let m = sample();
        assert_eq!(
            m.to_dense(),
            vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0, 5.0]
        );
    }

    #[test]
    fn col_dot_dense_matches_dense() {
        let m = sample();
        let v = [1.0, 2.0, 3.0];
        assert_eq!(m.col_dot_dense(0, &v), 1.0 + 12.0);
        assert_eq!(m.col_dot_dense(1, &v), 6.0);
        assert_eq!(m.col_dot_dense(2, &v), 2.0 + 15.0);
    }

    #[test]
    fn col_axpy_scatters() {
        let m = sample();
        let mut v = vec![0.0; 3];
        m.col_axpy_dense(2, 2.0, &mut v);
        assert_eq!(v, vec![4.0, 0.0, 10.0]);
    }

    #[test]
    fn col_dot_col_intersects() {
        let m = sample();
        assert_eq!(m.col_dot_col(0, 2), 1.0 * 2.0 + 4.0 * 5.0);
        assert_eq!(m.col_dot_col(0, 1), 0.0);
        assert_eq!(m.col_dot_col(1, 1), 9.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let y = m.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0 + 6.0, 6.0, 4.0 + 15.0]);
    }

    #[test]
    fn row_slice_partitions() {
        let m = sample();
        let top = m.row_slice(0, 2);
        assert_eq!(top.to_dense(), vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let bot = m.row_slice(2, 3);
        assert_eq!(bot.to_dense(), vec![4.0, 0.0, 5.0]);
        // shards tile the matrix exactly
        assert_eq!(top.nnz() + bot.nnz(), m.nnz());
    }

    #[test]
    fn gather_cols_dense_layout() {
        let m = sample();
        let g = m.gather_cols_dense(&[2, 0]);
        // row-major (3 x 2): [[2,1],[0,0],[5,4]]
        assert_eq!(g, vec![2.0, 1.0, 0.0, 0.0, 5.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn builder_rejects_unsorted_rows() {
        let mut b = CscBuilder::new(3);
        b.push_col(&[(2, 1.0), (1, 2.0)]);
    }

    #[test]
    fn col_norm_sq_sums_squares() {
        let m = sample();
        assert_eq!(m.col_norm_sq(0), 17.0);
    }
}
