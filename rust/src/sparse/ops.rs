//! Cross-representation sparse helpers used by apps and tests.

use super::{CscMatrix, CsrMatrix};

/// Dense-vector squared l2 norm.
pub fn norm_sq(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Standardize CSC columns to unit l2 norm in place semantics (returns a new
/// matrix plus the applied scales).  The paper assumes standardized X for
/// the Lasso CD update (eq. 5).
pub fn standardize_columns(m: &CscMatrix) -> (CscMatrix, Vec<f32>) {
    let mut trips = Vec::with_capacity(m.nnz());
    let mut scales = Vec::with_capacity(m.cols());
    for j in 0..m.cols() {
        let norm = m.col_norm_sq(j).sqrt();
        let scale = if norm > 0.0 { 1.0 / norm } else { 0.0 };
        scales.push(scale);
        for (r, v) in m.col_iter(j) {
            trips.push((r, j as u32, v * scale));
        }
    }
    (CscMatrix::from_triplets(m.rows(), m.cols(), &trips), scales)
}

/// CSC → CSR conversion.
pub fn csc_to_csr(m: &CscMatrix) -> CsrMatrix {
    let mut trips = Vec::with_capacity(m.nnz());
    for j in 0..m.cols() {
        for (r, v) in m.col_iter(j) {
            trips.push((r, j as u32, v));
        }
    }
    CsrMatrix::from_triplets(m.rows(), m.cols(), &trips)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_gives_unit_columns() {
        let m = CscMatrix::from_triplets(
            4,
            2,
            &[(0, 0, 3.0), (1, 0, 4.0), (2, 1, 2.0)],
        );
        let (s, scales) = standardize_columns(&m);
        assert!((s.col_norm_sq(0) - 1.0).abs() < 1e-6);
        assert!((s.col_norm_sq(1) - 1.0).abs() < 1e-6);
        assert!((scales[0] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn standardize_handles_empty_column() {
        let m = CscMatrix::from_triplets(2, 2, &[(0, 0, 5.0)]);
        let (s, scales) = standardize_columns(&m);
        assert_eq!(scales[1], 0.0);
        assert_eq!(s.col_nnz(1), 0);
    }

    #[test]
    fn csc_csr_roundtrip_dense() {
        let m = CscMatrix::from_triplets(
            3,
            2,
            &[(0, 0, 1.0), (2, 0, 2.0), (1, 1, 3.0)],
        );
        assert_eq!(csc_to_csr(&m).to_dense(), m.to_dense());
    }

    #[test]
    fn norm_sq_accumulates_f64() {
        assert!((norm_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }
}
