//! BSP/SSP-versioned parameter block.
//!
//! The coordinator commits pull results here; workers are brought up to
//! date by sync broadcasts.  Versions let us implement BSP strictly (the
//! default, as in the paper) and the SSP execution mode: a reader declares
//! its version and the store reports the staleness gap, while
//! [`VersionVector`] tracks every worker's applied version and *enforces*
//! the bounded-staleness invariant — no worker ever reads a snapshot older
//! than `committed_version - staleness`.

/// A dense parameter vector with a monotone version counter.
#[derive(Debug, Clone)]
pub struct VersionedParams<T: Clone> {
    value: T,
    version: u64,
}

impl<T: Clone> VersionedParams<T> {
    pub fn new(initial: T) -> Self {
        VersionedParams { value: initial, version: 0 }
    }

    /// Commit a full replacement (pull output), bumping the version.
    pub fn commit(&mut self, value: T) -> u64 {
        self.value = value;
        self.version += 1;
        self.version
    }

    /// Commit via in-place mutation, bumping the version.
    pub fn commit_with<F: FnOnce(&mut T)>(&mut self, f: F) -> u64 {
        f(&mut self.value);
        self.version += 1;
        self.version
    }

    /// Current committed value (coordinator-side read).
    pub fn read(&self) -> &T {
        &self.value
    }

    /// Clone-out snapshot for a sync broadcast.
    pub fn snapshot(&self) -> (T, u64) {
        (self.value.clone(), self.version)
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Staleness of a reader holding `reader_version` — 0 under strict BSP.
    pub fn staleness(&self, reader_version: u64) -> u64 {
        self.version.saturating_sub(reader_version)
    }

    /// Pair this block with a per-worker [`VersionVector`] (SSP mode).
    pub fn version_vector(&self, n_workers: usize) -> VersionVector {
        let mut vv = VersionVector::new(n_workers);
        vv.committed = self.version;
        vv.applied = vec![self.version; n_workers];
        vv
    }
}

/// Per-worker applied-version accounting for the SSP execution mode.
///
/// The coordinator bumps `committed` at every pull commit; a worker's
/// entry records the newest version its in-flight reads are known to
/// have seen (the engine advances it when collecting a round, to that
/// round's dispatch-time version — FIFO mailboxes guarantee the worker
/// had applied exactly those syncs first).  [`VersionVector::check_bound`]
/// is the bounded-staleness invariant from the SSP literature (Ho et al.,
/// Xing et al. 2016): every read sees all commits up to `committed - s`.
///
/// The rotation pipeline reuses the same accounting with pulls as the
/// commit events: a `Rotation { depth }` run bounds every dispatched
/// round's snapshot lag by `depth - 1` (the engine panics otherwise), so
/// the s-snapshot a slice sweep reads is never more than `depth - 1`
/// pulls behind.
#[derive(Debug, Clone)]
pub struct VersionVector {
    committed: u64,
    applied: Vec<u64>,
}

impl VersionVector {
    pub fn new(n_workers: usize) -> Self {
        VersionVector { committed: 0, applied: vec![0; n_workers] }
    }

    pub fn n_workers(&self) -> usize {
        self.applied.len()
    }

    /// Record a coordinator-side commit; returns the new committed version.
    pub fn commit(&mut self) -> u64 {
        self.committed += 1;
        self.committed
    }

    pub fn committed(&self) -> u64 {
        self.committed
    }

    /// Record that `worker` has applied the sync for `version`.  Versions
    /// apply in FIFO order, so this only ever moves forward.
    pub fn apply(&mut self, worker: usize, version: u64) {
        debug_assert!(version <= self.committed, "applying unseen version");
        if version > self.applied[worker] {
            self.applied[worker] = version;
        }
    }

    pub fn applied(&self, worker: usize) -> u64 {
        self.applied[worker]
    }

    /// Current staleness of one worker's view.
    pub fn staleness(&self, worker: usize) -> u64 {
        self.committed - self.applied[worker]
    }

    /// Worst staleness across the cluster.
    pub fn max_staleness(&self) -> u64 {
        self.applied
            .iter()
            .map(|&a| self.committed - a)
            .max()
            .unwrap_or(0)
    }

    /// Oldest applied version across workers.
    pub fn min_applied(&self) -> u64 {
        self.applied.iter().copied().min().unwrap_or(self.committed)
    }

    /// Enforce the bounded-staleness invariant: every worker's applied
    /// version must be within `bound` of the committed version.
    pub fn check_bound(&self, bound: u64) -> Result<(), String> {
        for (p, &a) in self.applied.iter().enumerate() {
            let gap = self.committed - a;
            if gap > bound {
                return Err(format!(
                    "worker {p} is {gap} versions stale (bound {bound}, \
                     committed {}, applied {a})",
                    self.committed
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_bumps_version() {
        let mut p = VersionedParams::new(vec![0.0f32; 3]);
        assert_eq!(p.version(), 0);
        let v = p.commit(vec![1.0, 2.0, 3.0]);
        assert_eq!(v, 1);
        assert_eq!(p.read(), &vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn commit_with_mutates_in_place() {
        let mut p = VersionedParams::new(vec![1.0f32, 2.0]);
        p.commit_with(|v| v[0] = 9.0);
        assert_eq!(p.read(), &vec![9.0, 2.0]);
        assert_eq!(p.version(), 1);
    }

    #[test]
    fn snapshot_is_consistent() {
        let mut p = VersionedParams::new(5i64);
        p.commit(6);
        let (val, ver) = p.snapshot();
        assert_eq!((val, ver), (6, 1));
    }

    #[test]
    fn staleness_gap() {
        let mut p = VersionedParams::new(());
        for _ in 0..4 {
            p.commit(());
        }
        assert_eq!(p.staleness(4), 0);
        assert_eq!(p.staleness(1), 3);
        assert_eq!(p.staleness(9), 0); // future reader clamps to 0
    }

    #[test]
    fn version_vector_tracks_per_worker_staleness() {
        let mut vv = VersionVector::new(3);
        assert_eq!(vv.max_staleness(), 0);
        vv.commit();
        vv.commit();
        assert_eq!(vv.committed(), 2);
        vv.apply(0, 2);
        vv.apply(1, 1);
        assert_eq!(vv.staleness(0), 0);
        assert_eq!(vv.staleness(1), 1);
        assert_eq!(vv.staleness(2), 2);
        assert_eq!(vv.max_staleness(), 2);
        assert_eq!(vv.min_applied(), 0);
        assert!(vv.check_bound(2).is_ok());
        assert!(vv.check_bound(1).is_err());
    }

    #[test]
    fn version_vector_apply_is_monotone() {
        let mut vv = VersionVector::new(1);
        vv.commit();
        vv.commit();
        vv.apply(0, 2);
        vv.apply(0, 1); // stale re-apply must not move the vector back
        assert_eq!(vv.applied(0), 2);
    }

    #[test]
    fn version_vector_from_params_starts_fresh() {
        let mut p = VersionedParams::new(0u8);
        p.commit(1);
        p.commit(2);
        let vv = p.version_vector(4);
        assert_eq!(vv.committed(), 2);
        assert_eq!(vv.max_staleness(), 0);
        assert!(vv.check_bound(0).is_ok());
    }
}
