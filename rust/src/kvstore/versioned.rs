//! BSP-versioned parameter block.
//!
//! The coordinator commits pull results here; workers are brought up to
//! date by sync broadcasts.  Versions let us implement BSP strictly (the
//! default, as in the paper) and support the SSP extension: a reader
//! declares its version and the store reports the staleness gap.

/// A dense parameter vector with a monotone version counter.
#[derive(Debug, Clone)]
pub struct VersionedParams<T: Clone> {
    value: T,
    version: u64,
}

impl<T: Clone> VersionedParams<T> {
    pub fn new(initial: T) -> Self {
        VersionedParams { value: initial, version: 0 }
    }

    /// Commit a full replacement (pull output), bumping the version.
    pub fn commit(&mut self, value: T) -> u64 {
        self.value = value;
        self.version += 1;
        self.version
    }

    /// Commit via in-place mutation, bumping the version.
    pub fn commit_with<F: FnOnce(&mut T)>(&mut self, f: F) -> u64 {
        f(&mut self.value);
        self.version += 1;
        self.version
    }

    /// Current committed value (coordinator-side read).
    pub fn read(&self) -> &T {
        &self.value
    }

    /// Clone-out snapshot for a sync broadcast.
    pub fn snapshot(&self) -> (T, u64) {
        (self.value.clone(), self.version)
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Staleness of a reader holding `reader_version` — 0 under strict BSP.
    pub fn staleness(&self, reader_version: u64) -> u64 {
        self.version.saturating_sub(reader_version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_bumps_version() {
        let mut p = VersionedParams::new(vec![0.0f32; 3]);
        assert_eq!(p.version(), 0);
        let v = p.commit(vec![1.0, 2.0, 3.0]);
        assert_eq!(v, 1);
        assert_eq!(p.read(), &vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn commit_with_mutates_in_place() {
        let mut p = VersionedParams::new(vec![1.0f32, 2.0]);
        p.commit_with(|v| v[0] = 9.0);
        assert_eq!(p.read(), &vec![9.0, 2.0]);
        assert_eq!(p.version(), 1);
    }

    #[test]
    fn snapshot_is_consistent() {
        let mut p = VersionedParams::new(5i64);
        p.commit(6);
        let (val, ver) = p.snapshot();
        assert_eq!((val, ver), (6, 1));
    }

    #[test]
    fn staleness_gap() {
        let mut p = VersionedParams::new(());
        for _ in 0..4 {
            p.commit(());
        }
        assert_eq!(p.staleness(4), 0);
        assert_eq!(p.staleness(1), 3);
        assert_eq!(p.staleness(9), 0); // future reader clamps to 0
    }
}
