//! Exclusively-leased model partitions.
//!
//! STRADS LDA partitions the word-topic table **B** into U slices that
//! rotate among workers; correctness requires that at most one worker holds
//! a slice at any time (disjointness is what makes parallel Gibbs nearly
//! exact, paper §3.1).  `SliceStore` enforces that invariant at runtime:
//! `checkout` moves the slice out (panicking on double-checkout — a
//! scheduling bug), `checkin` returns it.

/// A checked-out slice; must be returned via [`SliceStore::checkin`].
#[derive(Debug)]
pub struct SliceLease<T> {
    pub slice_id: usize,
    pub data: T,
    /// Version at checkout time (incremented every checkin).
    pub version: u64,
}

/// Store of `n` exclusively-leased partitions.
#[derive(Debug)]
pub struct SliceStore<T> {
    slots: Vec<Option<T>>,
    versions: Vec<u64>,
}

impl<T> SliceStore<T> {
    /// Build from initial slice contents.
    pub fn new(slices: Vec<T>) -> Self {
        let n = slices.len();
        SliceStore { slots: slices.into_iter().map(Some).collect(), versions: vec![0; n] }
    }

    pub fn n_slices(&self) -> usize {
        self.slots.len()
    }

    /// Exclusive checkout.  Panics if the slice is already leased — that is
    /// a scheduler bug (two workers assigned the same partition).
    pub fn checkout(&mut self, slice_id: usize) -> SliceLease<T> {
        let data = self.slots[slice_id]
            .take()
            .unwrap_or_else(|| panic!("slice {slice_id} already leased"));
        SliceLease { slice_id, data, version: self.versions[slice_id] }
    }

    /// Return a leased slice, bumping its version.
    pub fn checkin(&mut self, lease: SliceLease<T>) {
        assert!(
            self.slots[lease.slice_id].is_none(),
            "slice {} returned twice",
            lease.slice_id
        );
        self.versions[lease.slice_id] = lease.version + 1;
        self.slots[lease.slice_id] = Some(lease.data);
    }

    /// Re-install a slice that was moved out via [`SliceStore::checkout`]
    /// after its version chain advanced elsewhere — the pipelined-rotation
    /// path, where sweeps bump versions through the
    /// [`crate::kvstore::SliceRouter`] rather than through `checkin`.
    /// The version may only move forward.
    pub fn restore(&mut self, slice_id: usize, data: T, version: u64) {
        assert!(
            self.slots[slice_id].is_none(),
            "slice {slice_id} already present"
        );
        assert!(
            version >= self.versions[slice_id],
            "slice {slice_id} version went backwards: {} -> {version}",
            self.versions[slice_id]
        );
        self.versions[slice_id] = version;
        self.slots[slice_id] = Some(data);
    }

    /// Is the slice currently leased out?
    pub fn is_leased(&self, slice_id: usize) -> bool {
        self.slots[slice_id].is_none()
    }

    /// Read-only access to a checked-in slice.
    pub fn peek(&self, slice_id: usize) -> Option<&T> {
        self.slots[slice_id].as_ref()
    }

    pub fn version(&self, slice_id: usize) -> u64 {
        self.versions[slice_id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_checkin_roundtrip() {
        let mut s = SliceStore::new(vec![vec![1.0f32], vec![2.0]]);
        let lease = s.checkout(0);
        assert!(s.is_leased(0));
        assert!(!s.is_leased(1));
        assert_eq!(lease.data, vec![1.0]);
        s.checkin(lease);
        assert!(!s.is_leased(0));
        assert_eq!(s.version(0), 1);
        assert_eq!(s.version(1), 0);
    }

    #[test]
    #[should_panic(expected = "already leased")]
    fn double_checkout_panics() {
        let mut s = SliceStore::new(vec![0u8, 1]);
        let _a = s.checkout(1);
        let _b = s.checkout(1);
    }

    #[test]
    fn peek_reads_without_lease() {
        let mut s = SliceStore::new(vec![7i32]);
        assert_eq!(s.peek(0), Some(&7));
        let lease = s.checkout(0);
        assert_eq!(s.peek(0), None);
        s.checkin(lease);
        assert_eq!(s.peek(0), Some(&7));
    }

    #[test]
    fn restore_reinstalls_with_advanced_version() {
        let mut s = SliceStore::new(vec![vec![1u8]]);
        let lease = s.checkout(0);
        // the rotation router swept the slice 5 times elsewhere
        s.restore(0, lease.data, lease.version + 5);
        assert!(!s.is_leased(0));
        assert_eq!(s.version(0), 5);
        assert_eq!(s.peek(0), Some(&vec![1u8]));
    }

    #[test]
    fn versions_count_checkins() {
        let mut s = SliceStore::new(vec![0u8]);
        for expect in 1..=5u64 {
            let lease = s.checkout(0);
            s.checkin(lease);
            assert_eq!(s.version(0), expect);
        }
    }
}
