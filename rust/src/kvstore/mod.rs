//! Partitioned key-value store for model variables (paper §2, "Sync").
//!
//! Model variables live in a partitioned store owned by the coordinator
//! side; workers receive values through **push** payloads and BSP **sync**
//! broadcasts.  Two pieces:
//!
//! * [`SliceStore`] — exclusively-leased model partitions (the LDA
//!   word-topic table slices that *rotate* between workers: one owner per
//!   slice per round, enforced at runtime).
//! * [`VersionedParams`] — a BSP-versioned dense parameter block (Lasso's
//!   beta, MF's H): `commit` bumps the version, `snapshot` hands out the
//!   committed value.  Its [`VersionVector`] companion tracks every
//!   worker's applied version and enforces the bounded-staleness invariant
//!   of the SSP execution mode (see `coordinator::ExecutionMode`).

pub mod slices;
pub mod versioned;

pub use slices::{SliceLease, SliceStore};
pub use versioned::{VersionVector, VersionedParams};
