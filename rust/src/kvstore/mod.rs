//! Partitioned key-value store for model variables (paper §2, "Sync").
//!
//! Model variables live in a partitioned store owned by the coordinator
//! side; workers receive values through **push** payloads and BSP **sync**
//! broadcasts.  Two pieces:
//!
//! * [`SliceStore`] — exclusively-leased model partitions (the LDA
//!   word-topic table slices that *rotate* between workers: one owner per
//!   slice per round, enforced at runtime).
//! * [`VersionedParams`] — a BSP-versioned dense parameter block (Lasso's
//!   beta, MF's H): `commit` bumps the version, `snapshot` hands out the
//!   committed value.  Its [`VersionVector`] companion tracks every
//!   worker's applied version and enforces the bounded-staleness invariant
//!   of the SSP execution mode (see `coordinator::ExecutionMode`).
//! * [`SliceRouter`] / [`LeaseLedger`] — the pipelined-rotation path:
//!   slices are served worker→worker through a versioned handoff ring and
//!   the coordinator tracks only lease tokens, so LDA's rotation pipelines
//!   without the per-round checkout/checkin barrier.

pub mod router;
pub mod slices;
pub mod versioned;

pub use router::{
    rotation_availability, LeaseLedger, LeaseToken, NetLinkStats, RouterError,
    SliceChecksum, SliceMass, SliceRouter, StaleLease,
};
pub use slices::{SliceLease, SliceStore};
pub use versioned::{VersionVector, VersionedParams};
