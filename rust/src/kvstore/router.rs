//! Async worker→worker slice serving for pipelined rotation.
//!
//! The BSP rotation path funnels every slice through the coordinator each
//! round: `schedule` checks it out of [`crate::kvstore::SliceStore`],
//! `pull` checks it back in — a global barrier per round.  The paper's
//! rotation schedule (§3.1, Fig 4) only requires *disjointness per round*,
//! so the checkout/checkin cycle can be replaced by a ring of direct
//! handoffs: a worker finishing slice `a` forwards it straight to the ring
//! successor, and the coordinator only tracks lease *tokens*.
//!
//! Three pieces:
//!
//! * [`SliceRouter`] — the worker-side data plane: a slot-per-slice
//!   [`crate::cluster::ForwardQueue`] plus a per-slice **version chain**.
//!   `take(a, v)` blocks until the predecessor has forwarded exactly
//!   version `v` (bounded by `STRADS_ROUTER_SPIN_MS`, then panics with the
//!   lost lease's context); `try_take(a, v)` is the non-blocking poll —
//!   paired with per-slice **arrival stamps**, it lets a multi-slice
//!   worker sweep whichever of its queued slices landed first
//!   (availability-ordered rotation) instead of stalling on ring order.
//!   `forward(a, data, v+1)` hands the swept slice on.  The
//!   chain head only ever advances by one, so forwarding a second child of
//!   the same parent version panics — the exclusive-lease invariant of
//!   [`crate::kvstore::SliceStore`] preserved without a barrier.  Slots
//!   are keyed by **slice**, not worker, so the ring carries U ≥ P slices
//!   over P workers unchanged (multi-slice rotation: a worker takes and
//!   forwards each slice of its queue independently).
//! * [`LeaseToken`] — `(slice, version)`, the coordinator-visible name of
//!   one lease in the chain.
//! * [`LeaseLedger`] — the coordinator-side control plane: `grant` hands
//!   out strictly sequential versions at schedule time, `settle` retires
//!   them strictly in order at pull time.  Every version `v+1` therefore
//!   has exactly one parent `v`; any skip, replay, or fork panics.

use crate::cluster::{router_spin_ms, ForwardQueue, NetFaultPlan};
use crate::trace::{Event, TraceBuffer};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// One lease in a slice's version chain: the worker holding this token may
/// consume exactly version `version` of slice `slice_id` (and forwards
/// `version + 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseToken {
    pub slice_id: usize,
    pub version: u64,
}

/// A data-plane take whose deadline expired: the awaited handoff never
/// landed.  Carries everything a recovery (or a clean abort) needs — the
/// wedged slice, the version awaited, the chain head actually reached, and
/// (once the engine fills it from its in-flight lease table) the worker
/// suspected of holding the missing forward.  Returned instead of
/// panicking so a wedged take aborts the *run*, not the process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterError {
    /// The slice whose handoff never arrived.
    pub slice_id: usize,
    /// The version the take awaited.
    pub version: u64,
    /// The slice's chain head when the deadline expired (`version - 1`
    /// means the predecessor never forwarded; anything older means the
    /// wedge is further upstream).
    pub chain_head: u64,
    /// The worker holding the lease that should have produced the awaited
    /// version — `None` at the router layer (the data plane does not know
    /// the schedule); the engine fills it from its in-flight lease table.
    pub suspected_holder: Option<usize>,
    /// How long the take waited before giving up.
    pub waited_ms: u64,
}

impl fmt::Display for RouterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "slice {} handoff lost: awaited v{} never arrived within {}ms \
             (chain head is v{}",
            self.slice_id, self.version, self.waited_ms, self.chain_head
        )?;
        match self.suspected_holder {
            Some(w) => write!(f, "; suspected holder: worker {w})"),
            None => write!(f, "; holder unknown — tune STRADS_ROUTER_SPIN_MS)"),
        }
    }
}

impl std::error::Error for RouterError {}

/// Sweep-cost mass of a routed payload — what the dynamic queue order
/// ([`crate::scheduler::rotation::QueueOrder::Dynamic`]) scores parked
/// slices by: per-leg compute is proportional to a slice's token mass, so
/// the heaviest parked slice is the one whose sweep gates the most
/// downstream work, and releasing its handoff first buys the most
/// overlap.  Implementations return a non-negative, NaN-free score on the
/// same relative scale across one router's slices.
pub trait SliceMass {
    fn mass(&self) -> f64;
}

/// Element count — the stand-in mass the protocol test payloads use.
impl SliceMass for Vec<u32> {
    fn mass(&self) -> f64 {
        self.len() as f64
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content checksum of a routed payload — stamped into the transport
/// envelope at forward time and verified at delivery time, so a corrupt
/// retransmit buffer (or a payload type whose `Clone` is not value-exact)
/// fails loudly instead of silently diverging the model.  Order-sensitive
/// FNV-1a over the payload's canonical byte stream; two payloads that
/// compare equal must checksum equal.
pub trait SliceChecksum {
    fn checksum64(&self) -> u64;
}

impl SliceChecksum for u8 {
    fn checksum64(&self) -> u64 {
        fnv_bytes(FNV_OFFSET, &[*self])
    }
}

impl SliceChecksum for Vec<u32> {
    fn checksum64(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for v in self {
            h = fnv_bytes(h, &v.to_le_bytes());
        }
        h
    }
}

impl SliceChecksum for Vec<f32> {
    fn checksum64(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for v in self {
            h = fnv_bytes(h, &v.to_bits().to_le_bytes());
        }
        h
    }
}

/// Cumulative counters of one [`SliceRouter`]'s lossy-transport link
/// (all zero when no link is installed).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetLinkStats {
    /// Delivery attempts re-sent after an earlier attempt was dropped.
    pub retransmits: u64,
    /// Duplicate deliveries discarded idempotently by the receive side.
    pub dup_discards: u64,
    /// Delivery attempts the fault plan dropped.
    pub drops: u64,
    /// Retained payloads force-delivered by a recovery flush.
    pub redelivers: u64,
    /// Wall seconds deliveries spent parked in retransmit backoff.
    pub retry_wait_secs: f64,
}

/// One in-flight transport envelope: the retransmit buffer entry a sender
/// keeps from [`SliceRouter::forward`] until the receiver's take acks it.
#[derive(Debug)]
struct LinkEntry<T> {
    payload: T,
    version: u64,
    checksum: u64,
    /// Delivery attempts made so far (1-based once the first fires).
    attempts: u64,
    /// The payload reached the receive mailbox (awaiting the take-ack).
    delivered: bool,
    /// Earliest instant the next delivery attempt may fire (exponential
    /// backoff after a drop; the epoch for attempt 1).
    next_retry: Instant,
    /// Armed by a delay fault: the attempt is in flight and lands here.
    deliver_at: Option<Instant>,
    /// Armed by a duplication fault at forward time: a second copy is in
    /// flight, delivered if the primary drops (masking) and discarded
    /// idempotently otherwise.
    dup_pending: bool,
    /// When the most recent drop happened (meters the backoff latency the
    /// protocol paid once the payload finally lands).
    last_drop_at: Instant,
}

/// The lossy-transport layer under a [`SliceRouter`]'s forwards: a seeded
/// [`NetFaultPlan`] decides per delivery attempt whether to drop, delay,
/// or duplicate, and the ack/retry/backoff protocol around the retained
/// payload masks whatever the plan injects.  Installed at most once per
/// router ([`SliceRouter::install_link`]); with no link installed every
/// forward deposits directly, byte-identical to the pre-link code path.
///
/// There is no pump thread: receivers drive redelivery from their own
/// wait loops (`take_for` / the reordered-take sweeps pump between short
/// condvar parks), so the protocol works identically under both
/// execution backends.
#[derive(Debug)]
pub struct LossyLink<T> {
    plan: NetFaultPlan,
    /// Per-slice retransmit buffer (at most one outstanding envelope per
    /// slice: forwarding `v+1` requires taking `v`, which acks it).
    entries: Vec<Mutex<Option<LinkEntry<T>>>>,
    /// Highest version delivered to the mailbox per slice (seeded from
    /// the chain heads at install time, so coordinator seeds count as
    /// delivered) — the idempotent-receive dedup line.
    delivered_head: Vec<AtomicU64>,
    retransmits: AtomicU64,
    dup_discards: AtomicU64,
    drops: AtomicU64,
    redelivers: AtomicU64,
    retry_wait_nanos: AtomicU64,
    /// Trace sink for `NetDrop`/`Retransmit`/`DupDiscard`/`Redeliver`
    /// events (all excluded from fingerprints — the post-masking stream
    /// is what replay sees).
    sink: Option<Arc<TraceBuffer>>,
}

impl<T> LossyLink<T> {
    fn trace(&self, e: Event) {
        if let Some(sink) = &self.sink {
            sink.push(e);
        }
    }
}

/// How often a link-driven wait re-pumps the transport between condvar
/// parks — short against the smallest backoff step (~1 ms) so a due
/// retransmit never waits long for a driver.
const PUMP_INTERVAL: Duration = Duration::from_micros(500);

/// Worker-side slice handoff ring: versioned slices move peer→peer through
/// a blocking per-slice mailbox, never through the coordinator.
///
/// Shared by `Arc` between the coordinator (seeding, eval-time peeks,
/// teardown) and every worker's in-flight push closures.
#[derive(Debug)]
pub struct SliceRouter<T> {
    queue: ForwardQueue<T>,
    /// Highest version ever deposited per slice — the forward-only guard
    /// that detects a forked chain.
    heads: Mutex<Vec<u64>>,
    /// Per-slice arrival stamp of the most recent deposit: a global
    /// deposit sequence number, so an availability-ordered consumer can
    /// sweep its queued slices earliest-landed-first
    /// ([`crate::scheduler::rotation::QueueOrder`]).
    arrivals: Mutex<Vec<u64>>,
    arrival_counter: AtomicU64,
    /// Lossy-transport layer, installed at most once
    /// ([`SliceRouter::install_link`]); `None` keeps every forward on the
    /// direct-deposit path.
    link: OnceLock<LossyLink<T>>,
}

impl<T: Send> SliceRouter<T> {
    pub fn new(n_slices: usize) -> Self {
        SliceRouter {
            queue: ForwardQueue::new(n_slices),
            heads: Mutex::new(vec![0; n_slices]),
            arrivals: Mutex::new(vec![0; n_slices]),
            arrival_counter: AtomicU64::new(0),
            link: OnceLock::new(),
        }
    }

    /// Stamp `slice_id` with the next global deposit sequence number
    /// (called just before the deposit, so a consumer that sees the parked
    /// slice also sees its stamp).
    fn stamp_arrival(&self, slice_id: usize) {
        let seq = self.arrival_counter.fetch_add(1, Ordering::Relaxed) + 1;
        self.arrivals.lock().expect("router arrivals poisoned")[slice_id] = seq;
    }

    pub fn n_slices(&self) -> usize {
        self.queue.n_slots()
    }

    /// Install a slice's initial contents at `version` (coordinator-side,
    /// entering rotation mode).  Panics if the slot already holds data.
    pub fn seed(&self, slice_id: usize, data: T, version: u64) {
        self.heads.lock().expect("router heads poisoned")[slice_id] = version;
        self.stamp_arrival(slice_id);
        self.queue.deposit(slice_id, data, version);
    }

    /// Version currently parked in the slice's slot (`None` while the
    /// handoff is in flight) — the poll an availability-ordered consumer
    /// uses to rank its queue before committing to a take.  Deliberately
    /// does **not** pump the transport link: a delivery still held by a
    /// delay fault is genuinely unavailable, which is exactly the signal
    /// `SkipPolicy::Defer` keys off.
    pub fn parked_version(&self, slice_id: usize) -> Option<u64> {
        self.queue.parked_version(slice_id)
    }

    /// Arrival stamp (global deposit sequence number) of the slice's most
    /// recent deposit.  Consumers compare stamps across *parked* slices to
    /// sweep earliest-landed-first; a stamp read while the slice is in
    /// flight refers to the previous deposit and means nothing.
    ///
    /// Trace contract: a holder reading the stamp of the handoff it just
    /// consumed must do so **before** its own [`SliceRouter::forward`],
    /// which re-stamps the slot.  The read cannot race — the holder is
    /// the slot's sole depositor until it forwards.  The stamp lands in
    /// [`crate::trace::Event::Take`] as metadata only and is excluded
    /// from fingerprints (it counts *global* deposits, so it is
    /// timing-dependent across workers).
    pub fn arrival_seq(&self, slice_id: usize) -> u64 {
        self.arrivals.lock().expect("router arrivals poisoned")[slice_id]
    }

    /// Non-blocking peek of a parked slice's [`SliceMass`] score (`None`
    /// while the handoff is in flight) — how a dynamic-ordered consumer
    /// ranks its queue without taking anything.  Stable between the peek
    /// and a take by the granted worker: depositing over an occupied slot
    /// panics, so parked data cannot change under the poller.
    pub fn peek_parked_mass(&self, slice_id: usize) -> Option<f64>
    where
        T: SliceMass,
    {
        self.queue
            .with_slot(slice_id, |slot| slot.map(|(data, _)| data.mass()))
    }

    /// Current chain head (highest version deposited).
    pub fn version(&self, slice_id: usize) -> u64 {
        self.heads.lock().expect("router heads poisoned")[slice_id]
    }

    /// Cumulative seconds consumers spent *physically blocked* on this
    /// router's data plane (parked on slot condvars in
    /// [`SliceRouter::take_for`], or on the deposit epoch in the
    /// reordered-take sweeps).  ~0 under the single-threaded sim driver,
    /// which only ever takes parked slices; under `--backend threads` it
    /// is the measured handoff contention surfaced as
    /// `SspStats::router_block_secs`.
    pub fn block_secs(&self) -> f64 {
        self.queue.blocked_secs()
    }
}

/// The consumer/producer surface: every method that moves payloads (and
/// therefore may traverse the lossy link) requires `Clone` (the
/// retransmit buffer retains the payload until the take-ack) and
/// [`SliceChecksum`] (the envelope stamp verified at delivery).  With no
/// link installed, every path below is byte-identical to the pre-link
/// code.
impl<T: Send + Clone + SliceChecksum> SliceRouter<T> {
    /// Install the lossy-transport layer under this router's forwards (at
    /// most once, before any faulted forward fires).  The idempotence
    /// line `delivered_head` starts at the current chain heads, so
    /// coordinator seeds count as already delivered.  `sink` receives the
    /// transport trace events (`NetDrop`/`Retransmit`/`DupDiscard`/
    /// `Redeliver`), all excluded from fingerprints.
    pub fn install_link(&self, plan: NetFaultPlan, sink: Option<Arc<TraceBuffer>>) {
        plan.validate().expect("invalid net fault plan");
        let heads = self.heads.lock().expect("router heads poisoned");
        let link = LossyLink {
            plan,
            entries: (0..self.n_slices()).map(|_| Mutex::new(None)).collect(),
            delivered_head: heads.iter().map(|&h| AtomicU64::new(h)).collect(),
            retransmits: AtomicU64::new(0),
            dup_discards: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            redelivers: AtomicU64::new(0),
            retry_wait_nanos: AtomicU64::new(0),
            sink,
        };
        drop(heads);
        assert!(self.link.set(link).is_ok(), "lossy link already installed");
    }

    /// Whether a lossy-transport link is installed.
    pub fn has_link(&self) -> bool {
        self.link.get().is_some()
    }

    /// Snapshot of the link's cumulative counters (zeros with no link).
    pub fn net_stats(&self) -> NetLinkStats {
        match self.link.get() {
            None => NetLinkStats::default(),
            Some(l) => NetLinkStats {
                retransmits: l.retransmits.load(Ordering::Relaxed),
                dup_discards: l.dup_discards.load(Ordering::Relaxed),
                drops: l.drops.load(Ordering::Relaxed),
                redelivers: l.redelivers.load(Ordering::Relaxed),
                retry_wait_secs: l.retry_wait_nanos.load(Ordering::Relaxed)
                    as f64
                    * 1e-9,
            },
        }
    }

    /// Worker-side receive: block until exactly `version` of the slice has
    /// been forwarded, then take ownership.  Returns the slice together
    /// with the version the predecessor actually deposited — the holder's
    /// independent evidence of which lease it consumed (the coordinator
    /// cross-checks it against the granted token at collect time).  An
    /// *older* parked version is pipeline lag (its own consumer is still
    /// on its way) and the wait continues; a *newer* one panics (the
    /// awaited handoff can no longer arrive — a fork, i.e. a protocol
    /// bug, not a liveness fault).  The wait parks on the slot's condvar
    /// (no busy-spin); when the handoff never lands within the bounded
    /// [`crate::cluster::router_spin_ms`] deadline it returns a typed
    /// [`RouterError`] with slice/version/chain-head context — a lost
    /// handoff is a *liveness* fault (e.g. a dead holder) the engine maps
    /// to a recovery attempt or a clean run abort, never a process-killing
    /// panic.
    pub fn take(
        &self,
        slice_id: usize,
        version: u64,
    ) -> Result<(T, u64), RouterError> {
        self.take_for(slice_id, version, Duration::from_millis(router_spin_ms()))
    }

    /// [`SliceRouter::take`] with an explicit deadline (tests drive the
    /// lost-handoff error without waiting out the process-wide default).
    pub fn take_for(
        &self,
        slice_id: usize,
        version: u64,
        timeout: Duration,
    ) -> Result<(T, u64), RouterError> {
        let lost = || RouterError {
            slice_id,
            version,
            chain_head: self.version(slice_id),
            suspected_holder: None,
            waited_ms: timeout.as_millis() as u64,
        };
        if self.link.get().is_none() {
            return match self.queue.take_for(slice_id, version, timeout) {
                Some(got) => Ok(got),
                None => Err(lost()),
            };
        }
        // link installed: the take loop doubles as the transport pump —
        // short mailbox parks interleaved with redelivery attempts (there
        // is no pump thread; receivers drive their own redelivery)
        let deadline = Instant::now() + timeout;
        loop {
            self.pump_slice(slice_id);
            let now = Instant::now();
            let chunk = PUMP_INTERVAL.min(deadline.saturating_duration_since(now));
            if let Some((data, consumed)) =
                self.queue.take_for(slice_id, version, chunk)
            {
                self.ack(slice_id, consumed);
                return Ok((data, consumed));
            }
            if Instant::now() >= deadline {
                return Err(lost());
            }
        }
    }

    /// Non-blocking poll of the slice's handoff: `Some((data, version))`
    /// when exactly `version` is parked, `None` while it is in flight (or
    /// an older deposit still awaits its own consumer).  A *newer* parked
    /// version panics, exactly as [`SliceRouter::take`] would.  This is
    /// the availability-ordered consumer's primitive: sweep whichever
    /// queued slice landed first instead of stalling on a fixed ring
    /// order.
    pub fn try_take(&self, slice_id: usize, version: u64) -> Option<(T, u64)> {
        self.pump_slice(slice_id);
        let got = self.queue.try_take(slice_id, version);
        if let Some((_, consumed)) = &got {
            self.ack(slice_id, *consumed);
        }
        got
    }

    /// Availability-ordered take: block until **any** of the granted
    /// `(slice, version)` handoffs is parked, then take the one with the
    /// earliest arrival stamp (ties cannot occur — stamps are unique).
    /// Returns the index into `grants` of the picked entry together with
    /// the slice and the consumed version.  This is the one shared
    /// implementation of the earliest-landed-first discipline both
    /// availability-ordered apps sweep with
    /// ([`crate::scheduler::rotation::QueueOrder::Availability`]).
    ///
    /// Only the granted worker polls these `(slice, version)` pairs, so a
    /// slice seen parked cannot be taken by anyone else between the poll
    /// and the take.  After `timeout` it returns a typed [`RouterError`]
    /// naming the first still-pending grant — a stalled sweep is a
    /// lost-handoff liveness fault the engine maps to recovery or a clean
    /// run abort.
    pub fn take_earliest(
        &self,
        grants: &[(usize, u64)],
        timeout: Duration,
    ) -> Result<(usize, T, u64), RouterError> {
        self.spin_take(grants, timeout, "availability", |router, grants| {
            let mut best: Option<(usize, u64)> = None;
            for (i, &(slice_id, version)) in grants.iter().enumerate() {
                if router.parked_version(slice_id) == Some(version) {
                    let arr = router.arrival_seq(slice_id);
                    if best.is_none_or(|(_, b)| arr < b) {
                        best = Some((i, arr));
                    }
                }
            }
            best.map(|(i, _)| i)
        })
    }

    /// The shared scan/park/expire skeleton under both reordered-take
    /// disciplines: scan until `pick_best` names a parked grant to take,
    /// or return a typed [`RouterError`] (naming the first still-pending
    /// grant) when nothing lands within `timeout`.  `pick_best` sees the
    /// router and the grant list and returns the index of its chosen
    /// *parked* entry, or `None` while everything is in flight.
    ///
    /// Between scans the caller **parks** on the queue's deposit epoch
    /// ([`crate::cluster::ForwardQueue::wait_any_until`]) rather than
    /// busy-polling: the epoch is read *before* each scan, so a deposit
    /// landing between the scan and the park bumps the epoch past the
    /// snapshot and the park returns immediately — no missed wakeup.
    fn spin_take(
        &self,
        grants: &[(usize, u64)],
        timeout: Duration,
        discipline: &str,
        mut pick_best: impl FnMut(&Self, &[(usize, u64)]) -> Option<usize>,
    ) -> Result<(usize, T, u64), RouterError> {
        assert!(
            !grants.is_empty(),
            "{discipline} take needs at least one grant"
        );
        let deadline = std::time::Instant::now() + timeout;
        loop {
            // drive any pending transport deliveries for the granted
            // slices before scanning (no-op without a link)
            for &(slice_id, _) in grants {
                self.pump_slice(slice_id);
            }
            // epoch snapshot BEFORE the scan: any deposit after this point
            // makes the park below return at once
            let seen = self.queue.epoch();
            if let Some(i) = pick_best(self, grants) {
                let (slice_id, version) = grants[i];
                let (data, consumed) = self
                    .try_take(slice_id, version)
                    .expect("slice was parked when picked");
                return Ok((i, data, consumed));
            }
            if std::time::Instant::now() >= deadline {
                // every grant is still pending; report the first one (the
                // queue-order head — under a ring schedule that is the
                // most upstream wedge, hence the best recovery target)
                let &(slice_id, version) = grants
                    .iter()
                    .find(|&&(a, v)| self.parked_version(a) != Some(v))
                    .unwrap_or(&grants[0]);
                return Err(RouterError {
                    slice_id,
                    version,
                    chain_head: self.version(slice_id),
                    suspected_holder: None,
                    waited_ms: timeout.as_millis() as u64,
                });
            }
            // with a link, cap each park at the pump interval so a due
            // retransmit or delayed delivery never waits for a deposit
            let park = if self.link.get().is_some() {
                deadline.min(std::time::Instant::now() + PUMP_INTERVAL)
            } else {
                deadline
            };
            self.queue.wait_any_until(seen, park);
        }
    }

    /// Dynamic-ordered take: block until **any** of the granted
    /// `(slice, version)` handoffs is parked, then take the one with the
    /// largest [`SliceMass`] score (ties broken toward the earlier
    /// arrival stamp, then the lower grant index — the same tie-break the
    /// engine's virtual-time replay uses).  Returns the index into
    /// `grants` of the picked entry together with the slice and the
    /// consumed version.  This is the one shared implementation of the
    /// heaviest-parked-first discipline
    /// ([`crate::scheduler::rotation::QueueOrder::Dynamic`]); see
    /// [`SliceRouter::take_earliest`] for the earliest-landed-first
    /// sibling and the race-freedom argument (only the granted worker
    /// polls these pairs).  Returns a typed [`RouterError`] after
    /// `timeout`.
    pub fn take_heaviest(
        &self,
        grants: &[(usize, u64)],
        timeout: Duration,
    ) -> Result<(usize, T, u64), RouterError>
    where
        T: SliceMass,
    {
        // a parked grant's payload is immutable until this (the granted)
        // worker takes it, so its mass is measured once per grant and
        // reused across the poll iterations — BSlice masses are O(words ×
        // K) sums, far too hot for a 50 µs spin loop
        let mut mass_memo: Vec<Option<f64>> = vec![None; grants.len()];
        self.spin_take(grants, timeout, "dynamic", move |router, grants| {
            // (mass, reverse arrival, reverse index) lexicographic max
            let mut best: Option<(usize, f64, u64)> = None;
            for (i, &(slice_id, version)) in grants.iter().enumerate() {
                if router.parked_version(slice_id) == Some(version) {
                    let mass = *mass_memo[i].get_or_insert_with(|| {
                        router
                            .peek_parked_mass(slice_id)
                            .expect("slice was parked when polled")
                    });
                    let arr = router.arrival_seq(slice_id);
                    let better = match best {
                        None => true,
                        Some((_, bm, ba)) => {
                            mass > bm || (mass == bm && arr < ba)
                        }
                    };
                    if better {
                        best = Some((i, mass, arr));
                    }
                }
            }
            best.map(|(i, ..)| i)
        })
    }

    /// Worker-side handoff to the ring successor: deposit the swept slice
    /// as `version`.  Panics unless `version` extends the chain head by
    /// exactly one — forwarding a second child of the same parent is a
    /// **version fork** (two workers held the slice at once).
    pub fn forward(&self, slice_id: usize, data: T, version: u64) {
        {
            let mut heads = self.heads.lock().expect("router heads poisoned");
            assert!(
                version == heads[slice_id] + 1,
                "slice {} version fork: forwarding v{} but the chain head is v{}",
                slice_id,
                version,
                heads[slice_id]
            );
            heads[slice_id] = version;
        }
        let Some(link) = self.link.get() else {
            self.stamp_arrival(slice_id);
            self.queue.deposit(slice_id, data, version);
            return;
        };
        // envelope path: checksum + version stamp into the retransmit
        // buffer, then drive the first delivery attempt immediately — a
        // fault-free decision delivers synchronously, so an armed but
        // all-zero plan behaves exactly like the direct path
        let checksum = data.checksum64();
        let now = Instant::now();
        {
            let mut entry =
                link.entries[slice_id].lock().expect("lossy link poisoned");
            assert!(
                entry.is_none(),
                "slice {slice_id} already has an un-acked envelope in flight"
            );
            *entry = Some(LinkEntry {
                payload: data,
                version,
                checksum,
                attempts: 0,
                delivered: false,
                next_retry: now,
                deliver_at: None,
                dup_pending: link.plan.duplicates(slice_id, version),
                last_drop_at: now,
            });
        }
        self.pump_slice(slice_id);
    }

    /// Non-blocking removal of whatever the slot holds (pipeline
    /// teardown).  Flushes the slice's pending transport delivery first —
    /// the final forward of a run has no taker to pump it home.  Panics
    /// if the slice is still in flight.
    pub fn reclaim(&self, slice_id: usize) -> (T, u64) {
        self.flush_slice(slice_id);
        self.queue
            .reclaim(slice_id)
            .unwrap_or_else(|| panic!("slice {slice_id} still in flight at teardown"))
    }

    /// Inspect a parked slice without consuming it (eval-time reads; the
    /// engine drains the pipeline first, so `None` means a protocol bug).
    /// Flushes the slice's pending transport delivery first, so an eval
    /// read sees the chain head regardless of injected faults.
    pub fn with_slice<R>(&self, slice_id: usize, f: impl FnOnce(Option<&T>) -> R) -> R {
        self.flush_slice(slice_id);
        self.queue.with_slot(slice_id, |slot| f(slot.map(|(data, _)| data)))
    }

    /// Drive one slice's transport state machine: fire a due delivery
    /// attempt (applying the fault plan's drop/delay decisions), land a
    /// due delayed delivery, and resolve a pending duplicate.  No-op
    /// without a link or with no envelope in flight.
    fn pump_slice(&self, slice_id: usize) {
        let Some(link) = self.link.get() else { return };
        let mut guard =
            link.entries[slice_id].lock().expect("lossy link poisoned");
        let Some(entry) = guard.as_mut() else { return };
        let now = Instant::now();
        if let Some(at) = entry.deliver_at {
            // a delayed attempt in flight: it lands once its hold expires
            if !entry.delivered && now >= at {
                entry.deliver_at = None;
                self.deliver_copy(link, slice_id, entry, false);
            }
        } else if !entry.delivered && now >= entry.next_retry {
            entry.attempts += 1;
            if entry.attempts > 1 {
                link.retransmits.fetch_add(1, Ordering::Relaxed);
                link.retry_wait_nanos.fetch_add(
                    now.duration_since(entry.last_drop_at).as_nanos() as u64,
                    Ordering::Relaxed,
                );
                link.trace(Event::Retransmit {
                    slice: slice_id,
                    version: entry.version,
                    attempt: entry.attempts,
                });
            }
            if link.plan.drops(slice_id, entry.version, entry.attempts) {
                link.drops.fetch_add(1, Ordering::Relaxed);
                link.trace(Event::NetDrop {
                    slice: slice_id,
                    version: entry.version,
                    attempt: entry.attempts,
                });
                entry.last_drop_at = now;
                entry.next_retry = now
                    + link.plan.backoff(slice_id, entry.version, entry.attempts);
                if entry.dup_pending {
                    // the duplicated copy is an independent transmission:
                    // it masks the dropped primary by landing anyway
                    entry.dup_pending = false;
                    self.deliver_copy(link, slice_id, entry, false);
                }
            } else if link.plan.delayed(slice_id, entry.version, entry.attempts) {
                entry.deliver_at =
                    Some(now + link.plan.delay_hold(slice_id, entry.version));
            } else {
                self.deliver_copy(link, slice_id, entry, false);
            }
        }
        if entry.dup_pending && entry.delivered {
            // duplicate of an already-delivered version: idempotent discard
            entry.dup_pending = false;
            link.dup_discards.fetch_add(1, Ordering::Relaxed);
            link.trace(Event::DupDiscard {
                slice: slice_id,
                version: entry.version,
            });
        }
    }

    /// Clone the retained payload into the receive mailbox — the actual
    /// "wire delivery".  Verifies the envelope checksum, dedups against
    /// the delivered head (idempotent receive), and stamps the arrival.
    fn deliver_copy(
        &self,
        link: &LossyLink<T>,
        slice_id: usize,
        entry: &mut LinkEntry<T>,
        redelivery: bool,
    ) {
        let head = link.delivered_head[slice_id].load(Ordering::Relaxed);
        if entry.version <= head {
            link.dup_discards.fetch_add(1, Ordering::Relaxed);
            link.trace(Event::DupDiscard {
                slice: slice_id,
                version: entry.version,
            });
            entry.delivered = true;
            return;
        }
        let payload = entry.payload.clone();
        assert!(
            payload.checksum64() == entry.checksum,
            "slice {slice_id} v{} failed its transport checksum",
            entry.version
        );
        link.delivered_head[slice_id].store(entry.version, Ordering::Relaxed);
        if redelivery {
            link.redelivers.fetch_add(1, Ordering::Relaxed);
            link.trace(Event::Redeliver {
                slice: slice_id,
                version: entry.version,
            });
        }
        self.stamp_arrival(slice_id);
        self.queue.deposit(slice_id, payload, entry.version);
        entry.delivered = true;
    }

    /// Take-side acknowledgement: the consumer physically received
    /// `version`, so the sender's retained envelope is released.  A
    /// still-pending duplicate of the acked version is discarded here,
    /// keeping the dup counter deterministic (every injected dup is
    /// either delivered once, masking a drop, or discarded once).
    fn ack(&self, slice_id: usize, version: u64) {
        let Some(link) = self.link.get() else { return };
        let mut guard =
            link.entries[slice_id].lock().expect("lossy link poisoned");
        if let Some(entry) = guard.as_ref() {
            if entry.version == version {
                if entry.dup_pending {
                    link.dup_discards.fetch_add(1, Ordering::Relaxed);
                    link.trace(Event::DupDiscard { slice: slice_id, version });
                }
                *guard = None;
            }
        }
    }

    /// Force-deliver one slice's pending envelope, bypassing the fault
    /// plan's remaining decisions (recovery, teardown, and eval reads
    /// must see the chain head regardless of injected faults).  Traced as
    /// [`Event::Redeliver`] when a payload actually lands.
    fn flush_slice(&self, slice_id: usize) {
        let Some(link) = self.link.get() else { return };
        let mut guard =
            link.entries[slice_id].lock().expect("lossy link poisoned");
        if let Some(entry) = guard.as_mut() {
            if !entry.delivered {
                entry.deliver_at = None;
                self.deliver_copy(link, slice_id, entry, true);
            }
            if entry.dup_pending {
                entry.dup_pending = false;
                link.dup_discards.fetch_add(1, Ordering::Relaxed);
                link.trace(Event::DupDiscard {
                    slice: slice_id,
                    version: entry.version,
                });
            }
        }
    }

    /// [`Self::flush_slice`] over every slice — the recovery boundary's
    /// "make the data plane quiescent" step.  Idempotent; no-op without a
    /// link.
    pub fn flush_all(&self) {
        for a in 0..self.n_slices() {
            self.flush_slice(a);
        }
    }
}

// The threaded backend shares one router by `Arc` between the coordinator
// and every worker thread, and ships `LeaseToken`s across worker mailboxes
// — all three must stay `Send + Sync`.  Checked at compile time so a
// future `Rc`/`Cell` regression fails the build, not a stress run.
const _: () = {
    const fn assert_send_sync<S: Send + Sync>() {}
    assert_send_sync::<SliceRouter<Vec<u32>>>();
    assert_send_sync::<LeaseLedger>();
    assert_send_sync::<LeaseToken>();
};

/// The per-slice availability signal a skip-capable rotation schedule
/// feeds [`crate::scheduler::RotationScheduler::next_round_grants`]:
/// slice `a` is *available* when the version its next lease will consume
/// ([`LeaseLedger::next_version`]) is already parked in the router —
/// still in flight otherwise.  Without a router (BSP checkouts) every
/// slice is in hand, so nothing ever skips.  One shared implementation
/// for every rotation app, so the protocol cannot drift between them.
///
/// Note the signal reads the **live** data plane: under a pipelined run
/// it depends on how far the in-flight rounds' workers have physically
/// progressed, so `SkipPolicy::Defer` decisions are timing-dependent
/// (the rotation invariants hold under every interleaving — that is what
/// `tests/rotation_properties.rs` sweeps); only `SkipPolicy::Never` runs
/// are deterministic-replay exact.
pub fn rotation_availability<T: Send>(
    router: Option<&SliceRouter<T>>,
    ledger: &LeaseLedger,
) -> Vec<bool> {
    let u = ledger.n_slices();
    match router {
        Some(router) => (0..u)
            .map(|a| router.parked_version(a) == Some(ledger.next_version(a)))
            .collect(),
        None => vec![true; u],
    }
}

/// A settle rejected by the ledger's crash fence: the token belongs to a
/// lease that was re-granted after a recovery, so its holder is a zombie
/// (a worker presumed dead writing back stale work).  Returned — not
/// panicked — so the coordinator can drop the write and keep running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaleLease {
    pub slice_id: usize,
    /// The zombie token's version.
    pub version: u64,
    /// The settled head the last recovery armed the fence at.
    pub fence: u64,
}

impl fmt::Display for StaleLease {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "stale lease: slice {} v{} was re-granted after a crash \
             (fence at v{}); zombie write rejected",
            self.slice_id, self.version, self.fence
        )
    }
}

impl std::error::Error for StaleLease {}

/// Coordinator-side lease accounting for the rotation pipeline: a
/// per-slice version chain advanced by `grant` (schedule time) and
/// `settle` (pull time), panicking on any fork.  After a crash recovery
/// ([`LeaseLedger::recover`]) a per-slice **fence** additionally rejects
/// settles of pre-recovery tokens ([`StaleLease`]) — zombie-worker write
/// fencing.
#[derive(Debug, Clone)]
pub struct LeaseLedger {
    /// Next version to grant per slice.
    granted: Vec<u64>,
    /// Next version to settle per slice (≤ granted; the gap is in flight).
    settled: Vec<u64>,
    /// Armed by recovery with the settled head the chain resumed from
    /// (`None` = never recovered, nothing fenced).  Recovery re-grants the
    /// *same* versions the dead holder held, so a zombie token is
    /// indistinguishable from the re-grant by version alone *until* the
    /// re-granted lease settles — after which the zombie's settle targets
    /// an already-settled version, which on a fenced slice is rejected as
    /// stale rather than treated as a chain fork.
    fences: Vec<Option<u64>>,
}

impl LeaseLedger {
    pub fn new(n_slices: usize) -> Self {
        LeaseLedger {
            granted: vec![0; n_slices],
            settled: vec![0; n_slices],
            fences: vec![None; n_slices],
        }
    }

    pub fn n_slices(&self) -> usize {
        self.granted.len()
    }

    /// Re-base one slice's chain (entering rotation mode with a store
    /// whose versions already advanced).  Panics if leases are in flight.
    pub fn seed(&mut self, slice_id: usize, version: u64) {
        assert!(
            self.granted[slice_id] == self.settled[slice_id],
            "slice {slice_id} has in-flight leases"
        );
        self.granted[slice_id] = version;
        self.settled[slice_id] = version;
    }

    /// Grant the next lease of the slice's chain; returns the version the
    /// holder must consume.  Strictly sequential: a scheduler bug that
    /// grants the same round twice shows up as settle-time forks.
    pub fn grant(&mut self, slice_id: usize) -> u64 {
        let v = self.granted[slice_id];
        self.granted[slice_id] += 1;
        v
    }

    /// The version the *next* grant of this slice will hand out — what a
    /// skip-capable scheduler compares against
    /// [`SliceRouter::parked_version`] to decide whether the slice's
    /// handoff has landed ("available") or is still in flight
    /// ([`crate::scheduler::rotation::SkipPolicy::Defer`]).
    pub fn next_version(&self, slice_id: usize) -> u64 {
        self.granted[slice_id]
    }

    /// Retire a consumed lease.  Two distinct failure modes:
    ///
    /// * on a slice *fenced by a crash recovery*, a settle of an
    ///   already-settled version is a zombie write — the dead holder's
    ///   lease was re-granted and the re-grant settled first — and
    ///   returns a [`StaleLease`] error; the write is dropped, the run
    ///   continues.  (A zombie that races *ahead* of the re-grant is
    ///   indistinguishable by version and is accepted; in this codebase
    ///   that race cannot occur, because a killed worker's reply channel
    ///   is dropped before recovery runs.)
    /// * anything else out of sequence **panics**: settling a version that
    ///   is not exactly the oldest outstanding one means the chain forked
    ///   (version `v+1` with zero or two parents `v`) — a protocol bug,
    ///   not a membership fault.
    pub fn settle(&mut self, token: &LeaseToken) -> Result<(), StaleLease> {
        if let Some(fence) = self.fences[token.slice_id] {
            if token.version < self.settled[token.slice_id] {
                return Err(StaleLease {
                    slice_id: token.slice_id,
                    version: token.version,
                    fence,
                });
            }
        }
        assert!(
            token.version < self.granted[token.slice_id],
            "lease fork: slice {} settling ungranted v{}",
            token.slice_id,
            token.version
        );
        assert!(
            token.version == self.settled[token.slice_id],
            "lease fork: slice {} settling v{} but the chain expects v{}",
            token.slice_id,
            token.version,
            self.settled[token.slice_id]
        );
        self.settled[token.slice_id] += 1;
        Ok(())
    }

    /// Crash recovery for one slice: roll the grant head back to the last
    /// *settled* version (orphaned in-flight grants are forgotten — the
    /// next [`LeaseLedger::grant`] re-grants from the last settled
    /// version) and arm the fence so any zombie settle of a pre-recovery
    /// token is rejected with [`StaleLease`].  Returns the settled head
    /// the chain resumes from.
    pub fn recover(&mut self, slice_id: usize) -> u64 {
        let head = self.settled[slice_id];
        self.granted[slice_id] = head;
        self.fences[slice_id] = Some(head);
        head
    }

    /// [`LeaseLedger::recover`] over every slice; returns how many slices
    /// had orphaned (granted-but-unsettled) leases rolled back.
    pub fn recover_all(&mut self) -> usize {
        let orphaned = (0..self.n_slices())
            .filter(|&a| self.outstanding(a) > 0)
            .count();
        for a in 0..self.n_slices() {
            self.recover(a);
        }
        orphaned
    }

    /// The settled head the last recovery of this slice armed its fence
    /// at (0 if never recovered).
    pub fn fence(&self, slice_id: usize) -> u64 {
        self.fences[slice_id].unwrap_or(0)
    }

    /// Leases granted but not yet settled for one slice.
    pub fn outstanding(&self, slice_id: usize) -> u64 {
        self.granted[slice_id] - self.settled[slice_id]
    }

    /// Worst outstanding depth across slices (the pipeline depth actually
    /// reached).
    pub fn max_outstanding(&self) -> u64 {
        (0..self.n_slices()).map(|a| self.outstanding(a)).max().unwrap_or(0)
    }

    /// Fully settled chain head for one slice.
    pub fn settled_head(&self, slice_id: usize) -> u64 {
        self.settled[slice_id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_handoff_roundtrip() {
        let r = SliceRouter::new(2);
        r.seed(0, vec![1.0f32], 3);
        r.seed(1, vec![2.0f32], 0);
        assert_eq!(r.version(0), 3);
        let (d, consumed) = r.take(0, 3).expect("seeded handoff is parked");
        assert_eq!(d, vec![1.0]);
        assert_eq!(consumed, 3);
        r.forward(0, d, consumed + 1);
        assert_eq!(r.version(0), 4);
        r.with_slice(0, |s| assert_eq!(s, Some(&vec![1.0f32])));
        let (d, v) = r.reclaim(0);
        assert_eq!((d, v), (vec![1.0f32], 4));
        r.with_slice(0, |s| assert!(s.is_none()));
    }

    #[test]
    fn try_take_polls_and_arrival_stamps_order_deposits() {
        let r = SliceRouter::new(3);
        r.seed(2, 7u8, 0);
        r.seed(0, 8u8, 0);
        // slice 1 never seeded: in flight from the consumer's view
        assert!(r.try_take(1, 0).is_none());
        assert_eq!(r.parked_version(1), None);
        // slice 2 was deposited before slice 0
        assert_eq!(r.parked_version(2), Some(0));
        assert!(r.arrival_seq(2) < r.arrival_seq(0));
        let (d, v) = r.try_take(2, 0).expect("parked");
        assert_eq!((d, v), (7u8, 0));
        // forwarding re-stamps: slice 2 is now the latest arrival
        r.forward(2, d, 1);
        assert!(r.arrival_seq(2) > r.arrival_seq(0));
        assert_eq!(r.parked_version(2), Some(1));
    }

    #[test]
    fn take_earliest_picks_the_first_landed_grant() {
        let r = SliceRouter::new(3);
        r.seed(1, 11u8, 0); // lands first
        r.seed(2, 22u8, 0);
        // grants listed in ring order: slice 2 first, then 1; the earlier
        // arrival (slice 1) must win regardless
        let grants = [(2usize, 0u64), (1, 0)];
        let (idx, data, consumed) = r
            .take_earliest(&grants, Duration::from_millis(100))
            .expect("a grant is parked");
        assert_eq!((idx, data, consumed), (1, 11u8, 0));
        // slice 2 is the only parked grant left
        let (idx, data, _) = r
            .take_earliest(&grants[..1], Duration::from_millis(100))
            .expect("a grant is parked");
        assert_eq!((idx, data), (0, 22u8));
    }

    #[test]
    fn peek_parked_mass_scores_without_consuming() {
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(2);
        r.seed(0, vec![1, 2, 3], 0);
        // slice 1 in flight: no score
        assert_eq!(r.peek_parked_mass(1), None);
        assert_eq!(r.peek_parked_mass(0), Some(3.0));
        // peeking does not consume
        let (d, v) = r.try_take(0, 0).expect("still parked");
        assert_eq!((d, v), (vec![1, 2, 3], 0));
        assert_eq!(r.peek_parked_mass(0), None);
    }

    #[test]
    fn take_heaviest_picks_the_largest_parked_mass() {
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(3);
        r.seed(0, vec![7], 0); // mass 1, earliest arrival
        r.seed(1, vec![1, 2, 3], 0); // mass 3
        // slice 2 never seeded: in flight, must be ignored
        let grants = [(0usize, 0u64), (1, 0), (2, 0)];
        let (idx, data, consumed) = r
            .take_heaviest(&grants[..2], Duration::from_millis(100))
            .expect("a grant is parked");
        assert_eq!((idx, data, consumed), (1, vec![1, 2, 3], 0));
        // only the light slice remains parked
        let (idx, data, _) = r
            .take_heaviest(&grants[..1], Duration::from_millis(100))
            .expect("a grant is parked");
        assert_eq!((idx, data), (0, vec![7]));
    }

    #[test]
    fn take_heaviest_breaks_mass_ties_by_earliest_arrival() {
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(2);
        r.seed(1, vec![5, 6], 0); // lands first
        r.seed(0, vec![7, 8], 0); // equal mass, lands second
        let grants = [(0usize, 0u64), (1, 0)];
        let (idx, data, _) = r
            .take_heaviest(&grants, Duration::from_millis(100))
            .expect("a grant is parked");
        assert_eq!((idx, data), (1, vec![5, 6]));
    }

    #[test]
    fn parked_sweep_wakes_on_a_cross_thread_deposit() {
        use std::sync::Arc;
        // a reordered-take sweep parked on the deposit epoch must wake
        // when another thread forwards the awaited slice — and the park
        // time must show up in the router's block counter
        let r: Arc<SliceRouter<Vec<u32>>> = Arc::new(SliceRouter::new(2));
        assert_eq!(r.block_secs(), 0.0);
        let producer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                r.seed(1, vec![4, 5, 6], 0);
            })
        };
        let (idx, data, consumed) = r
            .take_earliest(&[(0, 0), (1, 0)], Duration::from_secs(5))
            .expect("producer deposits within the deadline");
        producer.join().expect("producer thread panicked");
        assert_eq!((idx, data, consumed), (1, vec![4, 5, 6], 0));
        assert!(
            r.block_secs() > 0.0,
            "parked wait must be metered: got {}",
            r.block_secs()
        );
    }

    #[test]
    fn take_heaviest_errors_typed_after_timeout() {
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(2);
        let err = r
            .take_heaviest(&[(0, 0), (1, 0)], Duration::from_millis(10))
            .expect_err("nothing ever parked");
        assert_eq!(err.slice_id, 0);
        assert_eq!(err.version, 0);
        assert_eq!(err.suspected_holder, None);
    }

    #[test]
    fn take_earliest_errors_typed_after_timeout() {
        let r: SliceRouter<u8> = SliceRouter::new(2);
        // nothing ever seeded: both grants stay pending
        let err = r
            .take_earliest(&[(0, 0), (1, 0)], Duration::from_millis(10))
            .expect_err("nothing ever parked");
        assert_eq!((err.slice_id, err.version), (0, 0));
    }

    #[test]
    fn take_errors_with_context_after_bounded_spin() {
        // consume the whole chain, then await a version nobody ever
        // forwards: the bounded wait must return a typed RouterError with
        // the lost lease's context (slice, version, chain head) rather
        // than hang or kill the process.  The explicit-timeout form
        // drives it; `take` uses the env-tunable STRADS_ROUTER_SPIN_MS
        // default, which tests must not mutate.
        let r: SliceRouter<u8> = SliceRouter::new(1);
        r.seed(0, 1, 0);
        let (d, v) = r.take(0, 0).expect("seeded");
        r.forward(0, d, v + 1);
        let _held = r.take(0, 1).expect("forwarded");
        let err = r
            .take_for(0, 2, Duration::from_millis(10))
            .expect_err("v2 is never forwarded");
        assert_eq!(err.slice_id, 0);
        assert_eq!(err.version, 2);
        assert_eq!(err.chain_head, 1, "chain head names the wedge point");
        assert_eq!(err.waited_ms, 10);
        let msg = err.to_string();
        assert!(msg.contains("handoff lost"), "{msg}");
        assert!(msg.contains("chain head is v1"), "{msg}");
        // the engine fills the holder once it consults its lease table
        let filled = RouterError { suspected_holder: Some(3), ..err };
        assert!(filled.to_string().contains("worker 3"), "{filled}");
    }

    #[test]
    #[should_panic(expected = "version fork")]
    fn second_child_of_same_parent_panics() {
        let r = SliceRouter::new(1);
        r.seed(0, 7u8, 0);
        let (d, _) = r.take(0, 0).unwrap();
        r.forward(0, d, 1);
        let (d, _) = r.take(0, 1).unwrap();
        // chain head is already v1: a second v1 (two children of v0 in
        // spirit) must panic rather than silently rewind
        r.forward(0, d, 1);
    }

    #[test]
    #[should_panic(expected = "still in flight")]
    fn reclaiming_an_in_flight_slice_panics() {
        let r = SliceRouter::new(1);
        r.seed(0, 7u8, 0);
        let _held = r.take(0, 0).unwrap();
        let _ = r.reclaim(0);
    }

    #[test]
    fn ledger_grants_and_settles_in_order() {
        let mut l = LeaseLedger::new(2);
        l.seed(1, 5);
        assert_eq!(l.grant(0), 0);
        assert_eq!(l.grant(0), 1);
        assert_eq!(l.grant(1), 5);
        assert_eq!(l.outstanding(0), 2);
        assert_eq!(l.max_outstanding(), 2);
        l.settle(&LeaseToken { slice_id: 0, version: 0 }).unwrap();
        l.settle(&LeaseToken { slice_id: 0, version: 1 }).unwrap();
        l.settle(&LeaseToken { slice_id: 1, version: 5 }).unwrap();
        assert_eq!(l.max_outstanding(), 0);
        assert_eq!(l.settled_head(0), 2);
        assert_eq!(l.settled_head(1), 6);
    }

    #[test]
    #[should_panic(expected = "lease fork")]
    fn settling_out_of_order_panics() {
        let mut l = LeaseLedger::new(1);
        let _v0 = l.grant(0);
        let _v1 = l.grant(0);
        let _ = l.settle(&LeaseToken { slice_id: 0, version: 1 }); // skips v0
    }

    #[test]
    #[should_panic(expected = "lease fork")]
    fn settling_an_ungranted_lease_panics() {
        let mut l = LeaseLedger::new(1);
        let _ = l.settle(&LeaseToken { slice_id: 0, version: 0 });
    }

    #[test]
    fn zombie_writes_are_fenced_after_recovery() {
        // Satellite 2: a lease granted before a crash must not settle
        // after the slice's chain was recovered and re-granted — the
        // zombie worker's write is fenced, the survivor's is accepted.
        let mut l = LeaseLedger::new(2);
        let zombie = LeaseToken { slice_id: 0, version: l.grant(0) };
        // worker dies holding the v0 lease; the coordinator rolls the
        // chain back to the settled head and arms the fence there
        assert_eq!(l.recover(0), 0);
        assert_eq!(l.fence(0), 0);
        assert_eq!(l.outstanding(0), 0, "recovery reclaims the grant");
        // fence at v0 means v0 itself was re-granted: the survivor's
        // fresh lease (same version, post-fence grant) must settle...
        let survivor = LeaseToken { slice_id: 0, version: l.grant(0) };
        assert_eq!(survivor.version, zombie.version);
        l.settle(&survivor).expect("re-granted lease settles");
        // ...after which the chain has moved past the fence, and the
        // zombie's stale settle is rejected with a typed error
        let err = l.settle(&zombie).expect_err("zombie write is fenced");
        assert_eq!(err.slice_id, 0);
        assert_eq!(err.version, 0);
        assert_eq!(err.fence, 0);
        let msg = err.to_string();
        assert!(msg.contains("stale lease"), "{msg}");
        assert!(msg.contains("zombie write rejected"), "{msg}");
        // untouched slices keep a zero fence
        assert_eq!(l.fence(1), 0);
    }

    #[test]
    fn checksums_are_content_stable_and_content_sensitive() {
        assert_eq!(vec![1u32, 2, 3].checksum64(), vec![1u32, 2, 3].checksum64());
        assert_ne!(vec![1u32, 2, 3].checksum64(), vec![1u32, 3, 2].checksum64());
        assert_ne!(vec![1u32, 2].checksum64(), vec![1u32, 2, 0].checksum64());
        assert_eq!(vec![1.5f32].checksum64(), vec![1.5f32].checksum64());
        assert_ne!(vec![1.5f32].checksum64(), vec![-1.5f32].checksum64());
        assert_ne!(3u8.checksum64(), 4u8.checksum64());
    }

    #[test]
    fn zero_rate_link_is_pass_through() {
        // an armed but all-zero plan must behave exactly like no link:
        // synchronous delivery at forward time, zero counters
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(2);
        r.seed(0, vec![1, 2], 0);
        r.install_link(NetFaultPlan::default(), None);
        assert!(r.has_link());
        let (d, v) = r.take(0, 0).expect("seeded");
        r.forward(0, d, v + 1);
        assert_eq!(r.parked_version(0), Some(1), "delivered synchronously");
        let (d, v) = r.take(0, 1).expect("forwarded through the link");
        assert_eq!((d, v), (vec![1, 2], 1));
        assert_eq!(r.net_stats(), NetLinkStats::default());
    }

    #[test]
    #[should_panic(expected = "already installed")]
    fn installing_a_second_link_panics() {
        let r: SliceRouter<u8> = SliceRouter::new(1);
        r.install_link(NetFaultPlan::default(), None);
        r.install_link(NetFaultPlan::default(), None);
    }

    #[test]
    fn dropped_forwards_retransmit_until_delivered() {
        // drop 60% of attempts: the ack/retry protocol must still land
        // every forward, metering the drops and retransmits it masked
        let plan = NetFaultPlan {
            drop_rate: 0.6,
            seed: 11,
            ..NetFaultPlan::default()
        };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(1);
        r.seed(0, vec![7], 0);
        r.install_link(plan, None);
        let mut payload = vec![7];
        for v in 0..8u64 {
            let (d, consumed) = r
                .take_for(0, v, Duration::from_secs(20))
                .expect("redelivery must mask every drop");
            assert_eq!(d, payload);
            assert_eq!(consumed, v);
            payload.push(v as u32);
            r.forward(0, payload.clone(), v + 1);
        }
        let stats = r.net_stats();
        assert!(stats.drops > 0, "60% drop rate over 8 forwards: {stats:?}");
        assert_eq!(
            stats.retransmits, stats.drops,
            "every drop costs exactly one retransmit: {stats:?}"
        );
        assert!(stats.retry_wait_secs > 0.0, "backoff waits are metered");
        assert_eq!(stats.redelivers, 0, "no recovery flush ran");
    }

    #[test]
    fn wedged_link_errors_typed_and_flush_redelivers() {
        // drop_rate 1.0 is a deterministic wedge: the take times out with
        // the usual typed error, and a recovery flush force-delivers the
        // retained payload so the run can continue
        let plan =
            NetFaultPlan { drop_rate: 1.0, seed: 3, ..NetFaultPlan::default() };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(1);
        r.seed(0, vec![5], 0);
        r.install_link(plan, None);
        let (d, _) = r.take(0, 0).expect("seeds bypass the link");
        r.forward(0, d, 1);
        let err = r
            .take_for(0, 1, Duration::from_millis(60))
            .expect_err("every delivery attempt drops");
        assert_eq!((err.slice_id, err.version), (0, 1));
        assert_eq!(err.chain_head, 1, "forwarded but never delivered");
        assert!(r.net_stats().drops >= 1);
        r.flush_all();
        assert_eq!(r.parked_version(0), Some(1), "flush force-delivered");
        assert_eq!(r.net_stats().redelivers, 1);
        let (d, v) = r.take(0, 1).expect("redelivered payload is takeable");
        assert_eq!((d, v), (vec![5], 1));
        // the take acked the envelope: the next forward finds it clear
        r.forward(0, d, 2);
    }

    #[test]
    fn duplicates_are_discarded_idempotently() {
        // dup 100%, no drops: every forward spawns a duplicate copy that
        // must be discarded exactly once, never deposited twice
        let plan =
            NetFaultPlan { dup_rate: 1.0, seed: 9, ..NetFaultPlan::default() };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(1);
        r.seed(0, vec![1], 0);
        r.install_link(plan, None);
        let (mut d, _) = r.take(0, 0).expect("seeded");
        for v in 1..=4u64 {
            r.forward(0, d, v);
            let got = r.take_for(0, v, Duration::from_secs(5)).expect("delivered");
            d = got.0;
        }
        let stats = r.net_stats();
        assert_eq!(stats.dup_discards, 4, "one discard per duplicated forward");
        assert_eq!(stats.drops, 0);
        assert_eq!(stats.retransmits, 0);
    }

    #[test]
    fn a_duplicate_masks_a_dropped_primary() {
        // drop 100% + dup 100%: the primary always drops, but the
        // duplicated copy is an independent transmission and lands — no
        // retransmit, no flush, the take succeeds immediately
        let plan = NetFaultPlan {
            drop_rate: 1.0,
            dup_rate: 1.0,
            seed: 5,
            ..NetFaultPlan::default()
        };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(1);
        r.seed(0, vec![2], 0);
        r.install_link(plan, None);
        let (d, _) = r.take(0, 0).expect("seeded");
        r.forward(0, d, 1);
        let (d, v) = r
            .take_for(0, 1, Duration::from_secs(5))
            .expect("the duplicate masks the dropped primary");
        assert_eq!((d, v), (vec![2], 1));
        let stats = r.net_stats();
        assert_eq!(stats.drops, 1, "the primary dropped");
        assert_eq!(stats.dup_discards, 0, "the duplicate was consumed, not discarded");
        assert_eq!(stats.redelivers, 0, "no flush was needed");
    }

    #[test]
    fn delayed_delivery_holds_then_lands() {
        // delay 100%: the forward is withheld (parked_version stays None —
        // exactly the unavailability signal SkipPolicy::Defer keys off)
        // until the hold expires, then a pumped take receives it
        let plan = NetFaultPlan {
            delay_rate: 1.0,
            seed: 13,
            ..NetFaultPlan::default()
        };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(1);
        r.seed(0, vec![9], 0);
        r.install_link(plan, None);
        let (d, _) = r.take(0, 0).expect("seeded");
        r.forward(0, d, 1);
        assert_eq!(
            r.parked_version(0),
            None,
            "a delayed delivery is genuinely unavailable"
        );
        let (d, v) = r
            .take_for(0, 1, Duration::from_secs(5))
            .expect("the hold expires within a few ms");
        assert_eq!((d, v), (vec![9], 1));
    }

    #[test]
    fn reordered_takes_pump_the_link_home() {
        // the availability-ordered sweep must drive redelivery itself:
        // drop the first attempts of both grants and let take_earliest's
        // pump retransmit them until they land
        let plan = NetFaultPlan {
            drop_rate: 0.5,
            delay_rate: 0.3,
            seed: 21,
            ..NetFaultPlan::default()
        };
        let r: SliceRouter<Vec<u32>> = SliceRouter::new(2);
        r.seed(0, vec![1], 0);
        r.seed(1, vec![2, 2], 0);
        r.install_link(plan, None);
        let (d0, _) = r.take(0, 0).expect("seeded");
        let (d1, _) = r.take(1, 0).expect("seeded");
        r.forward(0, d0, 1);
        r.forward(1, d1, 1);
        let grants = [(0usize, 1u64), (1, 1)];
        let (i, _, _) = r
            .take_earliest(&grants, Duration::from_secs(20))
            .expect("sweep pumps deliveries home");
        let rest = [grants[1 - i]];
        let (_, _, v) = r
            .take_heaviest(&rest, Duration::from_secs(20))
            .expect("second grant lands too");
        assert_eq!(v, 1);
    }

    #[test]
    fn double_settle_after_recovery_is_fenced_and_head_unchanged() {
        // Satellite: a duplicated (redelivered ack) or zombie settle
        // arriving after recover_all must hit the StaleLease fence and
        // leave the chain head exactly where it was — idempotently.
        let mut l = LeaseLedger::new(2);
        let t0 = LeaseToken { slice_id: 0, version: l.grant(0) };
        l.settle(&t0).unwrap();
        // v1 is in flight when the fault hits
        let t1 = LeaseToken { slice_id: 0, version: l.grant(0) };
        assert_eq!(l.recover_all(), 1, "one slice had an orphaned lease");
        // the survivor's re-granted lease settles normally
        let r1 = LeaseToken { slice_id: 0, version: l.grant(0) };
        assert_eq!(r1.version, t1.version);
        l.settle(&r1).expect("re-granted lease settles");
        let head = l.settled_head(0);
        // the zombie's duplicate settle of the same version is fenced...
        let err = l.settle(&t1).expect_err("duplicate settle is fenced");
        assert_eq!(err.slice_id, 0);
        assert_eq!(err.version, t1.version);
        assert_eq!(l.settled_head(0), head, "fenced settle moved the head");
        // ...and idempotently so: replaying the duplicate changes nothing
        let err2 = l.settle(&t1).expect_err("still fenced");
        assert_eq!(err, err2);
        assert_eq!(l.settled_head(0), head);
    }

    #[test]
    fn recover_all_counts_only_orphaned_slices() {
        let mut l = LeaseLedger::new(3);
        let t0 = l.grant(0);
        let _t1 = l.grant(1); // left outstanding: orphaned
        let _t2 = l.grant(1); // same slice, deeper pipeline
        l.settle(&LeaseToken { slice_id: 0, version: t0 }).unwrap();
        // slice 0 fully settled, slice 1 has two in flight, slice 2 idle
        assert_eq!(l.recover_all(), 1);
        assert_eq!(l.outstanding(1), 0);
        assert_eq!(l.fence(1), 0, "fence armed at the settled head");
        // post-recovery the ledger re-grants from the settled head
        assert_eq!(l.grant(1), 0);
    }
}
