//! The paper's three case-study applications, expressed through the STRADS
//! primitives (Table 1):
//!
//! | App   | schedule                    | push / pull                |
//! |-------|-----------------------------|----------------------------|
//! | LDA   | word-rotation               | collapsed Gibbs sampling   |
//! | MF    | round-robin over rank rows  | coordinate descent (CCD)   |
//! | MF (blocked) | item-block rotation (U ≥ P ring) | SGD block sweeps |
//! | Lasso | dynamic priority + dep. filter | coordinate descent      |

pub mod lasso;
pub mod lda;
pub mod mf;

pub use lasso::{LassoApp, LassoConfig};
pub use lda::{LdaApp, LdaConfig};
pub use mf::{MfApp, MfBlockApp, MfBlockConfig, MfConfig};
