//! STRADS LDA (paper §3.1, pseudocode Fig 4).
//!
//! schedule: the rotation scheduler assigns each worker a *queue* of word
//!           slices per round (one when U = P, ⌈U/P⌉ when the vocabulary
//!           is over-decomposed into U > P slices); under BSP each leg's
//!           word-topic block B_a is checked out of the kvstore and
//!           shipped with the task (its bytes dominate the round's
//!           traffic, exactly as in the paper's star topology).
//! push:     the worker Gibbs-sweeps its tokens slice by slice in queue
//!           order, mutating each B_a and a *local* copy s̃ of the topic
//!           sums that threads through the whole queue.
//! pull:     B slices are checked back in; the true s is rebuilt from the
//!           per-worker deltas; the s-error Δ (eq. 1) is measured here.
//! sync:     the fresh s ships with the next round's tasks (the paper syncs
//!           s at the end of every pull).
//!
//! Under `ExecutionMode::Rotation { depth }` the checkout/checkin cycle is
//! replaced by the async p2p path: slices live in a shared
//! [`SliceRouter`], each leg takes its versioned lease from the slice's
//! previous holder and forwards the swept slice directly to the next one,
//! and `pull` only settles lease tokens against a [`LeaseLedger`] —
//! rotation pipelines like SSP while slice disjointness stays
//! runtime-enforced.  With U > P the queue is what hides the handoff gap:
//! a worker sweeps one parked slice while another is still in flight (the
//! engine's per-slice virtual-time model scores exactly that overlap).

use crate::backend::{LdaShard, SamplerKind};
use crate::cluster::{router_spin_ms, NetFaultPlan};
use crate::coordinator::{
    EffectiveConfig, HandoffLeg, RotationCaps, RunConfig, StradsApp,
};
use crate::kvstore::{
    LeaseLedger, LeaseToken, NetLinkStats, RouterError, SliceChecksum,
    SliceMass, SliceRouter, SliceStore,
};
use crate::metrics::s_error;
use crate::scheduler::rotation::{
    self, GrantLeg, QueueOrder, RotationScheduler, SkipPolicy,
};
use crate::trace::{TraceBuffer, TracePlumbing, TraceReplayer};
use crate::util::wire::{Unwire, Wire};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Coordinator-side configuration.
pub struct LdaConfig {
    pub n_topics: usize,
    pub vocab: usize,
    pub n_workers: usize,
    pub alpha: f32,
    pub gamma: f32,
}

/// One word-topic slice: dense (slice_words × K) counts.
#[derive(Clone, Debug)]
pub struct BSlice {
    pub counts: Vec<f32>,
    pub n_words: usize,
}

/// Token mass — the count total *is* the number of corpus tokens assigned
/// to this slice's words, which is exactly what a sweep's compute scales
/// with ([`QueueOrder::Dynamic`]'s score).
impl SliceMass for BSlice {
    fn mass(&self) -> f64 {
        self.counts.iter().map(|&c| c as f64).sum()
    }
}

/// Content checksum for the lossy-transport envelope: the redelivery
/// protocol verifies a delivered slice bit-matches what the sender
/// forwarded (shape and count bits both participate).
impl SliceChecksum for BSlice {
    fn checksum64(&self) -> u64 {
        (self.n_words as u64) ^ self.counts.checksum64().rotate_left(17)
    }
}

/// One leg of a worker's round: a single slice assignment from its queue.
pub struct LdaTaskLeg {
    pub slice_id: usize,
    /// BSP path: the checked-out slice ships with the task.
    pub b_slice: Option<BSlice>,
    /// Rotation-pipelined path: the version this lease consumes (the
    /// worker takes it from the router and forwards `version + 1`).
    pub version: Option<u64>,
    /// Worker that holds this slice next round (handoff destination).
    pub dest_worker: usize,
}

/// Task for one worker: its slice queue (sweep order) plus the freshly
/// synced topic sums, and — in rotation mode — the shared handoff router.
pub struct LdaTask {
    pub legs: Vec<LdaTaskLeg>,
    pub s: Vec<f32>,
    /// Rotation-pipelined path: take/forward each leg's slice through the
    /// router instead of shipping payloads.
    pub router: Option<Arc<SliceRouter<BSlice>>>,
    /// The negotiated sampling kernel — stamped into every task so shards
    /// hear it before each sweep under both backends (workers are built
    /// before negotiation, so the choice cannot ride the constructor).
    pub sampler: SamplerKind,
    /// Within-queue service discipline: `Strict` blocks on each leg in
    /// queue order; `Availability` polls the router and sweeps whichever
    /// granted slice landed first (routed legs only — BSP legs carry
    /// their slice and have nothing to wait on).
    pub order: QueueOrder,
    /// The negotiated sampling kernel for this round's sweeps.
    pub sampler: SamplerKind,
}

/// One leg of a worker partial: mirrors [`LdaTaskLeg`] after the sweep.
pub struct LdaPartialLeg {
    pub slice_id: usize,
    /// BSP path: the mutated slice returns through the coordinator.
    pub b_slice: Option<BSlice>,
    /// Rotation path: the lease this sweep consumed (fork detection).
    pub lease: Option<LeaseToken>,
    /// Rotation path: slice bytes forwarded to the next holder.
    pub handoff_bytes: usize,
    /// Worker the slice was forwarded to.
    pub dest_worker: usize,
    /// Tokens sampled in this leg (the engine's per-leg compute weight).
    pub n_sampled: usize,
    /// Rotation path: the router arrival stamp of the handoff this leg
    /// consumed, read *before* the forward re-stamps the slot (0 under
    /// BSP).  Trace metadata only — excluded from fingerprints.
    pub arrival_seq: u64,
}

/// Worker partial: the per-leg results in sweep order, the worker's final
/// local s̃ (for the s-error metric; threaded through all legs), and the
/// number of distinct B rows touched (KV-store traffic accounting).
pub struct LdaPartial {
    pub legs: Vec<LdaPartialLeg>,
    pub s_local: Vec<f32>,
    pub touched_words: usize,
    pub n_topics: usize,
    /// Rotation path: a take deadline expired mid-sweep.  The sweep stops
    /// at the wedged leg (already-swept legs were forwarded and are
    /// reported above) and the engine aborts the run cleanly instead of
    /// panicking on a worker thread ([`StradsApp::partial_error`]).
    pub error: Option<RouterError>,
}

/// Coordinator state.
pub struct LdaApp {
    slices: SliceStore<BSlice>,
    /// Rotation-pipelined mode: the worker→worker handoff ring (None under
    /// BSP, where slices move through `slices` instead).
    router: Option<Arc<SliceRouter<BSlice>>>,
    /// Per-slice lease version chains (grant at schedule, settle at pull;
    /// panics on fork).
    ledger: LeaseLedger,
    /// s snapshots keyed by dispatch round: pipelined pulls must baseline
    /// worker deltas against the snapshot that round actually shipped, not
    /// the latest one.
    inflight_s: HashMap<u64, Vec<f32>>,
    /// Per-slice global word ids (slice-local row → corpus word id);
    /// empty when the striped `w = local·U + a` layout is in use.
    word_map: Vec<Vec<u32>>,
    /// True topic column sums s (K).
    pub s: Vec<f32>,
    sched: RotationScheduler,
    n_topics: usize,
    vocab: usize,
    n_workers: usize,
    /// Rotation slice count U (≥ `n_workers`).
    n_slices: usize,
    alpha: f32,
    gamma: f32,
    n_tokens: usize,
    /// Δ_t from the most recent pull (paper eq. 1, Fig 5).
    pub last_s_error: f64,
    pub s_error_history: Vec<f64>,
    /// SSP-style extension (paper §5 future work): refresh the s snapshot
    /// shipped to workers only every `s_staleness` pulls.  1 = strict BSP
    /// (the paper's setting); larger values trade s-error for fewer syncs.
    s_staleness: u64,
    s_snapshot: Vec<f32>,
    pulls: u64,
    /// Replay source: when set, `schedule` re-drives each worker's queue
    /// in the recorded sweep order and services it strictly (see
    /// [`TraceReplayer::reorder_legs`]).
    replay: Option<Arc<TraceReplayer>>,
    /// The negotiated sampling kernel, stamped into every task.
    sampler: SamplerKind,
    /// Sampler recorded in a restored checkpoint: `negotiate` asserts the
    /// resumed run asks for the same kernel (resuming an mh chain under
    /// exact would silently sample a different chain).
    restored_sampler: Option<SamplerKind>,
}

impl LdaApp {
    /// `slices` are the initial word-topic blocks — one per rotation slice,
    /// U ≥ `cfg.n_workers` of them (the word→slice map is the builder's
    /// concern — [`setup::build_sliced`] uses the frequency-aware split and
    /// installs it via [`LdaApp::set_word_map`], the striped `w % U` layout
    /// needs none); `s` their column sums; `n_tokens` the corpus token
    /// count (for Δ_t normalization).
    pub fn new(
        cfg: LdaConfig,
        slices: Vec<BSlice>,
        s: Vec<f32>,
        n_tokens: usize,
    ) -> Self {
        let n_slices = slices.len();
        assert!(
            n_slices >= cfg.n_workers,
            "need at least one slice per worker ({n_slices} < {})",
            cfg.n_workers
        );
        assert_eq!(s.len(), cfg.n_topics);
        LdaApp {
            sched: RotationScheduler::with_workers(n_slices, cfg.n_workers),
            slices: SliceStore::new(slices),
            router: None,
            ledger: LeaseLedger::new(n_slices),
            inflight_s: HashMap::new(),
            word_map: Vec::new(),
            s_snapshot: s.clone(),
            s,
            n_topics: cfg.n_topics,
            vocab: cfg.vocab,
            n_workers: cfg.n_workers,
            n_slices,
            alpha: cfg.alpha,
            gamma: cfg.gamma,
            n_tokens,
            last_s_error: 0.0,
            s_error_history: Vec::new(),
            s_staleness: 1,
            pulls: 0,
            replay: None,
            sampler: SamplerKind::Exact,
            restored_sampler: None,
        }
    }

    /// Enable the SSP-style sync relaxation: the s snapshot is refreshed
    /// only every `staleness` pulls (1 = strict BSP, the paper's mode).
    pub fn set_s_staleness(&mut self, staleness: u64) {
        assert!(staleness >= 1);
        self.s_staleness = staleness;
    }

    /// Install a skew-aware ring placement (see
    /// [`crate::scheduler::rotation::skew_aware_placement`]): a
    /// permutation of the slice ids deciding which slice starts at which
    /// virtual ring position.  Must be called before the first round.
    pub fn set_ring_placement(&mut self, placement: Vec<usize>) {
        self.sched.set_placement(placement);
    }

    /// One slice's contribution to the word-topic log-likelihood.
    fn slice_loglik(&self, slice: &BSlice) -> f64 {
        let k = self.n_topics;
        let vg = self.vocab as f64 * self.gamma as f64;
        let mut ll = 0.0f64;
        for w in 0..slice.n_words {
            for kk in 0..k {
                let c = slice.counts[w * k + kk] as f64;
                if c > 0.0 {
                    let phi =
                        (c + self.gamma as f64) / (self.s[kk] as f64 + vg);
                    ll += c * phi.ln();
                }
            }
        }
        ll
    }

    /// Word-topic log-likelihood term computed from the parked slices
    /// (checked in under BSP; drained into the router under rotation).
    fn word_loglik(&self) -> f64 {
        let mut ll = 0.0f64;
        for a in 0..self.slices.n_slices() {
            ll += match &self.router {
                Some(router) => router.with_slice(a, |slice| {
                    self.slice_loglik(
                        slice.expect("slice parked in the router at eval time"),
                    )
                }),
                None => self.slice_loglik(
                    self.slices
                        .peek(a)
                        .expect("all slices checked in at eval time"),
                ),
            };
        }
        ll
    }

    pub fn n_topics(&self) -> usize {
        self.n_topics
    }

    /// Read-only access to a checked-in word-topic slice (topic inspection,
    /// tests).  None while the slice is leased out to a worker.
    pub fn peek_slice(&self, slice_id: usize) -> Option<&BSlice> {
        self.slices.peek(slice_id)
    }

    /// A slice's committed version-chain head — the number of sweeps it
    /// has absorbed (rounds, minus any `SkipPolicy::Defer` deferrals).
    pub fn slice_version(&self, slice_id: usize) -> u64 {
        self.slices.version(slice_id)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Rotation slice count U (≥ [`LdaApp::n_workers`]).
    pub fn n_slices(&self) -> usize {
        self.n_slices
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// Install the slice-local→global word map produced by a non-striped
    /// partitioner (see
    /// [`crate::scheduler::RotationScheduler::partition_words_by_freq`]).
    pub fn set_word_map(&mut self, map: Vec<Vec<u32>>) {
        assert_eq!(map.len(), self.slices.n_slices());
        self.word_map = map;
    }

    /// Corpus word id for a slice-local row.  Falls back to the striped
    /// `w = local·U + a` layout when no explicit map was installed.
    pub fn global_word(&self, slice_id: usize, local: usize) -> usize {
        self.word_map
            .get(slice_id)
            .and_then(|m| m.get(local))
            .map(|&w| w as usize)
            .unwrap_or(local * self.n_slices + slice_id)
    }
}

impl StradsApp for LdaApp {
    type Task = LdaTask;
    type Partial = LdaPartial;
    type SyncMsg = Vec<f32>; // unused: s travels with tasks
    type WorkerState = Box<dyn LdaShard>;

    fn schedule(&mut self, round: u64) -> Vec<LdaTask> {
        let u = self.n_slices;
        // skip-capable scheduling polls the data plane (see
        // kvstore::rotation_availability); under SkipPolicy::Never the
        // signal would be ignored anyway, so the default path skips the
        // per-slice router polls entirely and the grants are the PR-4
        // stream bit-exact
        let grants = match self.sched.skip_policy() {
            SkipPolicy::Never => self.sched.next_round_grants(|_| true),
            SkipPolicy::Defer { .. } => {
                let avail = crate::kvstore::rotation_availability(
                    self.router.as_deref(),
                    &self.ledger,
                );
                self.sched.next_round_grants(|a| avail[a])
            }
        };
        // per-round disjointness is what licenses parallel sweeps
        let mut seen = vec![false; u];
        let mut tasks = Vec::with_capacity(grants.len());
        for (w, queue) in grants.into_iter().enumerate() {
            let mut legs = Vec::with_capacity(queue.len());
            for GrantLeg { slice_id, dest_worker } in queue {
                assert!(
                    !seen[slice_id],
                    "slice {slice_id} assigned twice in one round"
                );
                seen[slice_id] = true;
                let (b_slice, version) = match &self.router {
                    // pipelined rotation: grant a versioned lease; the
                    // slice moves worker→worker, only metadata + the
                    // synced s ship from here
                    Some(_) => (None, Some(self.ledger.grant(slice_id))),
                    None => (Some(self.slices.checkout(slice_id).data), None),
                };
                legs.push(LdaTaskLeg { slice_id, b_slice, version, dest_worker });
            }
            // replaying a recorded run: re-drive this queue in the
            // recorded sweep order and service it strictly, so the
            // original take sequence — and hence the math — reproduces
            // bit-exactly (the recorded order happened, so strict
            // blocking service cannot deadlock)
            let order = match &self.replay {
                Some(rep) if self.router.is_some() => {
                    legs = rep.reorder_legs(round, w, legs, |l| l.slice_id);
                    QueueOrder::Strict
                }
                _ => self.sched.queue_order(),
            };
            tasks.push(LdaTask {
                legs,
                s: self.s_snapshot.clone(),
                router: self.router.as_ref().map(Arc::clone),
                order,
                sampler: self.sampler,
            });
        }
        if self.router.is_some() {
            self.inflight_s.insert(round, self.s_snapshot.clone());
        }
        tasks
    }

    fn push(ws: &mut Self::WorkerState, task: LdaTask) -> LdaPartial {
        /// One routed leg once its slice is in hand: sweep, forward to the
        /// next holder, report the consumed lease.  The reported lease
        /// carries the version the *router* handed over, so the engine's
        /// collect-time cross-check against the granted token spans both
        /// layers.
        fn routed_leg(
            ws: &mut Box<dyn LdaShard>,
            router: &SliceRouter<BSlice>,
            slice_id: usize,
            dest_worker: usize,
            mut data: BSlice,
            consumed: u64,
            s_running: &mut Vec<f32>,
        ) -> (usize, LdaPartialLeg) {
            // in-place sweep: s̃ threads through the caller's buffer, so a
            // multi-leg queue allocates nothing per leg (the threaded
            // backend's hot path)
            let (n_sampled, touched) =
                ws.gibbs_slice_into(slice_id, &mut data.counts, s_running);
            let handoff_bytes = data.counts.len() * 4;
            // the arrival stamp of the handoff this leg consumed — read
            // before the forward re-stamps the slot (the holder is the
            // slot's sole depositor, so the read cannot race)
            let arrival_seq = router.arrival_seq(slice_id);
            router.forward(slice_id, data, consumed + 1);
            let leg = LdaPartialLeg {
                slice_id,
                b_slice: None,
                lease: Some(LeaseToken { slice_id, version: consumed }),
                handoff_bytes,
                dest_worker,
                n_sampled,
                arrival_seq,
            };
            (touched, leg)
        }

        let LdaTask { legs, s, router, order, sampler } = task;
        // kernel selection precedes every sweep: tasks are the only
        // channel that reaches worker state under both backends
        ws.set_sampler(sampler);
        let n_topics = s.len();
        // the worker's local s̃ threads through the queue: the next swept
        // leg samples against the sums the previous one left behind
        let mut s_running = s;
        let mut out_legs = Vec::with_capacity(legs.len());
        let mut touched_words = 0usize;

        // reordered sweeps apply to routed legs only (BSP legs carry
        // their slices — there is nothing to wait on): sweep whichever
        // granted slice landed first ([`SliceRouter::take_earliest`],
        // Availability) or the heaviest parked one
        // ([`SliceRouter::take_heaviest`], Dynamic) instead of stalling
        // on ring order.
        if order != QueueOrder::Strict && router.is_some() {
            let router = router.as_ref().expect("checked is_some");
            let mut remaining = legs;
            let spin = Duration::from_millis(router_spin_ms());
            while !remaining.is_empty() {
                let grants: Vec<(usize, u64)> = remaining
                    .iter()
                    .map(|l| {
                        let version =
                            l.version.expect("reordered legs are routed");
                        (l.slice_id, version)
                    })
                    .collect();
                let picked = match order {
                    QueueOrder::Dynamic => router.take_heaviest(&grants, spin),
                    _ => router.take_earliest(&grants, spin),
                };
                let (pick, data, consumed) = match picked {
                    Ok(t) => t,
                    Err(e) => {
                        // deadline expired with every remaining grant still
                        // parked — report the wedge instead of panicking;
                        // the engine aborts the run
                        return LdaPartial {
                            legs: out_legs,
                            s_local: s_running,
                            touched_words,
                            n_topics,
                            error: Some(e),
                        };
                    }
                };
                let leg = remaining.remove(pick);
                let (touched, out) = routed_leg(
                    ws,
                    router,
                    leg.slice_id,
                    leg.dest_worker,
                    data,
                    consumed,
                    &mut s_running,
                );
                touched_words += touched;
                out_legs.push(out);
            }
            return LdaPartial {
                legs: out_legs,
                s_local: s_running,
                touched_words,
                n_topics,
                error: None,
            };
        }

        let mut error = None;
        for leg in legs {
            let LdaTaskLeg { slice_id, b_slice, version, dest_worker } = leg;
            match (&router, version, b_slice) {
                (Some(router), Some(version), None) => {
                    // receive the slice from its previous holder (blocks
                    // until exactly this version was forwarded), sweep,
                    // then hand it straight on to the next holder
                    let (data, consumed) = match router.take(slice_id, version)
                    {
                        Ok(t) => t,
                        Err(e) => {
                            error = Some(e);
                            break;
                        }
                    };
                    let (touched, out) = routed_leg(
                        ws, router, slice_id, dest_worker, data, consumed,
                        &mut s_running,
                    );
                    touched_words += touched;
                    out_legs.push(out);
                }
                (None, None, Some(mut data)) => {
                    let (n_sampled, touched) = ws.gibbs_slice_into(
                        slice_id,
                        &mut data.counts,
                        &mut s_running,
                    );
                    touched_words += touched;
                    out_legs.push(LdaPartialLeg {
                        slice_id,
                        b_slice: Some(data),
                        lease: None,
                        handoff_bytes: 0,
                        dest_worker,
                        n_sampled,
                        arrival_seq: 0,
                    });
                }
                _ => panic!("task leg mixes the BSP and routed forms"),
            }
        }
        LdaPartial { legs: out_legs, s_local: s_running, touched_words, n_topics, error }
    }

    fn pull(&mut self, round: u64, partials: Vec<LdaPartial>) -> Option<Vec<f32>> {
        // rebuild the true s from per-worker deltas (slices are disjoint,
        // so deltas add); collect the stale local copies for Δ_t.  Deltas
        // are relative to the snapshot the workers were handed — under
        // pipelined rotation that is the snapshot captured at *dispatch*,
        // which later pulls may already have superseded.  A routed pull
        // with no recorded snapshot is a protocol bug: baselining against
        // a refreshed snapshot would silently drift token mass.
        let baseline = match self.inflight_s.remove(&round) {
            Some(snapshot) => snapshot,
            None if self.router.is_some() => {
                panic!("rotation pull for round {round} has no dispatch snapshot")
            }
            None => self.s_snapshot.clone(),
        };
        let mut s_new = self.s.clone();
        let mut local_copies = Vec::with_capacity(partials.len());
        for part in partials {
            let LdaPartial { legs, s_local, .. } = part;
            for k in 0..self.n_topics {
                s_new[k] += s_local[k] - baseline[k];
            }
            for leg in legs {
                match (leg.b_slice, leg.lease) {
                    (Some(data), _) => {
                        // BSP checkin: rebuild a lease-shaped return
                        let lease = crate::kvstore::SliceLease {
                            slice_id: leg.slice_id,
                            data,
                            version: self.slices.version(leg.slice_id),
                        };
                        self.slices.checkin(lease);
                    }
                    (None, Some(token)) => {
                        // the engine collects every granted lease exactly
                        // once, so a fenced (zombie) settle here is a
                        // pipeline bug, not a recoverable condition
                        self.ledger.settle(&token).unwrap_or_else(|z| {
                            panic!("zombie settle in engine flow: {z:?}")
                        });
                    }
                    (None, None) => {
                        panic!("partial leg carries neither a slice nor a lease")
                    }
                }
            }
            local_copies.push(s_local);
        }
        self.last_s_error = s_error(&local_copies, &s_new, self.n_tokens);
        self.s_error_history.push(self.last_s_error);
        self.s = s_new;
        self.pulls += 1;
        if self.pulls % self.s_staleness == 0 {
            self.s_snapshot = self.s.clone(); // BSP refresh (sync)
        }
        None // s ships with the next round's tasks
    }

    fn sync(_ws: &mut Self::WorkerState, _msg: &Vec<f32>) {}

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        ws.doc_loglik()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        shard_sum + self.word_loglik()
    }

    fn minimizing() -> bool {
        false // maximize log-likelihood
    }

    fn task_bytes(t: &LdaTask) -> usize {
        // B rows are fetched lazily from the partitioned KV store as the
        // worker samples (charged in partial_bytes); the scheduled task
        // itself carries only the slice queue and the synced s.
        t.s.len() * 4 + 8 * t.legs.len().max(1)
    }

    fn partial_bytes(p: &LdaPartial) -> usize {
        if p.legs.iter().any(|l| l.b_slice.is_some()) {
            // BSP KV-store traffic for the round: each distinct word row
            // touched is fetched once and written back once (2×K×4
            // bytes), plus s̃.
            p.touched_words * p.n_topics * 4 * 2 + p.s_local.len() * 4 + 16
        } else {
            // rotation: only the doc stats + lease tokens ride the hub;
            // the slice bytes are charged as the p2p handoffs
            p.s_local.len() * 4 + 32 * p.legs.len().max(1)
        }
    }

    fn sync_bytes(m: &Vec<f32>) -> usize {
        m.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }

    fn p2p_payloads() -> bool {
        // the word-topic slices rotate between workers / are served by the
        // partitioned KV store — they never funnel through the scheduler
        // (the paper's star topology carries schedule metadata, not data)
        true
    }

    fn supports_ssp() -> bool {
        // rotation leases each word-topic slice to exactly one worker per
        // round: SSP's shared-state stale reads do not apply.  Pipelining
        // happens through the rotation path below instead, so an SSP
        // request degrades to pipelined rotation, not to a barrier.
        false
    }

    fn supports_rotation() -> bool {
        true
    }

    fn rotation_caps() -> RotationCaps {
        // reorder: the Gibbs sweep threads s̃ leg to leg but is otherwise
        // order-free — any within-queue permutation leaves disjointness,
        // the version chains, and token conservation intact.
        // skip: the schedule already routes through next_round_grants
        // with a live parked-version signal, and push/pull tolerate short
        // (even empty) queues — a skipped slice simply contributes no
        // sweep and no s̃ delta that round.
        // elastic: slice state lives in the router/store, not on workers;
        // ownership is pure placement, so membership changes reduce to a
        // re_place at a drained boundary (recover_membership below).
        // mh_sampler: the native shard implements the alias/MH kernel and
        // every sweep is already lease-scoped, which is the cache boundary
        // the kernel needs.
        RotationCaps {
            queue_reorder: true,
            skip: true,
            elastic: true,
            mh_sampler: true,
        }
    }

    fn negotiate(&mut self, cfg: &RunConfig) -> EffectiveConfig {
        let eff = EffectiveConfig::negotiate(cfg, Self::rotation_caps());
        self.sched.set_queue_order(eff.queue_order);
        self.sched.set_skip_policy(eff.skip_policy);
        if let Some(restored) = self.restored_sampler {
            assert_eq!(
                restored, eff.sampler,
                "checkpoint was taken under sampler {restored} but this \
                 resume negotiates {}: resuming a chain under the other \
                 kernel would silently sample a different posterior path",
                eff.sampler
            );
        }
        self.sampler = eff.sampler;
        eff
    }

    fn install_trace(&mut self, plumbing: TracePlumbing) {
        self.replay = plumbing.replayer.clone();
        self.sched.install_trace(&plumbing);
    }

    fn n_rotation_slices(&self) -> usize {
        self.n_slices
    }

    fn data_plane_block_secs(&self) -> f64 {
        // cumulative seconds workers physically parked on the handoff
        // ring (0.0 under BSP, where there is no router)
        self.router.as_ref().map(|r| r.block_secs()).unwrap_or(0.0)
    }

    fn install_net_faults(
        &mut self,
        plan: NetFaultPlan,
        sink: Option<Arc<TraceBuffer>>,
    ) {
        self.router
            .as_ref()
            .expect("net faults install after begin_rotation")
            .install_link(plan, sink);
    }

    fn net_stats(&self) -> NetLinkStats {
        self.router.as_ref().map(|r| r.net_stats()).unwrap_or_default()
    }

    fn recover_data_plane(&mut self) -> bool {
        // Transport recovery at a salvaged boundary: redeliver every
        // buffered retransmit into its slot (the sender already swept the
        // payload — it must not be lost), then fence each chain at its
        // settled head so the engine re-grants exactly the legs whose
        // sweeps never completed.  Unlike `recover_membership` this runs
        // at a *wedged* boundary: orphaned grants are the expected case,
        // not a drain bug.
        let router = self.router.as_ref().expect("rotation mode active");
        router.flush_all();
        self.ledger.recover_all();
        true
    }

    fn begin_rotation(&mut self, _depth: u64) {
        assert!(self.router.is_none(), "rotation mode already active");
        let router = Arc::new(SliceRouter::new(self.slices.n_slices()));
        for a in 0..self.slices.n_slices() {
            let lease = self.slices.checkout(a);
            self.ledger.seed(a, lease.version);
            router.seed(a, lease.data, lease.version);
        }
        self.router = Some(router);
    }

    fn end_rotation(&mut self) {
        if let Some(router) = self.router.take() {
            for a in 0..router.n_slices() {
                let (data, version) = router.reclaim(a);
                self.slices.restore(a, data, version);
            }
        }
        self.inflight_s.clear();
    }

    fn task_leases(t: &LdaTask) -> Vec<LeaseToken> {
        t.legs
            .iter()
            .filter_map(|l| {
                l.version.map(|version| LeaseToken {
                    slice_id: l.slice_id,
                    version,
                })
            })
            .collect()
    }

    fn partial_legs(p: &LdaPartial) -> Vec<HandoffLeg> {
        p.legs
            .iter()
            .filter_map(|l| {
                l.lease.map(|token| HandoffLeg {
                    token,
                    dest_worker: l.dest_worker,
                    bytes: l.handoff_bytes,
                    weight: l.n_sampled as f64,
                    arrival_seq: l.arrival_seq,
                })
            })
            .collect()
    }

    fn partial_error(p: &LdaPartial) -> Option<RouterError> {
        p.error
    }

    fn recover_membership(&mut self, alive: &[bool]) -> usize {
        let router = self.router.as_ref().expect("rotation mode active");
        // Revive before kill: `set_alive` asserts at least one worker
        // stays live, and a same-boundary kill+join could transiently
        // empty the ring if deaths were applied first.
        let prev: Vec<bool> = self.sched.alive().to_vec();
        for (w, &live) in alive.iter().enumerate() {
            if live && !prev[w] {
                self.sched.set_alive(w, true);
            }
        }
        for (w, &live) in alive.iter().enumerate() {
            if !live && prev[w] {
                self.sched.set_alive(w, false);
            }
        }
        // Rebalance from the *parked* slice masses — the engine drains
        // the window before recovery, so every slice sits in its slot.
        // Dead workers keep a ring residue but their speed is pinned ≈0,
        // so the skew-aware split leaves their cohorts empty and
        // `live_owner` folds their positions onto live neighbors.
        let u = self.n_slices;
        let masses: Vec<u64> = (0..u)
            .map(|a| {
                router.with_slice(a, |s| {
                    s.expect("slice parked at a drained recovery boundary")
                        .mass()
                }) as u64
            })
            .collect();
        let speeds: Vec<f64> = alive
            .iter()
            .map(|&live| if live { 1.0 } else { 1e-9 })
            .collect();
        let placement = rotation::skew_aware_placement(&masses, &speeds);
        let moved =
            (0..u).filter(|&v| self.sched.slice_at(v) != placement[v]).count();
        self.sched.re_place(placement);
        // Fence every chain at its settled head so a zombie settle from
        // the dead worker's last partial hits [`StaleLease`], never the
        // ledger.  The drain above already collected all live grants, so
        // no orphans are expected here — the fence is belt-and-braces.
        let orphaned = self.ledger.recover_all();
        debug_assert_eq!(orphaned, 0, "recovery boundary was not drained");
        moved
    }

    fn supports_checkpoint() -> bool {
        true
    }

    fn checkpoint_app(&mut self) -> Vec<u8> {
        let router =
            self.router.as_ref().expect("checkpoint requires rotation mode");
        let mut w = Wire::new();
        w.put_u64(self.n_slices as u64);
        w.put_u64(self.n_topics as u64);
        for a in 0..self.n_slices {
            // every slice is parked at a drained boundary, so the chain
            // head is exactly the parked version
            let version = router
                .parked_version(a)
                .expect("slice parked at a drained checkpoint boundary");
            w.put_u64(version);
            let (n_words, counts) = router.with_slice(a, |s| {
                let s =
                    s.expect("slice parked at a drained checkpoint boundary");
                (s.n_words as u64, s.counts.clone())
            });
            w.put_u64(n_words);
            w.put_f32s(&counts);
        }
        w.put_f32s(&self.s);
        w.put_f32s(&self.s_snapshot);
        w.put_u64(self.pulls);
        w.put_u64(self.sched.round());
        // current-round slice coordinates (what `re_place` consumes),
        // so a resume reproduces placement even after mid-run reshuffles
        let current: Vec<u64> =
            (0..self.n_slices).map(|v| self.sched.slice_at(v) as u64).collect();
        w.put_u64s(&current);
        // the kernel is chain state: a resume must negotiate the same one
        w.put_u64(match self.sampler {
            SamplerKind::Exact => 0,
            SamplerKind::Mh => 1,
        });
        w.into_bytes()
    }

    fn restore_app(&mut self, blob: &[u8]) {
        assert!(
            self.router.is_none(),
            "restore must run before begin_rotation"
        );
        let mut r = Unwire::new(blob);
        assert_eq!(r.u64() as usize, self.n_slices, "slice count mismatch");
        assert_eq!(r.u64() as usize, self.n_topics, "topic count mismatch");
        for a in 0..self.n_slices {
            let version = r.u64();
            let n_words = r.u64() as usize;
            let counts = r.f32s();
            // drop the freshly built payload, then restore into the empty
            // slot (versions only move forward, which a checkpoint of the
            // same run always satisfies)
            let _ = self.slices.checkout(a);
            self.slices.restore(a, BSlice { counts, n_words }, version);
        }
        self.s = r.f32s();
        self.s_snapshot = r.f32s();
        self.pulls = r.u64();
        let counter = r.u64();
        let current: Vec<usize> =
            r.u64s().into_iter().map(|v| v as usize).collect();
        self.restored_sampler = Some(match r.u64() {
            0 => SamplerKind::Exact,
            1 => SamplerKind::Mh,
            other => panic!("checkpoint has unknown sampler tag {other}"),
        });
        r.done();
        // set_round first: re_place converts current-round coordinates
        // through the restored counter
        self.sched.set_round(counter);
        self.sched.re_place(current);
        self.inflight_s.clear();
    }

    fn checkpoint_worker(ws: &mut Self::WorkerState) -> Vec<u8> {
        ws.save_state()
    }

    fn restore_worker(ws: &mut Self::WorkerState, blob: &[u8]) {
        ws.load_state(blob);
    }
}

/// Helpers to build the initial partitioned state from a corpus.
pub mod setup {
    use super::*;
    use crate::backend::native::{NativeLdaShard, Token};
    use crate::datagen::Corpus;
    use crate::util::Rng;

    /// Partitioned LDA problem ready for the engine.
    pub struct LdaSetup {
        pub app: LdaApp,
        pub shards: Vec<Box<dyn LdaShard>>,
    }

    /// Build slices + worker shards from a corpus with U = `n_workers`
    /// rotation slices (the paper's one-slice-per-worker layout); see
    /// [`build_sliced`] for the over-decomposed U > P form.
    pub fn build(
        corpus: &Corpus,
        k: usize,
        n_workers: usize,
        alpha: f32,
        gamma: f32,
        seed: u64,
    ) -> LdaSetup {
        build_sliced(corpus, k, n_workers, n_workers, None, alpha, gamma, seed)
    }

    /// Build slices + worker shards from a corpus: documents are striped
    /// over workers, words are partitioned into `n_slices` ≥ `n_workers`
    /// rotation slices by the frequency-weighted split
    /// ([`crate::scheduler::RotationScheduler::partition_words_by_freq`]
    /// — per-round compute tracks a slice's token mass, so the Zipf head
    /// must spread across slices), and initial topics are drawn uniformly.
    /// When `worker_speeds` is given (relative speeds, higher = faster —
    /// see `StragglerModel::mean_speeds`), the ring placement is
    /// skew-aware: cohort masses balanced, heavy slices starting on fast
    /// workers ([`crate::scheduler::rotation::skew_aware_placement`]).
    #[allow(clippy::too_many_arguments)]
    pub fn build_sliced(
        corpus: &Corpus,
        k: usize,
        n_workers: usize,
        n_slices: usize,
        worker_speeds: Option<&[f64]>,
        alpha: f32,
        gamma: f32,
        seed: u64,
    ) -> LdaSetup {
        build_sliced_targets(
            corpus, k, n_workers, n_slices, worker_speeds, None, alpha,
            gamma, seed,
        )
    }

    /// [`build_sliced`] with an optional **slice-mass profile**: when
    /// `slice_mass_targets` is given, words are partitioned so slice `a`
    /// holds ≈ `targets[a]` of the corpus token mass
    /// ([`RotationScheduler::partition_words_to_targets`]) instead of the
    /// default balanced split — the controlled skew (e.g. a Zipf profile)
    /// the dynamic-order experiments sweep heaviest-first.  Skewed builds
    /// use the identity ring placement unless `worker_speeds` asks for
    /// the skew-aware one.
    #[allow(clippy::too_many_arguments)]
    pub fn build_sliced_targets(
        corpus: &Corpus,
        k: usize,
        n_workers: usize,
        n_slices: usize,
        worker_speeds: Option<&[f64]>,
        slice_mass_targets: Option<&[f64]>,
        alpha: f32,
        gamma: f32,
        seed: u64,
    ) -> LdaSetup {
        let u = n_slices;
        let v = corpus.vocab;
        assert!(u >= n_workers, "fewer slices than workers");
        assert!(v >= u, "vocab smaller than the slice count");
        if let Some(t) = slice_mass_targets {
            assert_eq!(t.len(), u, "one mass target per slice");
        }
        let mut rng = Rng::new(seed);

        // word→slice map (frequency-balanced by default, target-profiled
        // when a mass profile is given), plus slice-local indices
        let mut freqs = vec![0u64; v];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let slice_of = match slice_mass_targets {
            Some(targets) => {
                RotationScheduler::partition_words_to_targets(&freqs, targets)
            }
            None => RotationScheduler::partition_words_by_freq(&freqs, u),
        };
        let mut local_of = vec![0u32; v];
        let mut word_map: Vec<Vec<u32>> = vec![Vec::new(); u];
        for w in 0..v {
            let a = slice_of[w];
            local_of[w] = word_map[a].len() as u32;
            word_map[a].push(w as u32);
        }

        // word-topic slices
        let mut slices: Vec<BSlice> = word_map
            .iter()
            .map(|words| BSlice {
                counts: vec![0.0; words.len() * k],
                n_words: words.len(),
            })
            .collect();
        let mut s = vec![0.0f32; k];

        // worker doc shards: doc d -> worker d % n_workers
        let mut per_worker_tokens: Vec<Vec<Vec<Token>>> =
            (0..n_workers).map(|_| vec![Vec::new(); u]).collect();
        let mut per_worker_docs = vec![0usize; n_workers];
        for (d, doc) in corpus.docs.iter().enumerate() {
            let p = d % n_workers;
            let local_doc = per_worker_docs[p];
            per_worker_docs[p] += 1;
            for &w in doc {
                let w = w as usize;
                let slice = slice_of[w];
                let word_local = local_of[w];
                let z = rng.below(k) as u32;
                slices[slice].counts[word_local as usize * k + z as usize] += 1.0;
                s[z as usize] += 1.0;
                per_worker_tokens[p][slice].push(Token {
                    doc: local_doc as u32,
                    word_local,
                    z,
                });
            }
        }

        let n_tokens = corpus.n_tokens();
        let mut app = LdaApp::new(
            LdaConfig {
                n_topics: k,
                vocab: v,
                n_workers,
                alpha,
                gamma,
            },
            slices,
            s,
            n_tokens,
        );
        app.set_word_map(word_map);
        if let Some(speeds) = worker_speeds {
            // slice token masses drive the skew-aware ring order
            let mut masses = vec![0u64; u];
            for (w, &f) in freqs.iter().enumerate() {
                masses[slice_of[w]] += f;
            }
            app.set_ring_placement(rotation::skew_aware_placement(
                &masses, speeds,
            ));
        }
        let shards: Vec<Box<dyn LdaShard>> = per_worker_tokens
            .into_iter()
            .enumerate()
            .map(|(p, tokens)| {
                Box::new(NativeLdaShard::new(
                    tokens,
                    per_worker_docs[p].max(1),
                    k,
                    alpha,
                    gamma,
                    v,
                    seed ^ (p as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
                )) as Box<dyn LdaShard>
            })
            .collect();
        LdaSetup { app, shards }
    }
}

#[cfg(test)]
mod tests {
    use super::setup;
    use super::*;
    use crate::coordinator::{RunConfig, StradsEngine};
    use crate::datagen::lda_corpus::{self, CorpusConfig};

    fn engine(workers: usize, k: usize, seed: u64) -> StradsEngine<LdaApp> {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed,
            ..Default::default()
        });
        let s = setup::build(&corpus, k, workers, 0.1, 0.01, seed);
        StradsEngine::new(s.app, s.shards, &RunConfig::default())
    }

    #[test]
    fn gibbs_improves_loglik() {
        let mut e = engine(4, 8, 1);
        let ll0 = e.evaluate();
        for r in 0..20 {
            e.round(r);
        }
        let ll1 = e.evaluate();
        assert!(ll1 > ll0, "log-likelihood {ll0} -> {ll1}");
    }

    #[test]
    fn s_is_consistent_with_slices() {
        let mut e = engine(3, 6, 2);
        for r in 0..6 {
            e.round(r);
        }
        // s must equal the column sums over all slices
        let app = e.app();
        let k = app.n_topics();
        let mut sums = vec![0.0f32; k];
        for a in 0..app.slices.n_slices() {
            let sl = app.slices.peek(a).unwrap();
            for w in 0..sl.n_words {
                for kk in 0..k {
                    sums[kk] += sl.counts[w * k + kk];
                }
            }
        }
        for (a, b) in sums.iter().zip(app.s.iter()) {
            assert!((a - b).abs() < 1e-2, "{sums:?} vs {:?}", app.s);
        }
    }

    #[test]
    fn s_error_is_small_and_bounded() {
        let mut e = engine(4, 8, 3);
        for r in 0..10 {
            e.round(r);
        }
        for &d in &e.app().s_error_history {
            assert!((0.0..=2.0).contains(&d));
            // paper Fig 5: Δ_t tiny; generous bound here
            assert!(d < 0.1, "Δ_t = {d}");
        }
    }

    #[test]
    fn ssp_staleness_raises_s_error_but_conserves_counts() {
        let mut bsp = engine(4, 8, 6);
        let mut ssp = engine(4, 8, 6);
        ssp.app_mut().set_s_staleness(8);
        for r in 0..16 {
            bsp.round(r);
            ssp.round(r);
        }
        let e_bsp: f64 =
            bsp.app().s_error_history.iter().sum::<f64>() / 16.0;
        let e_ssp: f64 =
            ssp.app().s_error_history.iter().sum::<f64>() / 16.0;
        assert!(
            e_ssp > e_bsp,
            "staleness must raise mean s-error ({e_bsp} vs {e_ssp})"
        );
        let total: f32 = ssp.app().s.iter().sum();
        let total_bsp: f32 = bsp.app().s.iter().sum();
        assert!((total - total_bsp).abs() < 1e-2);
    }

    #[test]
    fn pipelined_rotation_runs_and_conserves_counts() {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed: 8,
            ..Default::default()
        });
        let s = setup::build(&corpus, 8, 4, 0.1, 0.01, 8);
        let cfg = RunConfig {
            max_rounds: 16,
            eval_every: 4,
            mode: crate::coordinator::ExecutionMode::Rotation { depth: 3 },
            label: "lda-rot".into(),
            ..Default::default()
        };
        let mut e = StradsEngine::new(s.app, s.shards, &cfg);
        let total0: f32 = e.app().s.iter().sum();
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, 16);
        let stats = res.ssp.expect("rotation run reports pipeline stats");
        assert!(stats.max_staleness() <= 2, "depth-3 bound");
        assert!(res.total_p2p_bytes > 0, "handoffs must ride p2p links");
        // slices are back in the store with advanced version chains
        let app = e.app();
        for a in 0..app.slices.n_slices() {
            assert!(app.slices.peek(a).is_some());
            assert_eq!(app.slices.version(a), 16);
        }
        let total1: f32 = app.s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
        // the run must actually learn
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective > first);
    }

    #[test]
    fn multislice_rotation_runs_and_conserves_counts() {
        // U = 2P: every worker sweeps a two-slice queue each round; the
        // handoff ring carries 8 slices over 4 workers.  One handoff per
        // slice per round must hit the p2p accounting, token mass is
        // conserved, and each slice's version chain advances once per
        // round.
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed: 9,
            ..Default::default()
        });
        let (workers, u) = (4usize, 8usize);
        let rounds = 16u64;
        let s = setup::build_sliced(
            &corpus, 8, workers, u, Some(&[1.0; 4]), 0.1, 0.01, 9,
        );
        assert_eq!(s.app.n_slices(), u);
        let cfg = RunConfig {
            max_rounds: rounds,
            eval_every: 4,
            mode: crate::coordinator::ExecutionMode::Rotation { depth: 3 },
            label: "lda-rot-u2p".into(),
            ..Default::default()
        };
        let mut e = StradsEngine::new(s.app, s.shards, &cfg);
        let total0: f32 = e.app().s.iter().sum();
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, rounds);
        assert!(res.total_p2p_bytes > 0);
        // every slice is forwarded once per round (U handoffs per round),
        // minus the self-transfers the network model skips; with U = 2P
        // each round has at least U - P distinct-endpoint handoffs
        assert!(
            res.total_p2p_msgs >= rounds * (u - workers) as u64,
            "only {} handoffs recorded",
            res.total_p2p_msgs
        );
        let app = e.app();
        for a in 0..app.slices.n_slices() {
            assert!(app.slices.peek(a).is_some());
            assert_eq!(app.slices.version(a), rounds);
        }
        let total1: f32 = app.s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective > first);
    }

    #[test]
    fn availability_order_runs_and_conserves_counts() {
        // U = 2P availability-ordered rotation under jittered handoff
        // latencies: workers sweep whichever queued slice lands first
        // (any within-queue permutation), yet every invariant holds —
        // token mass conserved, each chain advances once per round, the
        // run learns, and the engine reports the handoff wait it modelled.
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed: 11,
            ..Default::default()
        });
        let (workers, u) = (4usize, 8usize);
        let rounds = 16u64;
        let s = setup::build_sliced(
            &corpus, 8, workers, u, Some(&[1.0; 4]), 0.1, 0.01, 11,
        );
        let cfg = RunConfig {
            max_rounds: rounds,
            eval_every: 4,
            mode: crate::coordinator::ExecutionMode::Rotation { depth: 3 },
            queue_order: QueueOrder::Availability,
            handoff_jitter: crate::cluster::HandoffJitter::Jittered {
                base_frac: 0.2,
                jitter_frac: 1.5,
                seed: 11,
            },
            label: "lda-avail".into(),
            ..Default::default()
        };
        let mut e = StradsEngine::new(s.app, s.shards, &cfg);
        let total0: f32 = e.app().s.iter().sum();
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, rounds);
        assert!(res.total_p2p_bytes > 0);
        assert!(
            res.total_handoff_wait_secs >= 0.0,
            "handoff wait is accounted"
        );
        let app = e.app();
        for a in 0..app.slices.n_slices() {
            assert!(app.slices.peek(a).is_some());
            assert_eq!(app.slices.version(a), rounds);
        }
        let total1: f32 = app.s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective > first, "the run must learn");
    }

    #[test]
    fn u_equals_p_schedule_is_the_single_slice_stream() {
        // the app-level half of the "U = P is bit-identical to the PR-2
        // single-slice rotation" regression (the scheduler-level half
        // lives in scheduler::rotation): with U = P every task must be a
        // single-leg checkout following the paper's `(a + C) % U`
        // assignment, with the same s snapshot the old path shipped —
        // push/pull then see inputs identical to the one-slice code, so
        // trajectories are reproduced bit-exactly (locked end-to-end by
        // rotation_depth1_matches_bsp_exactly in tests/).
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 80,
            vocab: 300,
            doc_len_mean: 25,
            n_topics: 4,
            seed: 24,
            ..Default::default()
        });
        let mut s = setup::build(&corpus, 6, 4, 0.1, 0.01, 24);
        let u = s.app.n_slices();
        assert_eq!(u, s.app.n_workers());
        for c in 0..3 * u as u64 {
            let tasks = s.app.schedule(c);
            for (w, task) in tasks.iter().enumerate() {
                assert_eq!(task.legs.len(), 1, "U = P tasks are single-leg");
                assert_eq!(task.legs[0].slice_id, (w + c as usize) % u);
                assert!(task.legs[0].b_slice.is_some(), "BSP leg ships B");
                assert_eq!(task.s, s.app.s_snapshot);
            }
            // return the checked-out slices so the next round can lease
            // them again (pull's checkin path, minus the delta bookkeeping)
            for task in tasks {
                for leg in task.legs {
                    let lease = crate::kvstore::SliceLease {
                        slice_id: leg.slice_id,
                        data: leg.b_slice.expect("BSP leg ships its slice"),
                        version: s.app.slices.version(leg.slice_id),
                    };
                    s.app.slices.checkin(lease);
                }
            }
        }
    }

    #[test]
    fn global_word_roundtrips_the_frequency_partition() {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 80,
            vocab: 300,
            doc_len_mean: 25,
            n_topics: 4,
            seed: 5,
            ..Default::default()
        });
        let s = setup::build(&corpus, 4, 3, 0.1, 0.01, 5);
        // every corpus word appears exactly once across the slice maps
        let mut seen = vec![false; corpus.vocab];
        for a in 0..s.app.n_slices() {
            let n_words = s.app.peek_slice(a).unwrap().n_words;
            for local in 0..n_words {
                let w = s.app.global_word(a, local);
                assert!(!seen[w], "word {w} mapped twice");
                seen[w] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn token_count_is_conserved() {
        let mut e = engine(2, 4, 4);
        let total0: f32 = e.app().s.iter().sum();
        for r in 0..8 {
            e.round(r);
        }
        let total1: f32 = e.app().s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
    }
}
