//! STRADS LDA (paper §3.1, pseudocode Fig 4).
//!
//! schedule: the rotation scheduler assigns each worker one word slice per
//!           round; the slice's word-topic block B_a is checked out of the
//!           kvstore and shipped with the task (its bytes dominate the
//!           round's traffic, exactly as in the paper's star topology).
//! push:     the worker Gibbs-sweeps its tokens whose words lie in the
//!           slice, mutating B_a and a *local* copy s̃ of the topic sums.
//! pull:     B slices are checked back in; the true s is rebuilt from the
//!           per-worker deltas; the s-error Δ (eq. 1) is measured here.
//! sync:     the fresh s ships with the next round's tasks (the paper syncs
//!           s at the end of every pull).
//!
//! Under `ExecutionMode::Rotation { depth }` the checkout/checkin cycle is
//! replaced by the async p2p path: slices live in a shared
//! [`SliceRouter`], each push takes its versioned lease from the ring
//! predecessor and forwards the swept slice directly to the successor, and
//! `pull` only settles lease tokens against a [`LeaseLedger`] — rotation
//! pipelines like SSP while slice disjointness stays runtime-enforced.

use crate::backend::LdaShard;
use crate::coordinator::StradsApp;
use crate::kvstore::{LeaseLedger, LeaseToken, SliceRouter, SliceStore};
use crate::metrics::s_error;
use crate::scheduler::RotationScheduler;
use std::collections::HashMap;
use std::sync::Arc;

/// Coordinator-side configuration.
pub struct LdaConfig {
    pub n_topics: usize,
    pub vocab: usize,
    pub n_workers: usize,
    pub alpha: f32,
    pub gamma: f32,
}

/// One word-topic slice: dense (slice_words × K) counts.
#[derive(Clone, Debug)]
pub struct BSlice {
    pub counts: Vec<f32>,
    pub n_words: usize,
}

/// Task for one worker: its slice assignment plus the freshly synced topic
/// sums, and the slice payload (BSP) or its routed lease (rotation).
pub struct LdaTask {
    pub slice_id: usize,
    /// BSP path: the checked-out slice ships with the task.
    pub b_slice: Option<BSlice>,
    pub s: Vec<f32>,
    /// Rotation-pipelined path: take/forward the slice through the router
    /// instead.
    pub route: Option<LdaRoute>,
}

/// Rotation leg of a task: where to receive the slice from the ring
/// predecessor and the version this lease consumes (the worker forwards
/// `version + 1` to the successor).
pub struct LdaRoute {
    pub router: Arc<SliceRouter<BSlice>>,
    pub version: u64,
}

/// Worker partial: the worker's local s̃ (for the s-error metric), the
/// token count swept, the number of distinct B rows touched (KV-store
/// traffic accounting), and either the mutated slice (BSP) or the consumed
/// lease token plus the p2p bytes forwarded (rotation).
pub struct LdaPartial {
    pub slice_id: usize,
    /// BSP path: the mutated slice returns through the coordinator.
    pub b_slice: Option<BSlice>,
    /// Rotation path: the lease this sweep consumed (fork detection).
    pub lease: Option<LeaseToken>,
    /// Rotation path: slice bytes forwarded to the ring successor.
    pub handoff_bytes: usize,
    pub s_local: Vec<f32>,
    pub n_sampled: usize,
    pub touched_words: usize,
    pub n_topics: usize,
}

/// Coordinator state.
pub struct LdaApp {
    slices: SliceStore<BSlice>,
    /// Rotation-pipelined mode: the worker→worker handoff ring (None under
    /// BSP, where slices move through `slices` instead).
    router: Option<Arc<SliceRouter<BSlice>>>,
    /// Per-slice lease version chains (grant at schedule, settle at pull;
    /// panics on fork).
    ledger: LeaseLedger,
    /// s snapshots keyed by dispatch round: pipelined pulls must baseline
    /// worker deltas against the snapshot that round actually shipped, not
    /// the latest one.
    inflight_s: HashMap<u64, Vec<f32>>,
    /// Per-slice global word ids (slice-local row → corpus word id);
    /// empty when the striped `w = local·U + a` layout is in use.
    word_map: Vec<Vec<u32>>,
    /// True topic column sums s (K).
    pub s: Vec<f32>,
    sched: RotationScheduler,
    n_topics: usize,
    vocab: usize,
    n_workers: usize,
    alpha: f32,
    gamma: f32,
    n_tokens: usize,
    /// Δ_t from the most recent pull (paper eq. 1, Fig 5).
    pub last_s_error: f64,
    pub s_error_history: Vec<f64>,
    /// SSP-style extension (paper §5 future work): refresh the s snapshot
    /// shipped to workers only every `s_staleness` pulls.  1 = strict BSP
    /// (the paper's setting); larger values trade s-error for fewer syncs.
    s_staleness: u64,
    s_snapshot: Vec<f32>,
    pulls: u64,
}

impl LdaApp {
    /// `slices` are the initial word-topic blocks (one per worker; the
    /// word→slice map is the builder's concern — [`setup::build`] uses the
    /// frequency-aware split and installs it via
    /// [`LdaApp::set_word_map`], the striped `w % U` layout needs none);
    /// `s` their column sums; `n_tokens` the corpus token count (for Δ_t
    /// normalization).
    pub fn new(
        cfg: LdaConfig,
        slices: Vec<BSlice>,
        s: Vec<f32>,
        n_tokens: usize,
    ) -> Self {
        assert_eq!(slices.len(), cfg.n_workers);
        assert_eq!(s.len(), cfg.n_topics);
        LdaApp {
            sched: RotationScheduler::new(cfg.n_workers),
            slices: SliceStore::new(slices),
            router: None,
            ledger: LeaseLedger::new(cfg.n_workers),
            inflight_s: HashMap::new(),
            word_map: Vec::new(),
            s_snapshot: s.clone(),
            s,
            n_topics: cfg.n_topics,
            vocab: cfg.vocab,
            n_workers: cfg.n_workers,
            alpha: cfg.alpha,
            gamma: cfg.gamma,
            n_tokens,
            last_s_error: 0.0,
            s_error_history: Vec::new(),
            s_staleness: 1,
            pulls: 0,
        }
    }

    /// Enable the SSP-style sync relaxation: the s snapshot is refreshed
    /// only every `staleness` pulls (1 = strict BSP, the paper's mode).
    pub fn set_s_staleness(&mut self, staleness: u64) {
        assert!(staleness >= 1);
        self.s_staleness = staleness;
    }

    /// One slice's contribution to the word-topic log-likelihood.
    fn slice_loglik(&self, slice: &BSlice) -> f64 {
        let k = self.n_topics;
        let vg = self.vocab as f64 * self.gamma as f64;
        let mut ll = 0.0f64;
        for w in 0..slice.n_words {
            for kk in 0..k {
                let c = slice.counts[w * k + kk] as f64;
                if c > 0.0 {
                    let phi =
                        (c + self.gamma as f64) / (self.s[kk] as f64 + vg);
                    ll += c * phi.ln();
                }
            }
        }
        ll
    }

    /// Word-topic log-likelihood term computed from the parked slices
    /// (checked in under BSP; drained into the router under rotation).
    fn word_loglik(&self) -> f64 {
        let mut ll = 0.0f64;
        for a in 0..self.slices.n_slices() {
            ll += match &self.router {
                Some(router) => router.with_slice(a, |slice| {
                    self.slice_loglik(
                        slice.expect("slice parked in the router at eval time"),
                    )
                }),
                None => self.slice_loglik(
                    self.slices
                        .peek(a)
                        .expect("all slices checked in at eval time"),
                ),
            };
        }
        ll
    }

    pub fn n_topics(&self) -> usize {
        self.n_topics
    }

    /// Read-only access to a checked-in word-topic slice (topic inspection,
    /// tests).  None while the slice is leased out to a worker.
    pub fn peek_slice(&self, slice_id: usize) -> Option<&BSlice> {
        self.slices.peek(slice_id)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// Install the slice-local→global word map produced by a non-striped
    /// partitioner (see
    /// [`crate::scheduler::RotationScheduler::partition_words_by_freq`]).
    pub fn set_word_map(&mut self, map: Vec<Vec<u32>>) {
        assert_eq!(map.len(), self.slices.n_slices());
        self.word_map = map;
    }

    /// Corpus word id for a slice-local row.  Falls back to the striped
    /// `w = local·U + a` layout when no explicit map was installed.
    pub fn global_word(&self, slice_id: usize, local: usize) -> usize {
        self.word_map
            .get(slice_id)
            .and_then(|m| m.get(local))
            .map(|&w| w as usize)
            .unwrap_or(local * self.n_workers + slice_id)
    }
}

impl StradsApp for LdaApp {
    type Task = LdaTask;
    type Partial = LdaPartial;
    type SyncMsg = Vec<f32>; // unused: s travels with tasks
    type WorkerState = Box<dyn LdaShard>;

    fn schedule(&mut self, round: u64) -> Vec<LdaTask> {
        let assignment = self.sched.next_round();
        if let Some(router) = &self.router {
            // pipelined rotation: grant versioned leases; the slices move
            // worker→worker, only metadata + the synced s ship from here
            let mut seen = vec![false; assignment.len()];
            let mut tasks = Vec::with_capacity(assignment.len());
            for slice_id in assignment {
                assert!(
                    !seen[slice_id],
                    "slice {slice_id} assigned twice in one round"
                );
                seen[slice_id] = true;
                let version = self.ledger.grant(slice_id);
                tasks.push(LdaTask {
                    slice_id,
                    b_slice: None,
                    s: self.s_snapshot.clone(),
                    route: Some(LdaRoute { router: Arc::clone(router), version }),
                });
            }
            self.inflight_s.insert(round, self.s_snapshot.clone());
            tasks
        } else {
            assignment
                .into_iter()
                .map(|slice_id| {
                    let lease = self.slices.checkout(slice_id);
                    LdaTask {
                        slice_id,
                        b_slice: Some(lease.data),
                        s: self.s_snapshot.clone(),
                        route: None,
                    }
                })
                .collect()
        }
    }

    fn push(ws: &mut Self::WorkerState, task: LdaTask) -> LdaPartial {
        let LdaTask { slice_id, b_slice, s, route } = task;
        let n_topics = s.len();
        match route {
            Some(LdaRoute { router, version }) => {
                // receive the slice from the ring predecessor (blocks
                // until exactly this version was forwarded), sweep, then
                // hand it straight on to the successor.  The reported
                // lease carries the version the *router* handed over, so
                // the engine's collect-time cross-check against the
                // granted token spans both layers.
                let (mut data, consumed) = router.take(slice_id, version);
                let (s_local, n_sampled, touched_words) =
                    ws.gibbs_slice(slice_id, &mut data.counts, &s);
                let handoff_bytes = data.counts.len() * 4;
                router.forward(slice_id, data, consumed + 1);
                LdaPartial {
                    slice_id,
                    b_slice: None,
                    lease: Some(LeaseToken { slice_id, version: consumed }),
                    handoff_bytes,
                    s_local,
                    n_sampled,
                    touched_words,
                    n_topics,
                }
            }
            None => {
                let mut data = b_slice.expect("BSP task carries its slice");
                let (s_local, n_sampled, touched_words) =
                    ws.gibbs_slice(slice_id, &mut data.counts, &s);
                LdaPartial {
                    slice_id,
                    b_slice: Some(data),
                    lease: None,
                    handoff_bytes: 0,
                    s_local,
                    n_sampled,
                    touched_words,
                    n_topics,
                }
            }
        }
    }

    fn pull(&mut self, round: u64, partials: Vec<LdaPartial>) -> Option<Vec<f32>> {
        // rebuild the true s from per-worker deltas (slices are disjoint,
        // so deltas add); collect the stale local copies for Δ_t.  Deltas
        // are relative to the snapshot the workers were handed — under
        // pipelined rotation that is the snapshot captured at *dispatch*,
        // which later pulls may already have superseded.  A routed pull
        // with no recorded snapshot is a protocol bug: baselining against
        // a refreshed snapshot would silently drift token mass.
        let baseline = match self.inflight_s.remove(&round) {
            Some(snapshot) => snapshot,
            None if self.router.is_some() => {
                panic!("rotation pull for round {round} has no dispatch snapshot")
            }
            None => self.s_snapshot.clone(),
        };
        let mut s_new = self.s.clone();
        let mut local_copies = Vec::with_capacity(partials.len());
        for part in partials {
            let LdaPartial { slice_id, b_slice, lease, s_local, .. } = part;
            for k in 0..self.n_topics {
                s_new[k] += s_local[k] - baseline[k];
            }
            match (b_slice, lease) {
                (Some(data), _) => {
                    // BSP checkin: rebuild a lease-shaped return
                    let lease = crate::kvstore::SliceLease {
                        slice_id,
                        data,
                        version: self.slices.version(slice_id),
                    };
                    self.slices.checkin(lease);
                }
                (None, Some(token)) => self.ledger.settle(&token),
                (None, None) => {
                    panic!("partial carries neither a slice nor a lease")
                }
            }
            local_copies.push(s_local);
        }
        self.last_s_error = s_error(&local_copies, &s_new, self.n_tokens);
        self.s_error_history.push(self.last_s_error);
        self.s = s_new;
        self.pulls += 1;
        if self.pulls % self.s_staleness == 0 {
            self.s_snapshot = self.s.clone(); // BSP refresh (sync)
        }
        None // s ships with the next round's tasks
    }

    fn sync(_ws: &mut Self::WorkerState, _msg: &Vec<f32>) {}

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        ws.doc_loglik()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        shard_sum + self.word_loglik()
    }

    fn minimizing() -> bool {
        false // maximize log-likelihood
    }

    fn task_bytes(t: &LdaTask) -> usize {
        // B rows are fetched lazily from the partitioned KV store as the
        // worker samples (charged in partial_bytes); the scheduled task
        // itself carries only the slice id and the synced s.
        t.s.len() * 4 + 8
    }

    fn partial_bytes(p: &LdaPartial) -> usize {
        if p.b_slice.is_some() {
            // BSP KV-store traffic for the round: each distinct word row
            // touched is fetched once and written back once (2×K×4
            // bytes), plus s̃.
            p.touched_words * p.n_topics * 4 * 2 + p.s_local.len() * 4 + 16
        } else {
            // rotation: only the doc stats + lease token ride the hub; the
            // slice bytes are charged as the p2p handoff (handoff_bytes)
            p.s_local.len() * 4 + 32
        }
    }

    fn sync_bytes(m: &Vec<f32>) -> usize {
        m.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }

    fn p2p_payloads() -> bool {
        // the word-topic slices rotate between workers / are served by the
        // partitioned KV store — they never funnel through the scheduler
        // (the paper's star topology carries schedule metadata, not data)
        true
    }

    fn supports_ssp() -> bool {
        // rotation leases each word-topic slice to exactly one worker per
        // round: SSP's shared-state stale reads do not apply.  Pipelining
        // happens through the rotation path below instead, so an SSP
        // request degrades to pipelined rotation, not to a barrier.
        false
    }

    fn supports_rotation() -> bool {
        true
    }

    fn begin_rotation(&mut self, _depth: u64) {
        assert!(self.router.is_none(), "rotation mode already active");
        let router = Arc::new(SliceRouter::new(self.slices.n_slices()));
        for a in 0..self.slices.n_slices() {
            let lease = self.slices.checkout(a);
            self.ledger.seed(a, lease.version);
            router.seed(a, lease.data, lease.version);
        }
        self.router = Some(router);
    }

    fn end_rotation(&mut self) {
        if let Some(router) = self.router.take() {
            for a in 0..router.n_slices() {
                let (data, version) = router.reclaim(a);
                self.slices.restore(a, data, version);
            }
        }
        self.inflight_s.clear();
    }

    fn task_lease(t: &LdaTask) -> Option<LeaseToken> {
        t.route
            .as_ref()
            .map(|r| LeaseToken { slice_id: t.slice_id, version: r.version })
    }

    fn partial_lease(p: &LdaPartial) -> Option<LeaseToken> {
        p.lease
    }

    fn handoff_bytes(p: &LdaPartial) -> usize {
        p.handoff_bytes
    }
}

/// Helpers to build the initial partitioned state from a corpus.
pub mod setup {
    use super::*;
    use crate::backend::native::{NativeLdaShard, Token};
    use crate::datagen::Corpus;
    use crate::util::Rng;

    /// Partitioned LDA problem ready for the engine.
    pub struct LdaSetup {
        pub app: LdaApp,
        pub shards: Vec<Box<dyn LdaShard>>,
    }

    /// Build slices + worker shards from a corpus: documents are striped
    /// over workers, words are partitioned into U rotation slices by the
    /// frequency-weighted split
    /// ([`crate::scheduler::RotationScheduler::partition_words_by_freq`]
    /// — per-round compute tracks a slice's token mass, so the Zipf head
    /// must spread across slices), and initial topics are drawn uniformly.
    pub fn build(
        corpus: &Corpus,
        k: usize,
        n_workers: usize,
        alpha: f32,
        gamma: f32,
        seed: u64,
    ) -> LdaSetup {
        let u = n_workers;
        let v = corpus.vocab;
        assert!(v >= u, "vocab smaller than the slice count");
        let mut rng = Rng::new(seed);

        // frequency-aware word→slice map, plus slice-local indices
        let mut freqs = vec![0u64; v];
        for doc in &corpus.docs {
            for &w in doc {
                freqs[w as usize] += 1;
            }
        }
        let slice_of = RotationScheduler::partition_words_by_freq(&freqs, u);
        let mut local_of = vec![0u32; v];
        let mut word_map: Vec<Vec<u32>> = vec![Vec::new(); u];
        for w in 0..v {
            let a = slice_of[w];
            local_of[w] = word_map[a].len() as u32;
            word_map[a].push(w as u32);
        }

        // word-topic slices
        let mut slices: Vec<BSlice> = word_map
            .iter()
            .map(|words| BSlice {
                counts: vec![0.0; words.len() * k],
                n_words: words.len(),
            })
            .collect();
        let mut s = vec![0.0f32; k];

        // worker doc shards: doc d -> worker d % n_workers
        let mut per_worker_tokens: Vec<Vec<Vec<Token>>> =
            (0..n_workers).map(|_| vec![Vec::new(); u]).collect();
        let mut per_worker_docs = vec![0usize; n_workers];
        for (d, doc) in corpus.docs.iter().enumerate() {
            let p = d % n_workers;
            let local_doc = per_worker_docs[p];
            per_worker_docs[p] += 1;
            for &w in doc {
                let w = w as usize;
                let slice = slice_of[w];
                let word_local = local_of[w];
                let z = rng.below(k) as u32;
                slices[slice].counts[word_local as usize * k + z as usize] += 1.0;
                s[z as usize] += 1.0;
                per_worker_tokens[p][slice].push(Token {
                    doc: local_doc as u32,
                    word_local,
                    z,
                });
            }
        }

        let n_tokens = corpus.n_tokens();
        let mut app = LdaApp::new(
            LdaConfig {
                n_topics: k,
                vocab: v,
                n_workers,
                alpha,
                gamma,
            },
            slices,
            s,
            n_tokens,
        );
        app.set_word_map(word_map);
        let shards: Vec<Box<dyn LdaShard>> = per_worker_tokens
            .into_iter()
            .enumerate()
            .map(|(p, tokens)| {
                Box::new(NativeLdaShard::new(
                    tokens,
                    per_worker_docs[p].max(1),
                    k,
                    alpha,
                    gamma,
                    v,
                    seed ^ (p as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
                )) as Box<dyn LdaShard>
            })
            .collect();
        LdaSetup { app, shards }
    }
}

#[cfg(test)]
mod tests {
    use super::setup;
    use super::*;
    use crate::coordinator::{RunConfig, StradsEngine};
    use crate::datagen::lda_corpus::{self, CorpusConfig};

    fn engine(workers: usize, k: usize, seed: u64) -> StradsEngine<LdaApp> {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed,
            ..Default::default()
        });
        let s = setup::build(&corpus, k, workers, 0.1, 0.01, seed);
        StradsEngine::new(s.app, s.shards, &RunConfig::default())
    }

    #[test]
    fn gibbs_improves_loglik() {
        let mut e = engine(4, 8, 1);
        let ll0 = e.evaluate();
        for r in 0..20 {
            e.round(r);
        }
        let ll1 = e.evaluate();
        assert!(ll1 > ll0, "log-likelihood {ll0} -> {ll1}");
    }

    #[test]
    fn s_is_consistent_with_slices() {
        let mut e = engine(3, 6, 2);
        for r in 0..6 {
            e.round(r);
        }
        // s must equal the column sums over all slices
        let app = e.app();
        let k = app.n_topics();
        let mut sums = vec![0.0f32; k];
        for a in 0..app.slices.n_slices() {
            let sl = app.slices.peek(a).unwrap();
            for w in 0..sl.n_words {
                for kk in 0..k {
                    sums[kk] += sl.counts[w * k + kk];
                }
            }
        }
        for (a, b) in sums.iter().zip(app.s.iter()) {
            assert!((a - b).abs() < 1e-2, "{sums:?} vs {:?}", app.s);
        }
    }

    #[test]
    fn s_error_is_small_and_bounded() {
        let mut e = engine(4, 8, 3);
        for r in 0..10 {
            e.round(r);
        }
        for &d in &e.app().s_error_history {
            assert!((0.0..=2.0).contains(&d));
            // paper Fig 5: Δ_t tiny; generous bound here
            assert!(d < 0.1, "Δ_t = {d}");
        }
    }

    #[test]
    fn ssp_staleness_raises_s_error_but_conserves_counts() {
        let mut bsp = engine(4, 8, 6);
        let mut ssp = engine(4, 8, 6);
        ssp.app_mut().set_s_staleness(8);
        for r in 0..16 {
            bsp.round(r);
            ssp.round(r);
        }
        let e_bsp: f64 =
            bsp.app().s_error_history.iter().sum::<f64>() / 16.0;
        let e_ssp: f64 =
            ssp.app().s_error_history.iter().sum::<f64>() / 16.0;
        assert!(
            e_ssp > e_bsp,
            "staleness must raise mean s-error ({e_bsp} vs {e_ssp})"
        );
        let total: f32 = ssp.app().s.iter().sum();
        let total_bsp: f32 = bsp.app().s.iter().sum();
        assert!((total - total_bsp).abs() < 1e-2);
    }

    #[test]
    fn pipelined_rotation_runs_and_conserves_counts() {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed: 8,
            ..Default::default()
        });
        let s = setup::build(&corpus, 8, 4, 0.1, 0.01, 8);
        let cfg = RunConfig {
            max_rounds: 16,
            eval_every: 4,
            mode: crate::coordinator::ExecutionMode::Rotation { depth: 3 },
            label: "lda-rot".into(),
            ..Default::default()
        };
        let mut e = StradsEngine::new(s.app, s.shards, &cfg);
        let total0: f32 = e.app().s.iter().sum();
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, 16);
        let stats = res.ssp.expect("rotation run reports pipeline stats");
        assert!(stats.max_staleness() <= 2, "depth-3 bound");
        assert!(res.total_p2p_bytes > 0, "handoffs must ride p2p links");
        // slices are back in the store with advanced version chains
        let app = e.app();
        for a in 0..app.slices.n_slices() {
            assert!(app.slices.peek(a).is_some());
            assert_eq!(app.slices.version(a), 16);
        }
        let total1: f32 = app.s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
        // the run must actually learn
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective > first);
    }

    #[test]
    fn global_word_roundtrips_the_frequency_partition() {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 80,
            vocab: 300,
            doc_len_mean: 25,
            n_topics: 4,
            seed: 5,
            ..Default::default()
        });
        let s = setup::build(&corpus, 4, 3, 0.1, 0.01, 5);
        // every corpus word appears exactly once across the slice maps
        let mut seen = vec![false; corpus.vocab];
        for a in 0..s.app.n_workers() {
            let n_words = s.app.peek_slice(a).unwrap().n_words;
            for local in 0..n_words {
                let w = s.app.global_word(a, local);
                assert!(!seen[w], "word {w} mapped twice");
                seen[w] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn token_count_is_conserved() {
        let mut e = engine(2, 4, 4);
        let total0: f32 = e.app().s.iter().sum();
        for r in 0..8 {
            e.round(r);
        }
        let total1: f32 = e.app().s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
    }
}
