//! STRADS LDA (paper §3.1, pseudocode Fig 4).
//!
//! schedule: the rotation scheduler assigns each worker one word slice per
//!           round; the slice's word-topic block B_a is checked out of the
//!           kvstore and shipped with the task (its bytes dominate the
//!           round's traffic, exactly as in the paper's star topology).
//! push:     the worker Gibbs-sweeps its tokens whose words lie in the
//!           slice, mutating B_a and a *local* copy s̃ of the topic sums.
//! pull:     B slices are checked back in; the true s is rebuilt from the
//!           per-worker deltas; the s-error Δ (eq. 1) is measured here.
//! sync:     the fresh s ships with the next round's tasks (the paper syncs
//!           s at the end of every pull).

use crate::backend::LdaShard;
use crate::coordinator::StradsApp;
use crate::kvstore::SliceStore;
use crate::metrics::s_error;
use crate::scheduler::RotationScheduler;

/// Coordinator-side configuration.
pub struct LdaConfig {
    pub n_topics: usize,
    pub vocab: usize,
    pub n_workers: usize,
    pub alpha: f32,
    pub gamma: f32,
}

/// One word-topic slice: dense (slice_words × K) counts.
#[derive(Clone, Debug)]
pub struct BSlice {
    pub counts: Vec<f32>,
    pub n_words: usize,
}

/// Task for one worker: its slice assignment plus the slice data and the
/// freshly synced topic sums.
pub struct LdaTask {
    pub slice_id: usize,
    pub b_slice: BSlice,
    pub s: Vec<f32>,
}

/// Worker partial: the mutated slice, the worker's local s̃ (for the
/// s-error metric), the token count swept, and the number of distinct B
/// rows touched (KV-store traffic accounting).
pub struct LdaPartial {
    pub slice_id: usize,
    pub b_slice: BSlice,
    pub s_local: Vec<f32>,
    pub n_sampled: usize,
    pub touched_words: usize,
    pub n_topics: usize,
}

/// Coordinator state.
pub struct LdaApp {
    slices: SliceStore<BSlice>,
    /// True topic column sums s (K).
    pub s: Vec<f32>,
    sched: RotationScheduler,
    n_topics: usize,
    vocab: usize,
    n_workers: usize,
    alpha: f32,
    gamma: f32,
    n_tokens: usize,
    /// Δ_t from the most recent pull (paper eq. 1, Fig 5).
    pub last_s_error: f64,
    pub s_error_history: Vec<f64>,
    /// SSP-style extension (paper §5 future work): refresh the s snapshot
    /// shipped to workers only every `s_staleness` pulls.  1 = strict BSP
    /// (the paper's setting); larger values trade s-error for fewer syncs.
    s_staleness: u64,
    s_snapshot: Vec<f32>,
    pulls: u64,
}

impl LdaApp {
    /// `slices` are the initial word-topic blocks (one per worker; slice a
    /// holds words w with w % U == a, local index w / U); `s` their column
    /// sums; `n_tokens` the corpus token count (for Δ_t normalization).
    pub fn new(
        cfg: LdaConfig,
        slices: Vec<BSlice>,
        s: Vec<f32>,
        n_tokens: usize,
    ) -> Self {
        assert_eq!(slices.len(), cfg.n_workers);
        assert_eq!(s.len(), cfg.n_topics);
        LdaApp {
            sched: RotationScheduler::new(cfg.n_workers),
            slices: SliceStore::new(slices),
            s_snapshot: s.clone(),
            s,
            n_topics: cfg.n_topics,
            vocab: cfg.vocab,
            n_workers: cfg.n_workers,
            alpha: cfg.alpha,
            gamma: cfg.gamma,
            n_tokens,
            last_s_error: 0.0,
            s_error_history: Vec::new(),
            s_staleness: 1,
            pulls: 0,
        }
    }

    /// Enable the SSP-style sync relaxation: the s snapshot is refreshed
    /// only every `staleness` pulls (1 = strict BSP, the paper's mode).
    pub fn set_s_staleness(&mut self, staleness: u64) {
        assert!(staleness >= 1);
        self.s_staleness = staleness;
    }

    /// Word-topic log-likelihood term computed from the checked-in slices.
    fn word_loglik(&self) -> f64 {
        let k = self.n_topics;
        let vg = self.vocab as f64 * self.gamma as f64;
        let mut ll = 0.0f64;
        for a in 0..self.slices.n_slices() {
            let slice = self
                .slices
                .peek(a)
                .expect("all slices checked in at eval time");
            for w in 0..slice.n_words {
                for kk in 0..k {
                    let c = slice.counts[w * k + kk] as f64;
                    if c > 0.0 {
                        let phi = (c + self.gamma as f64)
                            / (self.s[kk] as f64 + vg);
                        ll += c * phi.ln();
                    }
                }
            }
        }
        ll
    }

    pub fn n_topics(&self) -> usize {
        self.n_topics
    }

    /// Read-only access to a checked-in word-topic slice (topic inspection,
    /// tests).  None while the slice is leased out to a worker.
    pub fn peek_slice(&self, slice_id: usize) -> Option<&BSlice> {
        self.slices.peek(slice_id)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }
}

impl StradsApp for LdaApp {
    type Task = LdaTask;
    type Partial = LdaPartial;
    type SyncMsg = Vec<f32>; // unused: s travels with tasks
    type WorkerState = Box<dyn LdaShard>;

    fn schedule(&mut self, _round: u64) -> Vec<LdaTask> {
        let assignment = self.sched.next_round();
        assignment
            .into_iter()
            .map(|slice_id| {
                let lease = self.slices.checkout(slice_id);
                LdaTask {
                    slice_id,
                    b_slice: lease.data,
                    s: self.s_snapshot.clone(),
                }
            })
            .collect()
    }

    fn push(ws: &mut Self::WorkerState, mut task: LdaTask) -> LdaPartial {
        let n_topics = task.s.len();
        let (s_local, n_sampled, touched_words) = ws.gibbs_slice(
            task.slice_id,
            &mut task.b_slice.counts,
            &task.s,
        );
        LdaPartial {
            slice_id: task.slice_id,
            b_slice: task.b_slice,
            s_local,
            n_sampled,
            touched_words,
            n_topics,
        }
    }

    fn pull(&mut self, _round: u64, partials: Vec<LdaPartial>) -> Option<Vec<f32>> {
        // rebuild the true s from per-worker deltas (slices are disjoint,
        // so deltas add); collect the stale local copies for Δ_t.  Deltas
        // are relative to the snapshot the workers were handed.
        let mut s_new = self.s.clone();
        let mut local_copies = Vec::with_capacity(partials.len());
        for part in partials {
            for k in 0..self.n_topics {
                s_new[k] += part.s_local[k] - self.s_snapshot[k];
            }
            local_copies.push(part.s_local.clone());
            // checkin: rebuild a lease-shaped return
            let lease = crate::kvstore::SliceLease {
                slice_id: part.slice_id,
                data: part.b_slice,
                version: self.slices.version(part.slice_id),
            };
            self.slices.checkin(lease);
        }
        self.last_s_error = s_error(&local_copies, &s_new, self.n_tokens);
        self.s_error_history.push(self.last_s_error);
        self.s = s_new;
        self.pulls += 1;
        if self.pulls % self.s_staleness == 0 {
            self.s_snapshot = self.s.clone(); // BSP refresh (sync)
        }
        None // s ships with the next round's tasks
    }

    fn sync(_ws: &mut Self::WorkerState, _msg: &Vec<f32>) {}

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        ws.doc_loglik()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        shard_sum + self.word_loglik()
    }

    fn minimizing() -> bool {
        false // maximize log-likelihood
    }

    fn task_bytes(t: &LdaTask) -> usize {
        // B rows are fetched lazily from the partitioned KV store as the
        // worker samples (charged in partial_bytes); the scheduled task
        // itself carries only the slice id and the synced s.
        t.s.len() * 4 + 8
    }

    fn partial_bytes(p: &LdaPartial) -> usize {
        // KV-store traffic for the round: each distinct word row touched is
        // fetched once and written back once (2×K×4 bytes), plus s̃.
        p.touched_words * p.n_topics * 4 * 2 + p.s_local.len() * 4 + 16
    }

    fn sync_bytes(m: &Vec<f32>) -> usize {
        m.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }

    fn p2p_payloads() -> bool {
        // the word-topic slices rotate between workers / are served by the
        // partitioned KV store — they never funnel through the scheduler
        // (the paper's star topology carries schedule metadata, not data)
        true
    }

    fn supports_ssp() -> bool {
        // rotation leases each word-topic slice to exactly one worker per
        // round; pipelining round t+1 before round t checks its slices
        // back in would double-lease.  The engine falls back to BSP.
        false
    }
}

/// Helpers to build the initial partitioned state from a corpus.
pub mod setup {
    use super::*;
    use crate::backend::native::{NativeLdaShard, Token};
    use crate::datagen::Corpus;
    use crate::util::Rng;

    /// Partitioned LDA problem ready for the engine.
    pub struct LdaSetup {
        pub app: LdaApp,
        pub shards: Vec<Box<dyn LdaShard>>,
    }

    /// Build slices + worker shards from a corpus: documents are striped
    /// over workers, words are partitioned into U rotation slices
    /// (w % U), and initial topics are drawn uniformly.
    pub fn build(
        corpus: &Corpus,
        k: usize,
        n_workers: usize,
        alpha: f32,
        gamma: f32,
        seed: u64,
    ) -> LdaSetup {
        let u = n_workers;
        let v = corpus.vocab;
        let slice_words = |a: usize| (v + u - 1 - a) / u; // words w: w%u==a
        let mut rng = Rng::new(seed);

        // word-topic slices
        let mut slices: Vec<BSlice> = (0..u)
            .map(|a| BSlice {
                counts: vec![0.0; slice_words(a) * k],
                n_words: slice_words(a),
            })
            .collect();
        let mut s = vec![0.0f32; k];

        // worker doc shards: doc d -> worker d % n_workers
        let mut per_worker_tokens: Vec<Vec<Vec<Token>>> =
            (0..n_workers).map(|_| vec![Vec::new(); u]).collect();
        let mut per_worker_docs = vec![0usize; n_workers];
        for (d, doc) in corpus.docs.iter().enumerate() {
            let p = d % n_workers;
            let local_doc = per_worker_docs[p];
            per_worker_docs[p] += 1;
            for &w in doc {
                let w = w as usize;
                let slice = w % u;
                let word_local = w / u;
                let z = rng.below(k) as u32;
                slices[slice].counts[word_local * k + z as usize] += 1.0;
                s[z as usize] += 1.0;
                per_worker_tokens[p][slice].push(Token {
                    doc: local_doc as u32,
                    word_local: word_local as u32,
                    z,
                });
            }
        }

        let n_tokens = corpus.n_tokens();
        let app = LdaApp::new(
            LdaConfig {
                n_topics: k,
                vocab: v,
                n_workers,
                alpha,
                gamma,
            },
            slices,
            s,
            n_tokens,
        );
        let shards: Vec<Box<dyn LdaShard>> = per_worker_tokens
            .into_iter()
            .enumerate()
            .map(|(p, tokens)| {
                Box::new(NativeLdaShard::new(
                    tokens,
                    per_worker_docs[p].max(1),
                    k,
                    alpha,
                    gamma,
                    v,
                    seed ^ (p as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
                )) as Box<dyn LdaShard>
            })
            .collect();
        LdaSetup { app, shards }
    }
}

#[cfg(test)]
mod tests {
    use super::setup;
    use super::*;
    use crate::coordinator::{RunConfig, StradsEngine};
    use crate::datagen::lda_corpus::{self, CorpusConfig};

    fn engine(workers: usize, k: usize, seed: u64) -> StradsEngine<LdaApp> {
        let corpus = lda_corpus::generate(&CorpusConfig {
            n_docs: 120,
            vocab: 400,
            doc_len_mean: 30,
            n_topics: 5,
            seed,
            ..Default::default()
        });
        let s = setup::build(&corpus, k, workers, 0.1, 0.01, seed);
        StradsEngine::new(s.app, s.shards, &RunConfig::default())
    }

    #[test]
    fn gibbs_improves_loglik() {
        let mut e = engine(4, 8, 1);
        let ll0 = e.evaluate();
        for r in 0..20 {
            e.round(r);
        }
        let ll1 = e.evaluate();
        assert!(ll1 > ll0, "log-likelihood {ll0} -> {ll1}");
    }

    #[test]
    fn s_is_consistent_with_slices() {
        let mut e = engine(3, 6, 2);
        for r in 0..6 {
            e.round(r);
        }
        // s must equal the column sums over all slices
        let app = e.app();
        let k = app.n_topics();
        let mut sums = vec![0.0f32; k];
        for a in 0..app.slices.n_slices() {
            let sl = app.slices.peek(a).unwrap();
            for w in 0..sl.n_words {
                for kk in 0..k {
                    sums[kk] += sl.counts[w * k + kk];
                }
            }
        }
        for (a, b) in sums.iter().zip(app.s.iter()) {
            assert!((a - b).abs() < 1e-2, "{sums:?} vs {:?}", app.s);
        }
    }

    #[test]
    fn s_error_is_small_and_bounded() {
        let mut e = engine(4, 8, 3);
        for r in 0..10 {
            e.round(r);
        }
        for &d in &e.app().s_error_history {
            assert!((0.0..=2.0).contains(&d));
            // paper Fig 5: Δ_t tiny; generous bound here
            assert!(d < 0.1, "Δ_t = {d}");
        }
    }

    #[test]
    fn ssp_staleness_raises_s_error_but_conserves_counts() {
        let mut bsp = engine(4, 8, 6);
        let mut ssp = engine(4, 8, 6);
        ssp.app_mut().set_s_staleness(8);
        for r in 0..16 {
            bsp.round(r);
            ssp.round(r);
        }
        let e_bsp: f64 =
            bsp.app().s_error_history.iter().sum::<f64>() / 16.0;
        let e_ssp: f64 =
            ssp.app().s_error_history.iter().sum::<f64>() / 16.0;
        assert!(
            e_ssp > e_bsp,
            "staleness must raise mean s-error ({e_bsp} vs {e_ssp})"
        );
        let total: f32 = ssp.app().s.iter().sum();
        let total_bsp: f32 = bsp.app().s.iter().sum();
        assert!((total - total_bsp).abs() < 1e-2);
    }

    #[test]
    fn token_count_is_conserved() {
        let mut e = engine(2, 4, 4);
        let total0: f32 = e.app().s.iter().sum();
        for r in 0..8 {
            e.round(r);
        }
        let total1: f32 = e.app().s.iter().sum();
        assert!((total0 - total1).abs() < 1e-2);
    }
}
