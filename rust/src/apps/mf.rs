//! STRADS Matrix Factorization (paper §3.2, pseudocode Fig 6).
//!
//! schedule: round-robin over (factor, rank-index) pairs.
//! push:     H rounds — workers return CCD stats (a_j, b_j) over their user
//!           row shards (g_1, g_2); W rounds — workers update their local W
//!           rows in closed form (no aggregation needed: W rows live with
//!           the data shard, exactly the paper's q_p partitioning).
//! pull:     H rounds — h_kj ← Σ_p a / (λ + Σ_p b) (g_3); broadcast row.
//! sync:     workers refresh their H copy + residuals.
//!
//! A second MF workload, [`MfBlockApp`], expresses the *block-rotation*
//! schedule (Gemulla et al.'s DSGD blocking on the same virtual ring as
//! LDA's word rotation): the item columns are over-decomposed into U ≥ P
//! disjoint [`HBlock`]s that rotate worker→worker, and each worker runs
//! SGD sweeps of its user-row shard against the blocks it currently
//! holds.  It reuses the rotation machinery wholesale —
//! [`crate::scheduler::RotationScheduler`] queues,
//! [`crate::kvstore::SliceRouter`] handoffs, [`LeaseLedger`] version
//! chains, [`crate::coordinator::HandoffLeg`] accounting — so the second
//! paper workload exercises the same multi-slice pipeline (and the
//! availability-ordered queue discipline) as LDA.

use crate::backend::MfShard;
use crate::cluster::{router_spin_ms, NetFaultPlan};
use crate::coordinator::{
    EffectiveConfig, HandoffLeg, RotationCaps, RunConfig, StradsApp,
};
use crate::kvstore::{
    LeaseLedger, LeaseToken, NetLinkStats, RouterError, SliceChecksum,
    SliceMass, SliceRouter, SliceStore,
};
use crate::scheduler::rotation::{
    self, GrantLeg, QueueOrder, RotationScheduler, SkipPolicy,
};
use crate::trace::{TraceBuffer, TracePlumbing, TraceReplayer};
use crate::scheduler::round_robin::{Factor, MfRound, RoundRobinScheduler};
use crate::sparse::CsrMatrix;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Coordinator-side configuration.
pub struct MfConfig {
    pub rank: usize,
    pub n_items: usize,
    pub lambda: f32,
    pub n_workers: usize,
}

/// Task broadcast each round.
#[derive(Clone, Debug)]
pub struct MfTask {
    pub round: MfRound,
    pub lambda: f32,
}

/// Worker partial.
#[derive(Debug)]
pub enum MfPartial {
    /// (a_j, b_j) sums for an H round.
    HStats(Vec<f32>, Vec<f32>),
    /// W rounds need no aggregation.
    WDone,
}

/// Sync broadcast: the committed H row.
#[derive(Clone, Debug)]
pub struct MfSync {
    pub k: usize,
    pub row: Vec<f32>,
}

/// Coordinator state: the item-factor matrix H and the schedule.
pub struct MfApp {
    /// H (rank × m), row-major — the shared model variables.
    pub h: Vec<f32>,
    rank: usize,
    n_items: usize,
    lambda: f32,
    n_workers: usize,
    sched: RoundRobinScheduler,
    /// Scheduled-but-unpulled rounds, keyed by engine round index (SSP
    /// keeps several in flight; BSP at most one).
    in_flight: HashMap<u64, MfRound>,
}

impl MfApp {
    pub fn new(cfg: MfConfig, h0: Vec<f32>) -> Self {
        assert_eq!(h0.len(), cfg.rank * cfg.n_items);
        MfApp {
            h: h0,
            rank: cfg.rank,
            n_items: cfg.n_items,
            lambda: cfg.lambda,
            n_workers: cfg.n_workers,
            sched: RoundRobinScheduler::new(cfg.rank),
            in_flight: HashMap::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Rounds for one full CCD sweep.
    pub fn rounds_per_sweep(&self) -> usize {
        self.sched.rounds_per_sweep()
    }
}

impl StradsApp for MfApp {
    type Task = MfTask;
    type Partial = MfPartial;
    type SyncMsg = MfSync;
    type WorkerState = Box<dyn MfShard>;

    fn schedule(&mut self, round: u64) -> Vec<MfTask> {
        let r = self.sched.next_round();
        self.in_flight.insert(round, r);
        (0..self.n_workers)
            .map(|_| MfTask { round: r, lambda: self.lambda })
            .collect()
    }

    fn push(ws: &mut Self::WorkerState, task: MfTask) -> MfPartial {
        match task.round.factor {
            Factor::H => {
                let (a, b) = ws.h_stats(task.round.k);
                MfPartial::HStats(a, b)
            }
            Factor::W => {
                ws.update_w(task.round.k);
                MfPartial::WDone
            }
        }
    }

    fn pull(&mut self, round: u64, partials: Vec<MfPartial>) -> Option<MfSync> {
        let round = self.in_flight.remove(&round).expect("pull without schedule");
        match round.factor {
            Factor::W => None, // W rows are shard-local; nothing to commit
            Factor::H => {
                let m = self.n_items;
                let mut a_sum = vec![0.0f32; m];
                let mut b_sum = vec![0.0f32; m];
                for p in partials {
                    if let MfPartial::HStats(a, b) = p {
                        for j in 0..m {
                            a_sum[j] += a[j];
                            b_sum[j] += b[j];
                        }
                    }
                }
                let k = round.k;
                let row: Vec<f32> = (0..m)
                    .map(|j| a_sum[j] / (self.lambda + b_sum[j]))
                    .collect();
                self.h[k * m..(k + 1) * m].copy_from_slice(&row);
                Some(MfSync { k, row })
            }
        }
    }

    fn sync(ws: &mut Self::WorkerState, msg: &MfSync) {
        ws.set_h_row(msg.k, &msg.row);
    }

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        // shard loss Σ r² + λ‖W_shard‖² (λ fixed at shard construction)
        ws.loss()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        let hreg: f64 = self.h.iter().map(|&x| (x as f64) * (x as f64)).sum();
        shard_sum + self.lambda as f64 * hreg
    }

    fn task_bytes(_: &MfTask) -> usize {
        16
    }

    fn partial_bytes(p: &MfPartial) -> usize {
        match p {
            MfPartial::HStats(a, b) => (a.len() + b.len()) * 4,
            MfPartial::WDone => 8,
        }
    }

    fn sync_bytes(m: &MfSync) -> usize {
        8 + m.row.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }
}

// ---------------------------------------------------------------------
// Block-rotation MF: U ≥ P item blocks on the LDA-style virtual ring
// ---------------------------------------------------------------------

/// One rotating block of the item-factor matrix H: the factor vectors of a
/// disjoint set of item columns, leased to exactly one worker per round.
#[derive(Clone, Debug)]
pub struct HBlock {
    /// Global item ids of this block's columns.
    pub cols: Vec<u32>,
    /// Factors, `cols.len() × rank` row-major (local column-major layout:
    /// the factor vector of `cols[c]` is `h[c*rank .. (c+1)*rank]`).
    pub h: Vec<f32>,
}

impl HBlock {
    /// Payload bytes a handoff of this block moves.
    pub fn bytes(&self) -> usize {
        self.cols.len() * 4 + self.h.len() * 4
    }
}

/// Column count as the sweep-cost proxy: the builder's nnz-balanced split
/// makes a block's rating mass track its column share, and the columns
/// are what an SGD block sweep iterates ([`QueueOrder::Dynamic`]'s
/// score).
impl SliceMass for HBlock {
    fn mass(&self) -> f64 {
        self.cols.len() as f64
    }
}

/// Content checksum for the lossy-transport envelope: both the column ids
/// and the factor bits participate, so a corrupted redelivery of either
/// half is detectable.
impl SliceChecksum for HBlock {
    fn checksum64(&self) -> u64 {
        self.cols.checksum64() ^ self.h.checksum64().rotate_left(17)
    }
}

/// Coordinator-side configuration for [`MfBlockApp`].
pub struct MfBlockConfig {
    pub rank: usize,
    pub n_items: usize,
    pub n_workers: usize,
    pub lambda: f32,
    /// Initial SGD step size.
    pub eta0: f32,
    /// Step decay: round `t` uses `eta0 / (1 + eta_decay·t)`.
    pub eta_decay: f32,
}

/// One leg of a worker's block-rotation round.
pub struct MfBlockTaskLeg {
    pub block_id: usize,
    /// BSP path: the checked-out block ships with the task.
    pub h_block: Option<HBlock>,
    /// Rotation-pipelined path: the lease version this leg consumes.
    pub version: Option<u64>,
    /// Worker that holds this block next round.
    pub dest_worker: usize,
}

/// Task for one worker: its block queue plus this round's SGD step.
pub struct MfBlockTask {
    pub legs: Vec<MfBlockTaskLeg>,
    pub eta: f32,
    pub router: Option<Arc<SliceRouter<HBlock>>>,
    /// Within-queue service discipline (see [`crate::apps::lda::LdaTask`]).
    pub order: QueueOrder,
}

/// One leg of a worker partial: mirrors [`MfBlockTaskLeg`] after the
/// sweep.
pub struct MfBlockPartialLeg {
    pub block_id: usize,
    pub h_block: Option<HBlock>,
    pub lease: Option<LeaseToken>,
    pub handoff_bytes: usize,
    pub dest_worker: usize,
    /// Rating updates applied in this leg (compute weight).
    pub n_updates: usize,
    /// Rotation path: the router arrival stamp of the handoff this leg
    /// consumed, read *before* the forward re-stamps the slot (0 under
    /// BSP).  Trace metadata only — excluded from fingerprints.
    pub arrival_seq: u64,
}

/// Worker partial: per-leg results in sweep order.
pub struct MfBlockPartial {
    pub legs: Vec<MfBlockPartialLeg>,
    /// Rotation path: a take deadline expired mid-sweep.  The sweep stops
    /// at the wedged leg (already-swept legs were forwarded and are
    /// reported above) and the engine recovers or aborts cleanly instead
    /// of panicking on a worker thread ([`StradsApp::partial_error`]).
    pub error: Option<RouterError>,
}

/// One worker's state for block-rotation MF: its user-row ratings shard,
/// its W rows (shard-local, exactly the paper's q_p partitioning), and a
/// full **H mirror** used only for objective evaluation.
///
/// Updates never read the mirror: SGD runs against the authoritative
/// routed block.  After sweeping a block the worker refreshes the
/// mirror's columns, so a mirror entry is at most U−1 rounds stale — an
/// SSP-style approximation that only touches the *reported* objective
/// (and vanishes as the factors converge), never the optimization path.
pub struct MfBlockShard {
    a: CsrMatrix,
    /// Local W rows (n_local × rank), row-major.
    pub w: Vec<f32>,
    /// Eval-only H mirror (n_items × rank, row per item).
    h_mirror: Vec<f32>,
    /// Global per-item rating counts (spreads the λ‖h_j‖ pull across the
    /// updates that touch column j, wherever they run).
    col_count: Vec<f32>,
    /// Per-local-row rating counts (same for the λ‖w_i‖ pull).
    row_count: Vec<f32>,
    rank: usize,
    lambda: f32,
    /// SGD passes over the shard×block ratings per leg.
    inner_sweeps: usize,
    /// Reusable global-item → block-local column map (`u32::MAX` =
    /// not in the current block).  Filled and reset per leg in
    /// O(block columns) — block composition is fixed for the run, so
    /// only the touched entries ever change.
    local_scratch: Vec<u32>,
}

impl MfBlockShard {
    pub fn new(
        a: CsrMatrix,
        w: Vec<f32>,
        h_mirror: Vec<f32>,
        col_count: Vec<f32>,
        rank: usize,
        lambda: f32,
        inner_sweeps: usize,
    ) -> Self {
        assert_eq!(w.len(), a.rows() * rank);
        assert_eq!(h_mirror.len(), a.cols() * rank);
        assert_eq!(col_count.len(), a.cols());
        assert!(inner_sweeps >= 1);
        let row_count: Vec<f32> =
            (0..a.rows()).map(|i| a.row_nnz(i).max(1) as f32).collect();
        let local_scratch = vec![u32::MAX; a.cols()];
        MfBlockShard {
            a,
            w,
            h_mirror,
            col_count,
            row_count,
            rank,
            lambda,
            inner_sweeps,
            local_scratch,
        }
    }

    /// SGD-sweep this shard's ratings whose items fall in `block`,
    /// mutating the block's factors and the local W rows in place, then
    /// refresh the eval mirror's columns.  Returns the number of rating
    /// updates applied (the leg's compute weight).
    pub fn sgd_block(&mut self, block: &mut HBlock, eta: f32) -> usize {
        let k = self.rank;
        // mark the block's columns in the persistent scratch map (reset
        // below, so fill + reset cost O(block columns), not O(items))
        for (c, &j) in block.cols.iter().enumerate() {
            self.local_scratch[j as usize] = c as u32;
        }
        let mut updates = 0usize;
        let mut wi_old = vec![0.0f32; k];
        for _ in 0..self.inner_sweeps {
            for i in 0..self.a.rows() {
                let (cols, vals) = self.a.row(i);
                for (&j, &aij) in cols.iter().zip(vals.iter()) {
                    let j = j as usize;
                    let c = self.local_scratch[j];
                    if c == u32::MAX {
                        continue;
                    }
                    let hj = c as usize * k;
                    let wi = i * k;
                    let mut pred = 0.0f32;
                    for r in 0..k {
                        pred += self.w[wi + r] * block.h[hj + r];
                    }
                    let e = aij - pred;
                    wi_old.copy_from_slice(&self.w[wi..wi + k]);
                    let wreg = self.lambda / self.row_count[i];
                    let hreg = self.lambda / self.col_count[j].max(1.0);
                    for r in 0..k {
                        self.w[wi + r] +=
                            eta * (e * block.h[hj + r] - wreg * wi_old[r]);
                        block.h[hj + r] +=
                            eta * (e * wi_old[r] - hreg * block.h[hj + r]);
                    }
                    updates += 1;
                }
            }
        }
        for (c, &j) in block.cols.iter().enumerate() {
            self.h_mirror[j as usize * k..(j as usize + 1) * k]
                .copy_from_slice(&block.h[c * k..(c + 1) * k]);
            self.local_scratch[j as usize] = u32::MAX; // reset for next leg
        }
        updates
    }

    /// Shard loss Σ (a_ij − w_i·h̃_j)² + λ‖W_shard‖² against the eval
    /// mirror.
    pub fn loss(&self) -> f64 {
        let k = self.rank;
        let mut sq = 0.0f64;
        for i in 0..self.a.rows() {
            for (j, aij) in self.a.row_iter(i) {
                let j = j as usize;
                let mut pred = 0.0f32;
                for r in 0..k {
                    pred += self.w[i * k + r] * self.h_mirror[j * k + r];
                }
                let e = (aij - pred) as f64;
                sq += e * e;
            }
        }
        let wreg: f64 =
            self.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        sq + self.lambda as f64 * wreg
    }
}

/// Coordinator state for block-rotation MF: the H blocks (leased via
/// [`SliceStore`] under BSP, a [`SliceRouter`] ring under pipelined
/// rotation), the rotation schedule, and the SGD step schedule.
pub struct MfBlockApp {
    blocks: SliceStore<HBlock>,
    router: Option<Arc<SliceRouter<HBlock>>>,
    ledger: LeaseLedger,
    sched: RotationScheduler,
    rank: usize,
    n_items: usize,
    n_workers: usize,
    n_blocks: usize,
    lambda: f32,
    eta0: f32,
    eta_decay: f32,
    /// Replay source: when set, `schedule` re-drives each worker's queue
    /// in the recorded sweep order and services it strictly (see
    /// [`TraceReplayer::reorder_legs`]).
    replay: Option<Arc<TraceReplayer>>,
}

impl MfBlockApp {
    /// `blocks` are the initial H blocks, U ≥ `cfg.n_workers` of them,
    /// jointly covering every item column exactly once.
    pub fn new(cfg: MfBlockConfig, blocks: Vec<HBlock>) -> Self {
        let n_blocks = blocks.len();
        assert!(
            n_blocks >= cfg.n_workers,
            "need at least one block per worker ({n_blocks} < {})",
            cfg.n_workers
        );
        let mut seen = vec![false; cfg.n_items];
        for b in &blocks {
            assert_eq!(b.h.len(), b.cols.len() * cfg.rank);
            for &j in &b.cols {
                assert!(!seen[j as usize], "item {j} in two blocks");
                seen[j as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "blocks must cover every item");
        MfBlockApp {
            sched: RotationScheduler::with_workers(n_blocks, cfg.n_workers),
            blocks: SliceStore::new(blocks),
            router: None,
            ledger: LeaseLedger::new(n_blocks),
            rank: cfg.rank,
            n_items: cfg.n_items,
            n_workers: cfg.n_workers,
            n_blocks,
            lambda: cfg.lambda,
            eta0: cfg.eta0,
            eta_decay: cfg.eta_decay,
            replay: None,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Read-only access to a checked-in block (tests, eval).
    pub fn peek_block(&self, block_id: usize) -> Option<&HBlock> {
        self.blocks.peek(block_id)
    }

    /// Install a skew-aware ring placement
    /// ([`rotation::skew_aware_placement`]); must precede round 0.
    pub fn set_ring_placement(&mut self, placement: Vec<usize>) {
        self.sched.set_placement(placement);
    }

    /// λ‖H‖² over the parked blocks (checked in under BSP, parked in the
    /// router between rotation rounds — the engine drains before eval).
    fn h_reg(&self) -> f64 {
        let mut reg = 0.0f64;
        for b in 0..self.n_blocks {
            let sum = |blk: &HBlock| -> f64 {
                blk.h.iter().map(|&x| (x as f64) * (x as f64)).sum()
            };
            reg += match &self.router {
                Some(router) => router.with_slice(b, |blk| {
                    sum(blk.expect("block parked in the router at eval time"))
                }),
                None => sum(self
                    .blocks
                    .peek(b)
                    .expect("all blocks checked in at eval time")),
            };
        }
        self.lambda as f64 * reg
    }
}

impl StradsApp for MfBlockApp {
    type Task = MfBlockTask;
    type Partial = MfBlockPartial;
    type SyncMsg = ();
    type WorkerState = MfBlockShard;

    fn schedule(&mut self, round: u64) -> Vec<MfBlockTask> {
        let u = self.n_blocks;
        let eta = self.eta0 / (1.0 + self.eta_decay * round as f32);
        // shared skip-capable availability signal; the default Never
        // path never reads it, so it skips the router polls entirely
        let grants = match self.sched.skip_policy() {
            SkipPolicy::Never => self.sched.next_round_grants(|_| true),
            SkipPolicy::Defer { .. } => {
                let avail = crate::kvstore::rotation_availability(
                    self.router.as_deref(),
                    &self.ledger,
                );
                self.sched.next_round_grants(|b| avail[b])
            }
        };
        let mut seen = vec![false; u];
        let mut tasks = Vec::with_capacity(grants.len());
        for (w, queue) in grants.into_iter().enumerate() {
            let mut legs = Vec::with_capacity(queue.len());
            for GrantLeg { slice_id: block_id, dest_worker } in queue {
                assert!(
                    !seen[block_id],
                    "block {block_id} assigned twice in one round"
                );
                seen[block_id] = true;
                let (h_block, version) = match &self.router {
                    Some(_) => (None, Some(self.ledger.grant(block_id))),
                    None => {
                        (Some(self.blocks.checkout(block_id).data), None)
                    }
                };
                legs.push(MfBlockTaskLeg {
                    block_id,
                    h_block,
                    version,
                    dest_worker,
                });
            }
            // replaying a recorded run: re-drive this queue in the
            // recorded sweep order and service it strictly, reproducing
            // the original take sequence bit-exactly
            let order = match &self.replay {
                Some(rep) if self.router.is_some() => {
                    legs = rep.reorder_legs(round, w, legs, |l| l.block_id);
                    QueueOrder::Strict
                }
                _ => self.sched.queue_order(),
            };
            tasks.push(MfBlockTask {
                legs,
                eta,
                router: self.router.as_ref().map(Arc::clone),
                order,
            });
        }
        tasks
    }

    fn push(ws: &mut MfBlockShard, task: MfBlockTask) -> MfBlockPartial {
        /// One routed leg once its block is in hand: sweep, forward,
        /// report the consumed lease.
        fn routed_leg(
            ws: &mut MfBlockShard,
            router: &SliceRouter<HBlock>,
            block_id: usize,
            dest_worker: usize,
            mut data: HBlock,
            consumed: u64,
            eta: f32,
        ) -> MfBlockPartialLeg {
            let n_updates = ws.sgd_block(&mut data, eta);
            let handoff_bytes = data.bytes();
            // arrival stamp of the consumed handoff, read before the
            // forward re-stamps the slot
            let arrival_seq = router.arrival_seq(block_id);
            router.forward(block_id, data, consumed + 1);
            MfBlockPartialLeg {
                block_id,
                h_block: None,
                lease: Some(LeaseToken { slice_id: block_id, version: consumed }),
                handoff_bytes,
                dest_worker,
                n_updates,
                arrival_seq,
            }
        }

        let MfBlockTask { legs, eta, router, order } = task;
        let mut out_legs = Vec::with_capacity(legs.len());

        // routed legs only (BSP legs carry their blocks): sweep whichever
        // granted block landed first ([`SliceRouter::take_earliest`],
        // Availability) or the heaviest parked one
        // ([`SliceRouter::take_heaviest`], Dynamic); see the LDA push
        // path for the shared contract
        if order != QueueOrder::Strict && router.is_some() {
            let router = router.as_ref().expect("checked is_some");
            let mut remaining = legs;
            let spin = Duration::from_millis(router_spin_ms());
            while !remaining.is_empty() {
                let grants: Vec<(usize, u64)> = remaining
                    .iter()
                    .map(|l| {
                        let version =
                            l.version.expect("reordered legs are routed");
                        (l.block_id, version)
                    })
                    .collect();
                let picked = match order {
                    QueueOrder::Dynamic => router.take_heaviest(&grants, spin),
                    _ => router.take_earliest(&grants, spin),
                };
                let (pick, data, consumed) = match picked {
                    Ok(t) => t,
                    Err(e) => {
                        // deadline expired with every remaining grant still
                        // parked — report the wedge instead of panicking;
                        // the engine recovers (lossy transport) or aborts
                        return MfBlockPartial {
                            legs: out_legs,
                            error: Some(e),
                        };
                    }
                };
                let leg = remaining.remove(pick);
                out_legs.push(routed_leg(
                    ws,
                    router,
                    leg.block_id,
                    leg.dest_worker,
                    data,
                    consumed,
                    eta,
                ));
            }
            return MfBlockPartial { legs: out_legs, error: None };
        }

        let mut error = None;
        for leg in legs {
            let MfBlockTaskLeg { block_id, h_block, version, dest_worker } =
                leg;
            match (&router, version, h_block) {
                (Some(router), Some(version), None) => {
                    let (data, consumed) = match router.take(block_id, version)
                    {
                        Ok(t) => t,
                        Err(e) => {
                            error = Some(e);
                            break;
                        }
                    };
                    out_legs.push(routed_leg(
                        ws, router, block_id, dest_worker, data, consumed,
                        eta,
                    ));
                }
                (None, None, Some(mut data)) => {
                    let n_updates = ws.sgd_block(&mut data, eta);
                    out_legs.push(MfBlockPartialLeg {
                        block_id,
                        h_block: Some(data),
                        lease: None,
                        handoff_bytes: 0,
                        dest_worker,
                        n_updates,
                        arrival_seq: 0,
                    });
                }
                _ => panic!("task leg mixes the BSP and routed forms"),
            }
        }
        MfBlockPartial { legs: out_legs, error }
    }

    fn pull(
        &mut self,
        _round: u64,
        partials: Vec<MfBlockPartial>,
    ) -> Option<()> {
        for part in partials {
            for leg in part.legs {
                match (leg.h_block, leg.lease) {
                    (Some(data), _) => {
                        let lease = crate::kvstore::SliceLease {
                            slice_id: leg.block_id,
                            data,
                            version: self.blocks.version(leg.block_id),
                        };
                        self.blocks.checkin(lease);
                    }
                    (None, Some(token)) => {
                        self.ledger.settle(&token).unwrap_or_else(|z| {
                            panic!("zombie settle in engine flow: {z:?}")
                        });
                    }
                    (None, None) => {
                        panic!("partial leg carries neither a block nor a lease")
                    }
                }
            }
        }
        None // H lives in the rotating blocks; nothing to broadcast
    }

    fn sync(_ws: &mut MfBlockShard, _msg: &()) {}

    fn eval(ws: &mut MfBlockShard) -> f64 {
        ws.loss()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        shard_sum + self.h_reg()
    }

    fn task_bytes(t: &MfBlockTask) -> usize {
        // BSP block payloads are charged on the partial side (one fetch +
        // one writeback per leg, like LDA's KV traffic); the task itself
        // carries scheduling metadata + the step size
        4 + 16 * t.legs.len().max(1)
    }

    fn partial_bytes(p: &MfBlockPartial) -> usize {
        let blocks: usize =
            p.legs.iter().filter_map(|l| l.h_block.as_ref()).map(HBlock::bytes).sum();
        if blocks > 0 {
            2 * blocks + 16
        } else {
            // rotation: only lease tokens ride the hub; block bytes are
            // charged as the p2p handoffs
            32 * p.legs.len().max(1)
        }
    }

    fn sync_bytes(_m: &()) -> usize {
        0
    }

    fn model_bytes(ws: &MfBlockShard) -> u64 {
        ((ws.w.len() + ws.h_mirror.len()) * 4) as u64
    }

    fn p2p_payloads() -> bool {
        // H blocks rotate between workers, never through the scheduler
        true
    }

    fn supports_ssp() -> bool {
        // blocks are exclusively leased: stale shared reads do not apply
        false
    }

    fn supports_rotation() -> bool {
        true
    }

    fn rotation_caps() -> RotationCaps {
        // reorder: the shard's W rows DO thread leg to leg (each sweep
        // reads the updates earlier legs made), but any within-queue
        // permutation is still a valid sequential SGD order — reordering
        // is legal; sweeping legs concurrently within a worker would not
        // be.  skip: grants route through next_round_grants with a live
        // parked-version signal, and a short (even empty) queue is just a
        // round with fewer SGD sweeps — W rows and the eval mirror need
        // no per-round completeness.
        // elastic: not yet wired — H blocks are coordinator-held like
        // LDA's slices, but the W shards are worker-resident, so a
        // membership change would strand a dead worker's W rows.
        // mh_sampler: an LDA-kernel knob — meaningless for CCD sweeps, so
        // a stray `--sampler mh` degrades to exact instead of lying.
        RotationCaps {
            queue_reorder: true,
            skip: true,
            elastic: false,
            mh_sampler: false,
        }
    }

    fn negotiate(&mut self, cfg: &RunConfig) -> EffectiveConfig {
        let eff = EffectiveConfig::negotiate(cfg, Self::rotation_caps());
        self.sched.set_queue_order(eff.queue_order);
        self.sched.set_skip_policy(eff.skip_policy);
        eff
    }

    fn install_trace(&mut self, plumbing: TracePlumbing) {
        self.replay = plumbing.replayer.clone();
        self.sched.install_trace(&plumbing);
    }

    fn n_rotation_slices(&self) -> usize {
        self.n_blocks
    }

    fn data_plane_block_secs(&self) -> f64 {
        // cumulative seconds workers physically parked on the handoff
        // ring (0.0 under BSP, where there is no router)
        self.router.as_ref().map(|r| r.block_secs()).unwrap_or(0.0)
    }

    fn partial_error(p: &MfBlockPartial) -> Option<RouterError> {
        p.error
    }

    fn install_net_faults(
        &mut self,
        plan: NetFaultPlan,
        sink: Option<Arc<TraceBuffer>>,
    ) {
        self.router
            .as_ref()
            .expect("net faults install after begin_rotation")
            .install_link(plan, sink);
    }

    fn net_stats(&self) -> NetLinkStats {
        self.router.as_ref().map(|r| r.net_stats()).unwrap_or_default()
    }

    fn recover_data_plane(&mut self) -> bool {
        // See [`crate::apps::lda::LdaApp`]: redeliver buffered
        // retransmits, then fence every chain at its settled head so only
        // uncompleted legs are re-granted.
        let router = self.router.as_ref().expect("rotation mode active");
        router.flush_all();
        self.ledger.recover_all();
        true
    }

    fn begin_rotation(&mut self, _depth: u64) {
        assert!(self.router.is_none(), "rotation mode already active");
        let router = Arc::new(SliceRouter::new(self.n_blocks));
        for b in 0..self.n_blocks {
            let lease = self.blocks.checkout(b);
            self.ledger.seed(b, lease.version);
            router.seed(b, lease.data, lease.version);
        }
        self.router = Some(router);
    }

    fn end_rotation(&mut self) {
        if let Some(router) = self.router.take() {
            for b in 0..router.n_slices() {
                let (data, version) = router.reclaim(b);
                self.blocks.restore(b, data, version);
            }
        }
    }

    fn task_leases(t: &MfBlockTask) -> Vec<LeaseToken> {
        t.legs
            .iter()
            .filter_map(|l| {
                l.version.map(|version| LeaseToken {
                    slice_id: l.block_id,
                    version,
                })
            })
            .collect()
    }

    fn partial_legs(p: &MfBlockPartial) -> Vec<HandoffLeg> {
        p.legs
            .iter()
            .filter_map(|l| {
                l.lease.map(|token| HandoffLeg {
                    token,
                    dest_worker: l.dest_worker,
                    bytes: l.handoff_bytes,
                    weight: l.n_updates as f64,
                    arrival_seq: l.arrival_seq,
                })
            })
            .collect()
    }
}

/// Builders for the block-rotation MF problem.
pub mod block_setup {
    use super::*;
    use crate::util::Rng;

    /// Knobs with the defaults the fig9 MF-rotation arm uses (validated
    /// against CCD convergence at bench scales).
    pub struct BlockSgdConfig {
        pub lambda: f32,
        pub eta0: f32,
        pub eta_decay: f32,
        pub inner_sweeps: usize,
    }

    impl Default for BlockSgdConfig {
        fn default() -> Self {
            BlockSgdConfig {
                lambda: 0.05,
                eta0: 0.3,
                eta_decay: 0.05,
                inner_sweeps: 3,
            }
        }
    }

    /// Block-rotation MF problem ready for the engine.
    pub struct MfBlockSetup {
        pub app: MfBlockApp,
        pub shards: Vec<MfBlockShard>,
    }

    /// Build U = `n_blocks` ≥ `n_workers` item blocks (nnz-balanced via
    /// the frequency-weighted split — per-leg compute tracks a block's
    /// rating mass) and per-worker user-row shards from a ratings matrix.
    /// Factor init mirrors the CCD builder's recipe (`seed ^ 0xF00D`,
    /// 1/√rank-scaled normals, H then per-shard W) so the two MF apps
    /// start from comparable objectives on the same data.  When
    /// `worker_speeds` is given, the ring placement is skew-aware on
    /// block rating mass.
    #[allow(clippy::too_many_arguments)]
    pub fn build_blocked(
        a: &CsrMatrix,
        rank: usize,
        n_workers: usize,
        n_blocks: usize,
        worker_speeds: Option<&[f64]>,
        sgd: &BlockSgdConfig,
        seed: u64,
    ) -> MfBlockSetup {
        let (users, m) = (a.rows(), a.cols());
        assert!(n_blocks >= n_workers, "fewer blocks than workers");
        assert!(m >= n_blocks, "fewer items than blocks");

        // per-item rating counts drive the nnz-balanced block split
        let mut col_nnz = vec![0u64; m];
        for i in 0..users {
            for (j, _) in a.row_iter(i) {
                col_nnz[j as usize] += 1;
            }
        }
        let block_of =
            RotationScheduler::partition_words_by_freq(&col_nnz, n_blocks);
        let mut cols_by_block: Vec<Vec<u32>> = vec![Vec::new(); n_blocks];
        for (j, &b) in block_of.iter().enumerate() {
            cols_by_block[b].push(j as u32);
        }

        // factor init, CCD-recipe order: H first, then per-shard W
        let mut rng = Rng::new(seed ^ 0xF00D);
        let scale = 1.0 / (rank as f32).sqrt();
        let h0: Vec<f32> =
            (0..rank * m).map(|_| rng.normal_f32() * scale).collect();
        let blocks: Vec<HBlock> = cols_by_block
            .iter()
            .map(|cols| {
                let mut h = Vec::with_capacity(cols.len() * rank);
                for &j in cols {
                    for r in 0..rank {
                        h.push(h0[r * m + j as usize]);
                    }
                }
                HBlock { cols: cols.clone(), h }
            })
            .collect();
        let mut mirror0 = vec![0.0f32; m * rank];
        for j in 0..m {
            for r in 0..rank {
                mirror0[j * rank + r] = h0[r * m + j];
            }
        }

        let mut app = MfBlockApp::new(
            MfBlockConfig {
                rank,
                n_items: m,
                n_workers,
                lambda: sgd.lambda,
                eta0: sgd.eta0,
                eta_decay: sgd.eta_decay,
            },
            blocks,
        );
        if let Some(speeds) = worker_speeds {
            let mut masses = vec![0u64; n_blocks];
            for (j, &b) in block_of.iter().enumerate() {
                masses[b] += col_nnz[j];
            }
            app.set_ring_placement(rotation::skew_aware_placement(
                &masses, speeds,
            ));
        }

        let col_count: Vec<f32> =
            col_nnz.iter().map(|&c| c.max(1) as f32).collect();
        let per = users / n_workers;
        let mut shards = Vec::with_capacity(n_workers);
        for p in 0..n_workers {
            let lo = p * per;
            let hi = if p == n_workers - 1 { users } else { lo + per };
            let shard = a.row_slice(lo, hi);
            let w0: Vec<f32> = (0..shard.rows() * rank)
                .map(|_| rng.normal_f32() * scale)
                .collect();
            shards.push(MfBlockShard::new(
                shard,
                w0,
                mirror0.clone(),
                col_count.clone(),
                rank,
                sgd.lambda,
                sgd.inner_sweeps,
            ));
        }
        MfBlockSetup { app, shards }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeMfShard;
    use crate::backend::MfShard;
    use crate::coordinator::{ExecutionMode, RunConfig, StradsEngine};
    use crate::datagen::mf_ratings::{self, MfGenConfig};
    use crate::util::Rng;

    fn build(
        users: usize,
        items: usize,
        rank: usize,
        workers: usize,
        seed: u64,
    ) -> StradsEngine<MfApp> {
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: users,
            n_items: items,
            density: 0.1,
            true_rank: 4,
            seed,
            ..Default::default()
        });
        let lambda = 0.05f32;
        let mut rng = Rng::new(seed ^ 0xABC);
        let scale = 1.0 / (rank as f32).sqrt();
        let h0: Vec<f32> = (0..rank * items)
            .map(|_| rng.normal_f32() * scale)
            .collect();
        let app = MfApp::new(
            MfConfig { rank, n_items: items, lambda, n_workers: workers },
            h0.clone(),
        );
        let per = users / workers;
        let mut states: Vec<Box<dyn MfShard>> = Vec::new();
        for p in 0..workers {
            let lo = p * per;
            let hi = if p == workers - 1 { users } else { lo + per };
            let shard = data.a.row_slice(lo, hi);
            let w0: Vec<f32> = (0..shard.rows() * rank)
                .map(|_| rng.normal_f32() * scale)
                .collect();
            states.push(Box::new(NativeMfShard::new(
                shard, w0, h0.clone(), rank, lambda,
            )));
        }
        StradsEngine::new(app, states, &RunConfig::default())
    }

    #[test]
    fn ccd_sweeps_reduce_objective() {
        let mut e = build(120, 80, 4, 3, 5);
        let start = e.evaluate();
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 5) {
            e.round(r);
        }
        let end = e.evaluate();
        assert!(end < 0.7 * start, "objective {start} -> {end}");
    }

    #[test]
    fn sharded_equals_single_worker() {
        let mut e1 = build(120, 80, 2, 1, 9);
        let mut e3 = build(120, 80, 2, 3, 9);
        let sweep = e1.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 3) {
            e1.round(r);
            e3.round(r);
        }
        let h1 = &e1.app().h;
        let h3 = &e3.app().h;
        let max_diff = h1
            .iter()
            .zip(h3.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "H divergence {max_diff}");
        let (o1, o3) = (e1.evaluate(), e3.evaluate());
        assert!(
            (o1 - o3).abs() / o1.abs().max(1e-9) < 1e-3,
            "objective {o1} vs {o3}"
        );
    }

    #[test]
    fn residuals_stay_consistent_with_factors() {
        // after arbitrary rounds, every worker's residual must equal
        // a_ij - w_i h_j recomputed from scratch — the incremental
        // maintenance in set_h_row/update_w must never drift
        let mut e = build(90, 60, 3, 3, 21);
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 2) {
            e.round(r);
        }
        // rebuild an identical engine and fast-forward H to compare loss
        // against a fresh residual recompute
        let obj_incremental = e.evaluate();
        assert!(obj_incremental.is_finite() && obj_incremental >= 0.0);
        // a second engine driven identically must land on the same value
        let mut e2 = build(90, 60, 3, 3, 21);
        for r in 0..(sweep * 2) {
            e2.round(r);
        }
        let obj2 = e2.evaluate();
        assert!(
            (obj_incremental - obj2).abs() < 1e-9,
            "{obj_incremental} vs {obj2}"
        );
    }

    #[test]
    fn every_rank_row_changes_after_full_sweep() {
        let mut e = build(90, 60, 4, 2, 33);
        let h0 = e.app().h.clone();
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..sweep {
            e.round(r);
        }
        let m = 60;
        for k in 0..4 {
            let changed = (0..m).any(|j| {
                (e.app().h[k * m + j] - h0[k * m + j]).abs() > 0.0
            });
            assert!(changed, "H row {k} untouched after a full sweep");
        }
    }

    #[test]
    fn pull_commits_h_rows() {
        let mut e = build(60, 40, 2, 2, 13);
        let h_before = e.app().h.clone();
        // round 0 is a W round, round 1 is the first H round
        e.round(0);
        assert_eq!(&e.app().h, &h_before, "W round must not touch H");
        e.round(1);
        assert_ne!(&e.app().h, &h_before, "H round must update a row");
    }

    // ---- block-rotation MF -------------------------------------------

    fn block_engine(
        users: usize,
        items: usize,
        rank: usize,
        workers: usize,
        blocks: usize,
        seed: u64,
        cfg: &RunConfig,
    ) -> StradsEngine<MfBlockApp> {
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: users,
            n_items: items,
            density: 0.08,
            true_rank: 4,
            seed,
            ..Default::default()
        });
        let speeds = vec![1.0; workers];
        let s = block_setup::build_blocked(
            &data.a,
            rank,
            workers,
            blocks,
            Some(&speeds),
            &block_setup::BlockSgdConfig::default(),
            seed,
        );
        StradsEngine::new(s.app, s.shards, cfg)
    }

    /// Every block's H, concatenated in block order (bit-exact state
    /// comparison across modes).
    fn all_block_factors(app: &MfBlockApp) -> Vec<f32> {
        (0..app.n_blocks())
            .flat_map(|b| {
                app.peek_block(b).expect("checked in").h.iter().copied()
            })
            .collect()
    }

    #[test]
    fn block_sgd_reduces_objective_under_bsp() {
        let cfg = RunConfig {
            max_rounds: 36,
            eval_every: 12,
            label: "mf-block-bsp".into(),
            ..Default::default()
        };
        let mut e = block_engine(90, 60, 4, 3, 6, 7, &cfg);
        let res = e.run(&cfg);
        let first = res.recorder.points()[0].objective;
        assert!(
            res.final_objective < 0.5 * first,
            "block SGD must cut the objective: {first} -> {}",
            res.final_objective
        );
    }

    #[test]
    fn block_rotation_depth1_matches_bsp_exactly() {
        // the SGD sweep is deterministic and the depth-1 router path
        // serializes into the same block order as the checkout/checkin
        // barrier, so objectives and the factor state must match
        // bit-exactly (the MF analog of the LDA depth-1 regression).
        let run = |mode: ExecutionMode| {
            let cfg = RunConfig {
                max_rounds: 12,
                eval_every: 4,
                mode,
                label: "mf-block-eq".into(),
                ..Default::default()
            };
            let mut e = block_engine(60, 40, 4, 2, 4, 17, &cfg);
            let res = e.run(&cfg);
            let objs: Vec<f64> = res
                .recorder
                .points()
                .iter()
                .map(|p| p.objective)
                .collect();
            (objs, all_block_factors(e.app()))
        };
        let (bsp_obj, bsp_h) = run(ExecutionMode::Bsp);
        let (rot_obj, rot_h) = run(ExecutionMode::Rotation { depth: 1 });
        assert_eq!(bsp_obj, rot_obj, "depth-1 must reproduce BSP objectives");
        assert_eq!(bsp_h, rot_h, "factor state must match bit-exactly");
    }

    #[test]
    fn block_rotation_pipelines_and_settles_chains() {
        let (workers, blocks) = (3usize, 6usize);
        let rounds = 18u64;
        let cfg = RunConfig {
            max_rounds: rounds,
            eval_every: 6,
            mode: ExecutionMode::Rotation { depth: 3 },
            straggler: crate::cluster::StragglerModel::Rotating {
                factor: 4.0,
            },
            label: "mf-block-rot".into(),
            ..Default::default()
        };
        let mut e = block_engine(90, 60, 4, workers, blocks, 23, &cfg);
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, rounds);
        let stats = res.ssp.expect("rotation run reports pipeline stats");
        assert!(stats.max_staleness() <= 2, "depth-3 bound");
        assert!(res.total_p2p_bytes > 0, "handoffs ride the p2p links");
        // every block forwarded once per round, minus free self-transfers
        assert!(
            res.total_p2p_msgs >= rounds * (blocks - workers) as u64,
            "only {} handoffs recorded",
            res.total_p2p_msgs
        );
        let app = e.app();
        for b in 0..app.n_blocks() {
            assert!(app.peek_block(b).is_some());
        }
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective < first, "the run must learn");
    }

    #[test]
    fn block_rotation_availability_order_runs_and_learns() {
        let cfg = RunConfig {
            max_rounds: 18,
            eval_every: 6,
            mode: ExecutionMode::Rotation { depth: 3 },
            queue_order: crate::coordinator::QueueOrder::Availability,
            handoff_jitter: crate::cluster::HandoffJitter::Jittered {
                base_frac: 0.2,
                jitter_frac: 1.5,
                seed: 5,
            },
            label: "mf-block-avail".into(),
            ..Default::default()
        };
        let mut e = block_engine(90, 60, 4, 3, 6, 29, &cfg);
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, 18);
        assert!(res.total_handoff_wait_secs >= 0.0);
        let first = res.recorder.points()[0].objective;
        assert!(res.final_objective < first, "the run must learn");
    }

    #[test]
    fn blocked_builder_covers_items_and_balances_nnz() {
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: 120,
            n_items: 80,
            density: 0.1,
            true_rank: 4,
            seed: 3,
            ..Default::default()
        });
        let s = block_setup::build_blocked(
            &data.a,
            4,
            3,
            6,
            None,
            &block_setup::BlockSgdConfig::default(),
            3,
        );
        // blocks partition the item set
        let mut seen = vec![false; 80];
        let mut nnz = vec![0usize; 6];
        let mut col_nnz = vec![0usize; 80];
        for i in 0..data.a.rows() {
            for (j, _) in data.a.row_iter(i) {
                col_nnz[j as usize] += 1;
            }
        }
        for b in 0..s.app.n_blocks() {
            let blk = s.app.peek_block(b).unwrap();
            assert_eq!(blk.h.len(), blk.cols.len() * 4);
            for &j in &blk.cols {
                assert!(!seen[j as usize], "item {j} in two blocks");
                seen[j as usize] = true;
                nnz[b] += col_nnz[j as usize];
            }
        }
        assert!(seen.iter().all(|&s| s), "blocks must cover every item");
        // the nnz-weighted split keeps block rating masses balanced
        let (mn, mx) =
            (*nnz.iter().min().unwrap(), *nnz.iter().max().unwrap());
        assert!(
            (mx as f64) <= 1.3 * (mn as f64).max(1.0),
            "block nnz imbalanced: {nnz:?}"
        );
        assert_eq!(s.shards.len(), 3);
    }
}
