//! STRADS Matrix Factorization (paper §3.2, pseudocode Fig 6).
//!
//! schedule: round-robin over (factor, rank-index) pairs.
//! push:     H rounds — workers return CCD stats (a_j, b_j) over their user
//!           row shards (g_1, g_2); W rounds — workers update their local W
//!           rows in closed form (no aggregation needed: W rows live with
//!           the data shard, exactly the paper's q_p partitioning).
//! pull:     H rounds — h_kj ← Σ_p a / (λ + Σ_p b) (g_3); broadcast row.
//! sync:     workers refresh their H copy + residuals.

use crate::backend::MfShard;
use crate::coordinator::StradsApp;
use crate::scheduler::round_robin::{Factor, MfRound, RoundRobinScheduler};
use std::collections::HashMap;

/// Coordinator-side configuration.
pub struct MfConfig {
    pub rank: usize,
    pub n_items: usize,
    pub lambda: f32,
    pub n_workers: usize,
}

/// Task broadcast each round.
#[derive(Clone, Debug)]
pub struct MfTask {
    pub round: MfRound,
    pub lambda: f32,
}

/// Worker partial.
#[derive(Debug)]
pub enum MfPartial {
    /// (a_j, b_j) sums for an H round.
    HStats(Vec<f32>, Vec<f32>),
    /// W rounds need no aggregation.
    WDone,
}

/// Sync broadcast: the committed H row.
#[derive(Clone, Debug)]
pub struct MfSync {
    pub k: usize,
    pub row: Vec<f32>,
}

/// Coordinator state: the item-factor matrix H and the schedule.
pub struct MfApp {
    /// H (rank × m), row-major — the shared model variables.
    pub h: Vec<f32>,
    rank: usize,
    n_items: usize,
    lambda: f32,
    n_workers: usize,
    sched: RoundRobinScheduler,
    /// Scheduled-but-unpulled rounds, keyed by engine round index (SSP
    /// keeps several in flight; BSP at most one).
    in_flight: HashMap<u64, MfRound>,
}

impl MfApp {
    pub fn new(cfg: MfConfig, h0: Vec<f32>) -> Self {
        assert_eq!(h0.len(), cfg.rank * cfg.n_items);
        MfApp {
            h: h0,
            rank: cfg.rank,
            n_items: cfg.n_items,
            lambda: cfg.lambda,
            n_workers: cfg.n_workers,
            sched: RoundRobinScheduler::new(cfg.rank),
            in_flight: HashMap::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Rounds for one full CCD sweep.
    pub fn rounds_per_sweep(&self) -> usize {
        self.sched.rounds_per_sweep()
    }
}

impl StradsApp for MfApp {
    type Task = MfTask;
    type Partial = MfPartial;
    type SyncMsg = MfSync;
    type WorkerState = Box<dyn MfShard>;

    fn schedule(&mut self, round: u64) -> Vec<MfTask> {
        let r = self.sched.next_round();
        self.in_flight.insert(round, r);
        (0..self.n_workers)
            .map(|_| MfTask { round: r, lambda: self.lambda })
            .collect()
    }

    fn push(ws: &mut Self::WorkerState, task: MfTask) -> MfPartial {
        match task.round.factor {
            Factor::H => {
                let (a, b) = ws.h_stats(task.round.k);
                MfPartial::HStats(a, b)
            }
            Factor::W => {
                ws.update_w(task.round.k);
                MfPartial::WDone
            }
        }
    }

    fn pull(&mut self, round: u64, partials: Vec<MfPartial>) -> Option<MfSync> {
        let round = self.in_flight.remove(&round).expect("pull without schedule");
        match round.factor {
            Factor::W => None, // W rows are shard-local; nothing to commit
            Factor::H => {
                let m = self.n_items;
                let mut a_sum = vec![0.0f32; m];
                let mut b_sum = vec![0.0f32; m];
                for p in partials {
                    if let MfPartial::HStats(a, b) = p {
                        for j in 0..m {
                            a_sum[j] += a[j];
                            b_sum[j] += b[j];
                        }
                    }
                }
                let k = round.k;
                let row: Vec<f32> = (0..m)
                    .map(|j| a_sum[j] / (self.lambda + b_sum[j]))
                    .collect();
                self.h[k * m..(k + 1) * m].copy_from_slice(&row);
                Some(MfSync { k, row })
            }
        }
    }

    fn sync(ws: &mut Self::WorkerState, msg: &MfSync) {
        ws.set_h_row(msg.k, &msg.row);
    }

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        // shard loss Σ r² + λ‖W_shard‖² (λ fixed at shard construction)
        ws.loss()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        let hreg: f64 = self.h.iter().map(|&x| (x as f64) * (x as f64)).sum();
        shard_sum + self.lambda as f64 * hreg
    }

    fn task_bytes(_: &MfTask) -> usize {
        16
    }

    fn partial_bytes(p: &MfPartial) -> usize {
        match p {
            MfPartial::HStats(a, b) => (a.len() + b.len()) * 4,
            MfPartial::WDone => 8,
        }
    }

    fn sync_bytes(m: &MfSync) -> usize {
        8 + m.row.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeMfShard;
    use crate::backend::MfShard;
    use crate::coordinator::{RunConfig, StradsEngine};
    use crate::datagen::mf_ratings::{self, MfGenConfig};
    use crate::util::Rng;

    fn build(
        users: usize,
        items: usize,
        rank: usize,
        workers: usize,
        seed: u64,
    ) -> StradsEngine<MfApp> {
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: users,
            n_items: items,
            density: 0.1,
            true_rank: 4,
            seed,
            ..Default::default()
        });
        let lambda = 0.05f32;
        let mut rng = Rng::new(seed ^ 0xABC);
        let scale = 1.0 / (rank as f32).sqrt();
        let h0: Vec<f32> = (0..rank * items)
            .map(|_| rng.normal_f32() * scale)
            .collect();
        let app = MfApp::new(
            MfConfig { rank, n_items: items, lambda, n_workers: workers },
            h0.clone(),
        );
        let per = users / workers;
        let mut states: Vec<Box<dyn MfShard>> = Vec::new();
        for p in 0..workers {
            let lo = p * per;
            let hi = if p == workers - 1 { users } else { lo + per };
            let shard = data.a.row_slice(lo, hi);
            let w0: Vec<f32> = (0..shard.rows() * rank)
                .map(|_| rng.normal_f32() * scale)
                .collect();
            states.push(Box::new(NativeMfShard::new(
                shard, w0, h0.clone(), rank, lambda,
            )));
        }
        StradsEngine::new(app, states, &RunConfig::default())
    }

    #[test]
    fn ccd_sweeps_reduce_objective() {
        let mut e = build(120, 80, 4, 3, 5);
        let start = e.evaluate();
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 5) {
            e.round(r);
        }
        let end = e.evaluate();
        assert!(end < 0.7 * start, "objective {start} -> {end}");
    }

    #[test]
    fn sharded_equals_single_worker() {
        let mut e1 = build(120, 80, 2, 1, 9);
        let mut e3 = build(120, 80, 2, 3, 9);
        let sweep = e1.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 3) {
            e1.round(r);
            e3.round(r);
        }
        let h1 = &e1.app().h;
        let h3 = &e3.app().h;
        let max_diff = h1
            .iter()
            .zip(h3.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "H divergence {max_diff}");
        let (o1, o3) = (e1.evaluate(), e3.evaluate());
        assert!(
            (o1 - o3).abs() / o1.abs().max(1e-9) < 1e-3,
            "objective {o1} vs {o3}"
        );
    }

    #[test]
    fn residuals_stay_consistent_with_factors() {
        // after arbitrary rounds, every worker's residual must equal
        // a_ij - w_i h_j recomputed from scratch — the incremental
        // maintenance in set_h_row/update_w must never drift
        let mut e = build(90, 60, 3, 3, 21);
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..(sweep * 2) {
            e.round(r);
        }
        // rebuild an identical engine and fast-forward H to compare loss
        // against a fresh residual recompute
        let obj_incremental = e.evaluate();
        assert!(obj_incremental.is_finite() && obj_incremental >= 0.0);
        // a second engine driven identically must land on the same value
        let mut e2 = build(90, 60, 3, 3, 21);
        for r in 0..(sweep * 2) {
            e2.round(r);
        }
        let obj2 = e2.evaluate();
        assert!(
            (obj_incremental - obj2).abs() < 1e-9,
            "{obj_incremental} vs {obj2}"
        );
    }

    #[test]
    fn every_rank_row_changes_after_full_sweep() {
        let mut e = build(90, 60, 4, 2, 33);
        let h0 = e.app().h.clone();
        let sweep = e.app().rounds_per_sweep() as u64;
        for r in 0..sweep {
            e.round(r);
        }
        let m = 60;
        for k in 0..4 {
            let changed = (0..m).any(|j| {
                (e.app().h[k * m + j] - h0[k * m + j]).abs() > 0.0
            });
            assert!(changed, "H row {k} untouched after a full sweep");
        }
    }

    #[test]
    fn pull_commits_h_rows() {
        let mut e = build(60, 40, 2, 2, 13);
        let h_before = e.app().h.clone();
        // round 0 is a W round, round 1 is the first H round
        e.round(0);
        assert_eq!(&e.app().h, &h_before, "W round must not touch H");
        e.round(1);
        assert_ne!(&e.app().h, &h_before, "H round must update a row");
    }
}
