//! STRADS Lasso (paper §3.3, pseudocode Fig 7).
//!
//! schedule: draw U′ candidates from c_j ∝ |δβ_j| + η, dependency-filter to
//!           B with pairwise |x_j^T x_k| < ρ (or uniform random for the
//!           Lasso-RR baseline).
//! push:     each worker returns z_{j,p} = (x_j^p)^T r^p + ‖x_j^p‖² β_j
//!           over its row shard (eq. 6, rewritten through the residual).
//! pull:     β_j ← S(Σ_p z_{j,p}, λ); broadcast deltas.
//! sync:     workers update residuals r ← r − X_sel δ.

use crate::backend::LassoShard;
use crate::coordinator::StradsApp;
use crate::scheduler::{PriorityScheduler, RandomScheduler};
use crate::sparse::CscMatrix;
use std::collections::HashMap;
use std::sync::Arc;

/// Scheduling policy for the Lasso app.
pub enum LassoSched {
    /// The paper's dynamic scheduler.
    Priority(PriorityScheduler),
    /// Uniform random (Lasso-RR / Shotgun baseline).
    Random(RandomScheduler),
}

/// Coordinator-side configuration.
pub struct LassoConfig {
    pub lambda: f32,
    pub n_workers: usize,
}

/// Task sent to every worker each round.
#[derive(Clone, Debug)]
pub struct LassoTask {
    pub sel: Vec<usize>,
    pub beta_sel: Vec<f32>,
}

/// Sync broadcast after pull.
#[derive(Clone, Debug)]
pub struct LassoSync {
    pub sel: Vec<usize>,
    pub delta: Vec<f32>,
}

/// The coordinator-side app state.
pub struct LassoApp {
    pub beta: Vec<f32>,
    lambda: f32,
    n_workers: usize,
    sched: LassoSched,
    /// Scheduler's view of the design matrix (for dependency checks; the
    /// paper grants `schedule` access to all data D).
    x_cols: Arc<CscMatrix>,
    /// Sets scheduled but not yet pulled, keyed by round: under SSP
    /// several rounds are in flight at once (BSP holds at most one entry).
    in_flight: HashMap<u64, Vec<usize>>,
    /// Running count of committed coefficient updates.
    pub updates_committed: u64,
}

impl LassoApp {
    pub fn new(
        x_cols: Arc<CscMatrix>,
        cfg: LassoConfig,
        sched: LassoSched,
    ) -> Self {
        let j = x_cols.cols();
        LassoApp {
            beta: vec![0.0; j],
            lambda: cfg.lambda,
            n_workers: cfg.n_workers,
            sched,
            x_cols,
            in_flight: HashMap::new(),
            updates_committed: 0,
        }
    }

    pub fn lambda(&self) -> f32 {
        self.lambda
    }

    /// Number of non-zero coefficients.
    pub fn nnz(&self) -> usize {
        self.beta.iter().filter(|&&b| b != 0.0).count()
    }

    fn soft_threshold(v: f32, lam: f32) -> f32 {
        if v > lam {
            v - lam
        } else if v < -lam {
            v + lam
        } else {
            0.0
        }
    }
}

impl StradsApp for LassoApp {
    type Task = LassoTask;
    type Partial = Vec<f32>;
    type SyncMsg = LassoSync;
    type WorkerState = Box<dyn LassoShard>;

    fn schedule(&mut self, round: u64) -> Vec<LassoTask> {
        let sel = match &mut self.sched {
            LassoSched::Priority(p) => p.next_set(&self.x_cols),
            LassoSched::Random(r) => r.next_set(),
        };
        // beta_sel ships the coordinator's current coefficients.  Under
        // SSP a coefficient redrawn while still in flight makes the z
        // partial mix a fresh beta_j with a staler residual — that error
        // is exactly what the bounded-staleness window limits.
        let beta_sel: Vec<f32> = sel.iter().map(|&j| self.beta[j]).collect();
        self.in_flight.insert(round, sel.clone());
        (0..self.n_workers)
            .map(|_| LassoTask { sel: sel.clone(), beta_sel: beta_sel.clone() })
            .collect()
    }

    fn push(ws: &mut Self::WorkerState, task: LassoTask) -> Vec<f32> {
        ws.partials(&task.sel, &task.beta_sel)
    }

    fn pull(&mut self, round: u64, partials: Vec<Vec<f32>>) -> Option<LassoSync> {
        let sel = self.in_flight.remove(&round).expect("pull without schedule");
        let u = sel.len();
        let mut z = vec![0.0f32; u];
        for p in &partials {
            debug_assert_eq!(p.len(), u);
            for (zi, pi) in z.iter_mut().zip(p.iter()) {
                *zi += pi;
            }
        }
        let mut delta = vec![0.0f32; u];
        for (i, &j) in sel.iter().enumerate() {
            let new = Self::soft_threshold(z[i], self.lambda);
            delta[i] = new - self.beta[j];
            if let LassoSched::Priority(p) = &mut self.sched {
                p.update_priority(j, delta[i].abs() as f64);
            }
            self.beta[j] = new;
            self.updates_committed += 1;
        }
        Some(LassoSync { sel, delta })
    }

    fn sync(ws: &mut Self::WorkerState, msg: &LassoSync) {
        ws.apply_delta(&msg.sel, &msg.delta);
    }

    fn eval(ws: &mut Self::WorkerState) -> f64 {
        ws.loss()
    }

    fn objective_from(&self, shard_sum: f64) -> f64 {
        let l1: f64 = self.beta.iter().map(|&b| b.abs() as f64).sum();
        shard_sum + self.lambda as f64 * l1
    }

    fn task_bytes(t: &LassoTask) -> usize {
        t.sel.len() * 8 + t.beta_sel.len() * 4
    }

    fn partial_bytes(p: &Vec<f32>) -> usize {
        p.len() * 4
    }

    fn sync_bytes(m: &LassoSync) -> usize {
        m.sel.len() * 8 + m.delta.len() * 4
    }

    fn model_bytes(ws: &Self::WorkerState) -> u64 {
        ws.model_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeLassoShard;
    use crate::coordinator::{RunConfig, StradsEngine};
    use crate::datagen::lasso_synth::{self, LassoGenConfig};
    use crate::scheduler::priority::PriorityConfig;

    fn build(
        n: usize,
        j: usize,
        workers: usize,
        priority: bool,
        lambda: f32,
    ) -> (StradsEngine<LassoApp>, Arc<CscMatrix>) {
        let prob = lasso_synth::generate(&LassoGenConfig {
            n_samples: n,
            n_features: j,
            seed: 7,
            ..Default::default()
        });
        let x = Arc::new(prob.x);
        let sched = if priority {
            LassoSched::Priority(PriorityScheduler::new(
                j,
                PriorityConfig::paper_defaults(8),
                11,
            ))
        } else {
            LassoSched::Random(RandomScheduler::new(j, 8, 11))
        };
        let app = LassoApp::new(
            x.clone(),
            LassoConfig { lambda, n_workers: workers },
            sched,
        );
        let per = n / workers;
        let mut states: Vec<Box<dyn LassoShard>> = Vec::new();
        for p in 0..workers {
            let lo = p * per;
            let hi = if p == workers - 1 { n } else { lo + per };
            states.push(Box::new(NativeLassoShard::new(
                x.row_slice(lo, hi),
                prob.y[lo..hi].to_vec(),
            )));
        }
        let cfg = RunConfig::default();
        (StradsEngine::new(app, states, &cfg), x)
    }

    #[test]
    fn objective_decreases_monotonically_priority() {
        let (mut e, _) = build(256, 512, 4, true, 0.05);
        let mut prev = e.evaluate();
        for r in 0..30 {
            e.round(r);
            let obj = e.evaluate();
            assert!(
                obj <= prev + 1e-4,
                "objective rose at round {r}: {prev} -> {obj}"
            );
            prev = obj;
        }
    }

    #[test]
    fn converges_toward_sparse_solution() {
        let (mut e, _) = build(256, 512, 4, true, 0.02);
        let start = e.evaluate();
        for r in 0..200 {
            e.round(r);
        }
        let end = e.evaluate();
        assert!(end < 0.6 * start, "objective {start} -> {end}");
        let nnz = e.app().nnz();
        assert!(nnz > 0 && nnz < 512, "nnz={nnz}");
    }

    #[test]
    fn priority_beats_random_in_overcomplete_regime() {
        // The paper's claim (§3.3, citing Bradley et al.): random parallel
        // CD fails in the presence of feature dependencies, while the
        // dependency-filtered dynamic schedule stays stable.  In the
        // overcomplete J >> n regime with U=16 concurrent updates, the
        // random scheduler co-updates correlated columns and diverges
        // (objective explodes / NaN); STRADS priority scheduling converges.
        use crate::figures::common::lasso_engine_corr;
        let cfg = crate::coordinator::RunConfig::default();
        let (mut ep, _) =
            lasso_engine_corr(128, 2048, 4, 16, true, 0.08, 0.9, 7, &cfg);
        let (mut er, _) =
            lasso_engine_corr(128, 2048, 4, 16, false, 0.08, 0.9, 7, &cfg);
        for r in 0..200 {
            ep.round(r);
            er.round(r);
        }
        let (op, orr) = (ep.evaluate(), er.evaluate());
        assert!(op.is_finite(), "priority must stay stable, got {op}");
        assert!(
            orr.is_nan() || op < orr,
            "priority {op} should beat random {orr}"
        );
        // and the margin should be decisive, not noise
        if orr.is_finite() {
            assert!(op < 0.5 * orr, "priority {op} vs random {orr}");
        }
    }

    #[test]
    fn sharded_equals_single_worker() {
        // the push/pull decomposition must not change the math
        let (mut e1, _) = build(256, 512, 1, false, 0.05);
        let (mut e4, _) = build(256, 512, 4, false, 0.05);
        for r in 0..50 {
            e1.round(r);
            e4.round(r);
        }
        // same scheduler seed => same update sequence => same beta
        let b1 = &e1.app().beta;
        let b4 = &e4.app().beta;
        let max_diff = b1
            .iter()
            .zip(b4.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "max beta divergence {max_diff}");
    }
}
