//! Native (pure-rust, sparse-aware) shard backends.  Mirrors the L1/L2
//! artifact math exactly — integration tests assert agreement with the
//! XLA path to float tolerance.

use super::{LassoShard, LdaShard, MfShard, SamplerKind};
use crate::sparse::{CscMatrix, CsrMatrix};
use crate::util::{AliasTable, Rng, Unwire, Wire};

// ------------------------------------------------------------- Lasso -----

/// One worker's row shard of the Lasso problem.
pub struct NativeLassoShard {
    /// Shard design matrix (rows = this worker's samples).
    pub x: CscMatrix,
    pub y: Vec<f32>,
    /// Residual r = y - X beta over this shard.
    r: Vec<f32>,
    /// Cached per-column squared norms over this shard.
    col_norms: Vec<f32>,
}

impl NativeLassoShard {
    pub fn new(x: CscMatrix, y: Vec<f32>) -> Self {
        assert_eq!(x.rows(), y.len());
        let col_norms = (0..x.cols()).map(|j| x.col_norm_sq(j)).collect();
        let r = y.clone(); // beta = 0 initially
        NativeLassoShard { x, y, r, col_norms }
    }

    pub fn residual(&self) -> &[f32] {
        &self.r
    }
}

impl LassoShard for NativeLassoShard {
    fn partials(&mut self, sel: &[usize], beta_sel: &[f32]) -> Vec<f32> {
        sel.iter()
            .zip(beta_sel.iter())
            .map(|(&j, &bj)| {
                self.x.col_dot_dense(j, &self.r) + self.col_norms[j] * bj
            })
            .collect()
    }

    fn apply_delta(&mut self, sel: &[usize], delta: &[f32]) {
        for (&j, &dj) in sel.iter().zip(delta.iter()) {
            if dj != 0.0 {
                self.x.col_axpy_dense(j, -dj, &mut self.r);
            }
        }
    }

    fn reset_residual(&mut self, beta: &[f32]) {
        let xb = self.x.matvec(beta);
        for (ri, (yi, xbi)) in
            self.r.iter_mut().zip(self.y.iter().zip(xb.iter()))
        {
            *ri = yi - xbi;
        }
    }

    fn loss(&self) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(&self.r)
    }

    fn model_bytes(&self) -> u64 {
        // residual + column-norm cache (model-adjacent state)
        (self.r.len() * 4 + self.col_norms.len() * 4) as u64
    }
}

// ---------------------------------------------------------------- MF -----

/// One worker's user-row shard of the MF problem.
pub struct NativeMfShard {
    /// Residuals r_ij stored in the shard's CSR values.
    resid: CsrMatrix,
    /// Local W rows (n_local × k), row-major.
    pub w: Vec<f32>,
    /// Local copy of H (k × m), row-major (synced by the engine).
    pub h: Vec<f32>,
    pub rank: usize,
    n_items: usize,
    lambda: f32,
}

impl NativeMfShard {
    /// Build from the shard's ratings and initial factors; initializes
    /// residuals r = a - w h over observed entries.
    pub fn new(
        a: CsrMatrix,
        w: Vec<f32>,
        h: Vec<f32>,
        rank: usize,
        lambda: f32,
    ) -> Self {
        let n_items = a.cols();
        assert_eq!(w.len(), a.rows() * rank);
        assert_eq!(h.len(), rank * n_items);
        let mut shard =
            NativeMfShard { resid: a, w, h, rank, n_items, lambda };
        shard.recompute_residuals();
        shard
    }

    fn recompute_residuals(&mut self) {
        let k = self.rank;
        let m = self.n_items;
        for i in 0..self.resid.rows() {
            let wi: Vec<f32> = self.w[i * k..(i + 1) * k].to_vec();
            for (pos, (j, v)) in
                self.resid.row(i).0.to_vec().into_iter().zip(
                    self.resid.row(i).1.to_vec().into_iter(),
                ).enumerate()
            {
                let mut pred = 0.0f32;
                for p in 0..k {
                    pred += wi[p] * self.h[p * m + j as usize];
                }
                self.resid.row_values_mut(i)[pos] = v - pred;
            }
        }
    }

    pub fn residual_view(&self) -> &CsrMatrix {
        &self.resid
    }
}

impl MfShard for NativeMfShard {
    fn h_stats(&mut self, k: usize) -> (Vec<f32>, Vec<f32>) {
        let m = self.n_items;
        let kk = self.rank;
        let mut a = vec![0.0f32; m];
        let mut b = vec![0.0f32; m];
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            if wik == 0.0 {
                continue;
            }
            let hk = &self.h[k * m..(k + 1) * m];
            let (cols, vals) = self.resid.row(i);
            for (j, r) in cols.iter().zip(vals.iter()) {
                let j = *j as usize;
                a[j] += (r + wik * hk[j]) * wik;
                b[j] += wik * wik;
            }
        }
        (a, b)
    }

    fn set_h_row(&mut self, k: usize, row: &[f32]) {
        let m = self.n_items;
        debug_assert_eq!(row.len(), m);
        // residual maintenance: r_ij -= w_ik (h'_kj - h_kj)
        let kk = self.rank;
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            if wik == 0.0 {
                continue;
            }
            let (cols, _) = self.resid.row(i);
            let cols = cols.to_vec();
            let vals = self.resid.row_values_mut(i);
            for (pos, j) in cols.iter().enumerate() {
                let j = *j as usize;
                vals[pos] -= wik * (row[j] - self.h[k * m + j]);
            }
        }
        self.h[k * m..(k + 1) * m].copy_from_slice(row);
    }

    fn update_w(&mut self, k: usize) {
        let m = self.n_items;
        let kk = self.rank;
        let hk: Vec<f32> = self.h[k * m..(k + 1) * m].to_vec();
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            let mut num = 0.0f32;
            let mut den = self.lambda;
            {
                let (cols, vals) = self.resid.row(i);
                for (j, r) in cols.iter().zip(vals.iter()) {
                    let h = hk[*j as usize];
                    num += (r + wik * h) * h;
                    den += h * h;
                }
            }
            let w_new = if den > 0.0 { num / den } else { 0.0 };
            let dw = w_new - wik;
            if dw != 0.0 {
                let (cols, _) = self.resid.row(i);
                let cols = cols.to_vec();
                let vals = self.resid.row_values_mut(i);
                for (pos, j) in cols.iter().enumerate() {
                    vals[pos] -= dw * hk[*j as usize];
                }
                self.w[i * kk + k] = w_new;
            }
        }
    }

    fn loss(&self) -> f64 {
        let mut sq = 0.0f64;
        for i in 0..self.resid.rows() {
            for (_, r) in self.resid.row_iter(i) {
                sq += (r as f64) * (r as f64);
            }
        }
        let wreg: f64 =
            self.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        sq + self.lambda as f64 * wreg
    }

    fn model_bytes(&self) -> u64 {
        // W shard + replicated H copy + residual values
        (self.w.len() * 4 + self.h.len() * 4 + self.resid.nnz() * 4) as u64
    }

    fn save_state(&self) -> Vec<u8> {
        // mutable state only: W, the local H copy, residual values (the
        // sparsity pattern and λ are immutable construction inputs)
        let mut wr = Wire::new();
        wr.put_f32s(&self.w);
        wr.put_f32s(&self.h);
        wr.put_u64(self.resid.rows() as u64);
        for i in 0..self.resid.rows() {
            wr.put_f32s(self.resid.row(i).1);
        }
        wr.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) {
        let mut r = Unwire::new(bytes);
        let w = r.f32s();
        assert_eq!(w.len(), self.w.len(), "checkpoint W shape mismatch");
        self.w = w;
        let h = r.f32s();
        assert_eq!(h.len(), self.h.len(), "checkpoint H shape mismatch");
        self.h = h;
        assert_eq!(
            r.u64() as usize,
            self.resid.rows(),
            "checkpoint residual row-count mismatch"
        );
        for i in 0..self.resid.rows() {
            let vals = r.f32s();
            let row = self.resid.row_values_mut(i);
            assert_eq!(vals.len(), row.len(), "checkpoint residual mismatch");
            row.copy_from_slice(&vals);
        }
        r.done();
    }
}

// --------------------------------------------------------------- LDA -----

/// A token with its current topic assignment.
#[derive(Debug, Clone, Copy)]
pub struct Token {
    /// Local document index within the shard.
    pub doc: u32,
    /// Local word index within the word slice.
    pub word_local: u32,
    pub z: u32,
}

/// Per-bucket CSR over `word_local` → token positions.  The doc/word
/// coordinates of a bucket never change (only `z` does), so this is
/// built once per bucket and reused for every MH sweep.
struct WordCsr {
    starts: Vec<u32>,
    positions: Vec<u32>,
}

/// doc → (bucket, position) of every token of that doc, across all
/// buckets, sorted by (bucket, position) within each doc.  Immutable
/// coordinates, built once on the first MH sweep.
struct DocIndex {
    starts: Vec<u32>,
    toks: Vec<(u32, u32)>,
}

/// One word's frozen proposal for the current sweep: the topics its
/// local tokens currently sit in, snapshot at the word's first visit,
/// with an alias table over count·stale_inv_s for O(1) draws.
struct WordProposal {
    /// Distinct topics, ascending (binary-searched by `count`).
    topics: Vec<u32>,
    /// Frozen per-topic counts (parallel to `topics`).
    counts: Vec<f32>,
    alias: AliasTable,
    /// Σ counts·stale_inv_s — the sparse component's mixture mass.
    mass: f32,
}

impl WordProposal {
    /// Frozen count at topic `kk` (0 when the word's snapshot has no
    /// local token there).
    fn count(&self, kk: usize) -> f32 {
        match self.topics.binary_search(&(kk as u32)) {
            Ok(i) => self.counts[i],
            Err(_) => 0.0,
        }
    }
}

/// Caches behind `--sampler mh` (LightLDA-style cycled word/doc
/// Metropolis–Hastings — see PAPERS.md).  Split by lifetime: the CSR /
/// doc indices depend only on immutable token coordinates and are built
/// once; the proposal tables are frozen per sweep (the slice lease is
/// the staleness boundary) and cleared on exit.
#[derive(Default)]
struct MhState {
    word_csr: Vec<Option<WordCsr>>,
    doc_index: Option<DocIndex>,
    /// Per-word frozen proposals for the sweep in progress (indexed by
    /// `word_local`; all entries are None between sweeps).
    word_props: Vec<Option<WordProposal>>,
    /// 1/(Vγ + s̃_k) frozen at sweep entry (proposals use the stale
    /// snapshot; acceptance uses the live `inv_s`).
    stale_inv_s: Vec<f32>,
    /// s̃ itself at sweep entry — the reverse-proposal correction needs
    /// the snapshot with the token's own contribution relocated.
    stale_s: Vec<f32>,
    /// Shared dense prior alias over γ·stale_inv_s and its total mass.
    prior_alias: AliasTable,
    prior_mass: f32,
    /// Snapshot-build scratch (k-sized counts + touched-topic list).
    count_scratch: Vec<f32>,
    topic_scratch: Vec<u32>,
}

/// Snapshot one word's local topic counts (all of its tokens in this
/// bucket, own token included) and freeze them into a `WordProposal`.
fn build_word_proposal(
    csr: &WordCsr,
    w: usize,
    bucket: &[Token],
    stale_inv_s: &[f32],
    count_scratch: &mut [f32],
    topic_scratch: &mut Vec<u32>,
) -> WordProposal {
    topic_scratch.clear();
    let lo = csr.starts[w] as usize;
    let hi = csr.starts[w + 1] as usize;
    for &pos in &csr.positions[lo..hi] {
        let z = bucket[pos as usize].z as usize;
        if count_scratch[z] == 0.0 {
            topic_scratch.push(z as u32);
        }
        count_scratch[z] += 1.0;
    }
    topic_scratch.sort_unstable();
    let topics: Vec<u32> = topic_scratch.clone();
    let counts: Vec<f32> =
        topics.iter().map(|&z| count_scratch[z as usize]).collect();
    let weights: Vec<f32> = topics
        .iter()
        .zip(&counts)
        .map(|(&z, &c)| c * stale_inv_s[z as usize])
        .collect();
    let mass = weights.iter().map(|&x| x as f64).sum::<f64>() as f32;
    for &z in &topics {
        count_scratch[z as usize] = 0.0;
    }
    WordProposal { topics, counts, alias: AliasTable::new(&weights), mass }
}

/// One worker's document shard: tokens bucketed by word slice.
pub struct NativeLdaShard {
    /// tokens[slice_id] — tokens whose word belongs to that rotation slice.
    tokens: Vec<Vec<Token>>,
    /// Doc-topic counts (n_docs_local × k), row-major f32.
    d_tab: Vec<f32>,
    /// Per-document token totals (for the doc log-likelihood).
    doc_totals: Vec<f32>,
    n_docs: usize,
    k: usize,
    alpha: f32,
    gamma: f32,
    v_global: usize,
    rng: Rng,
    /// Scratch for the conditional distribution.
    prob: Vec<f32>,
    /// Scratch bitmap for touched-word counting (perf: avoids a HashSet in
    /// the sampling loop — see EXPERIMENTS.md §Perf).
    touched_scratch: Vec<bool>,
    /// Scratch for 1/(Vγ + s̃_k): only 2 entries change per token, so the
    /// reciprocals are maintained incrementally instead of recomputed
    /// (removed K divisions/token — EXPERIMENTS.md §Perf).
    inv_s: Vec<f32>,
    /// Which sampling kernel `sweep` dispatches to (stamped per task by
    /// the app from the negotiated `RunConfig::sampler`).
    sampler: SamplerKind,
    /// MH-only caches; empty (and costing nothing) under `Exact`.
    mh: MhState,
}

impl NativeLdaShard {
    /// `tokens_by_slice[a]` lists this worker's tokens for slice a, with
    /// initial topic assignments already counted into `d_tab` by the
    /// caller... (no: we count here from the assignments).
    pub fn new(
        tokens_by_slice: Vec<Vec<Token>>,
        n_docs: usize,
        k: usize,
        alpha: f32,
        gamma: f32,
        v_global: usize,
        seed: u64,
    ) -> Self {
        let mut d_tab = vec![0.0f32; n_docs * k];
        let mut doc_totals = vec![0.0f32; n_docs];
        for bucket in &tokens_by_slice {
            for t in bucket {
                d_tab[t.doc as usize * k + t.z as usize] += 1.0;
                doc_totals[t.doc as usize] += 1.0;
            }
        }
        NativeLdaShard {
            tokens: tokens_by_slice,
            d_tab,
            doc_totals,
            n_docs,
            k,
            alpha,
            gamma,
            v_global,
            rng: Rng::new(seed),
            prob: vec![0.0f32; k],
            touched_scratch: Vec::new(),
            inv_s: vec![0.0f32; k],
            sampler: SamplerKind::default(),
            mh: MhState::default(),
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.iter().map(|b| b.len()).sum()
    }

    pub fn d_tab(&self) -> &[f32] {
        &self.d_tab
    }

    /// Tokens in one slice bucket (XLA staging).
    pub fn bucket(&self, slice_id: usize) -> &[Token] {
        &self.tokens[slice_id]
    }

    pub fn bucket_mut(&mut self, slice_id: usize) -> &mut Vec<Token> {
        &mut self.tokens[slice_id]
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.n_docs, self.k)
    }

    /// The shared Gibbs-sweep core: samples every token of the slice
    /// in place, maintaining `s_local` (the worker's running local topic
    /// sums) directly in the caller's buffer.  Both `gibbs_slice` (which
    /// copies `s` first) and the allocation-free `gibbs_slice_into` funnel
    /// here, so the RNG sequence is identical by construction.
    fn sweep_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_local: &mut [f32],
    ) -> (usize, usize) {
        let k = self.k;
        let vgamma = self.v_global as f32 * self.gamma;
        // tokens mutated in place; slice words tracked in a reusable bitmap
        // (HashSet insertion was ~30% of the sweep — EXPERIMENTS.md §Perf)
        let n_slice_words = b_slice.len() / k;
        if self.touched_scratch.len() < n_slice_words {
            self.touched_scratch.resize(n_slice_words, false);
        }
        let mut n_touched = 0usize;
        let mut bucket = std::mem::take(&mut self.tokens[slice_id]);
        let n = bucket.len();
        // reciprocal table maintained incrementally (2 updates/token)
        for kk in 0..k {
            self.inv_s[kk] = 1.0 / (vgamma + s_local[kk]);
        }
        for t in bucket.iter_mut() {
            let w = t.word_local as usize;
            if !self.touched_scratch[w] {
                self.touched_scratch[w] = true;
                n_touched += 1;
            }
            let drow = t.doc as usize * k;
            let brow = w * k;
            let zi = t.z as usize;
            self.d_tab[drow + zi] -= 1.0;
            b_slice[brow + zi] -= 1.0;
            s_local[zi] -= 1.0;
            self.inv_s[zi] = 1.0 / (vgamma + s_local[zi]);
            // conditional: (γ+B)·inv_s·(α+D), fused into a running CDF
            let mut total = 0.0f32;
            let d_row = &self.d_tab[drow..drow + k];
            let b_row = &b_slice[brow..brow + k];
            for kk in 0..k {
                let p = (self.gamma + b_row[kk]) * self.inv_s[kk]
                    * (self.alpha + d_row[kk]);
                total += p;
                self.prob[kk] = total;
            }
            let u = self.rng.next_f32() * total;
            // inverse CDF (linear scan; K is small at our scales)
            let mut z_new = k - 1;
            for (kk, &c) in self.prob.iter().enumerate() {
                if u < c {
                    z_new = kk;
                    break;
                }
            }
            self.d_tab[drow + z_new] += 1.0;
            b_slice[brow + z_new] += 1.0;
            s_local[z_new] += 1.0;
            self.inv_s[z_new] = 1.0 / (vgamma + s_local[z_new]);
            t.z = z_new as u32;
        }
        // reset only the bits we set (bitmap reuse across calls)
        for t in bucket.iter() {
            self.touched_scratch[t.word_local as usize] = false;
        }
        self.tokens[slice_id] = bucket;
        (n, n_touched)
    }

    /// Kernel dispatch: both `gibbs_slice` and `gibbs_slice_into` funnel
    /// here.  Within a kernel the RNG sequence is identical across the
    /// two entry points (the sim-vs-threads contract); across kernels
    /// the sequences differ by design — mh is a different chain with
    /// the same stationary distribution.
    fn sweep(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_local: &mut [f32],
    ) -> (usize, usize) {
        match self.sampler {
            SamplerKind::Exact => {
                self.sweep_slice(slice_id, b_slice, s_local)
            }
            SamplerKind::Mh => {
                self.sweep_slice_mh(slice_id, b_slice, s_local)
            }
        }
    }

    /// Build the coordinate indices the MH kernel draws through: the
    /// per-bucket word→positions CSR and the doc→tokens index.  Both
    /// depend only on immutable (doc, word) coordinates, so each is
    /// built exactly once per shard lifetime, lazily on first MH use.
    fn ensure_mh_indices(&mut self, slice_id: usize, n_slice_words: usize) {
        if self.mh.word_csr.len() <= slice_id {
            self.mh.word_csr.resize_with(slice_id + 1, || None);
        }
        if self.mh.word_csr[slice_id].is_none() {
            let bucket = &self.tokens[slice_id];
            let mut starts = vec![0u32; n_slice_words + 1];
            for t in bucket {
                starts[t.word_local as usize + 1] += 1;
            }
            for i in 0..n_slice_words {
                starts[i + 1] += starts[i];
            }
            let mut cursor = starts.clone();
            let mut positions = vec![0u32; bucket.len()];
            for (pos, t) in bucket.iter().enumerate() {
                let w = t.word_local as usize;
                positions[cursor[w] as usize] = pos as u32;
                cursor[w] += 1;
            }
            self.mh.word_csr[slice_id] = Some(WordCsr { starts, positions });
        }
        if self.mh.doc_index.is_none() {
            let mut starts = vec![0u32; self.n_docs + 1];
            for b in &self.tokens {
                for t in b {
                    starts[t.doc as usize + 1] += 1;
                }
            }
            for d in 0..self.n_docs {
                starts[d + 1] += starts[d];
            }
            let mut cursor = starts.clone();
            let mut toks = vec![(0u32, 0u32); starts[self.n_docs] as usize];
            // bucket-ascending, position-ascending: each doc's range ends
            // up sorted by (bucket, position), so a token finds its own
            // entry by binary search
            for (bi, b) in self.tokens.iter().enumerate() {
                for (pos, t) in b.iter().enumerate() {
                    let d = t.doc as usize;
                    toks[cursor[d] as usize] = (bi as u32, pos as u32);
                    cursor[d] += 1;
                }
            }
            self.mh.doc_index = Some(DocIndex { starts, toks });
        }
    }

    /// The `--sampler mh` sweep: LightLDA-style cycled word-proposal +
    /// doc-proposal Metropolis–Hastings, amortized O(1) per token in K.
    ///
    /// Per token (target p̂(k) ∝ (γ+B_wk)·(α+D_dk)/(Vγ+s̃_k), counts
    /// live and token-decremented, exactly as the exact kernel):
    ///
    /// 1. **Word step** — propose from the word's frozen snapshot (its
    ///    local tokens' topics at first visit this sweep, alias-encoded
    ///    with weights count·stale_inv_s) mixed with a sweep-shared
    ///    dense prior alias over γ·stale_inv_s.  The snapshot includes
    ///    the token's own assignment, so the Hastings ratio subtracts
    ///    one from the reverse side's count at `cur` and shifts the
    ///    reverse normalizer by the self-weight difference — without
    ///    those corrections the kernel is biased for rare words, where
    ///    the token's own count dominates its proposal.
    /// 2. **Doc step** — propose a uniformly chosen *other* token of
    ///    the doc and adopt its current topic (probability ∝ D_dk with
    ///    the current token excluded), mixed with an α·K uniform part.
    ///    Reading live assignments makes q̂_d(k) = D_dk + α exactly —
    ///    no alias table, no staleness, plain independence MH.
    ///
    /// Proposals are evaluated against stale tables but corrected by
    /// acceptance against the live ones, so the stationary distribution
    /// is the same collapsed posterior the exact kernel samples.  (The
    /// within-sweep freeze makes later tokens of a word see a snapshot
    /// taken before earlier tokens moved — the standard LightLDA
    /// staleness, independent of the resampled token's own state and
    /// corrected by the same ratio.)
    fn sweep_slice_mh(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_local: &mut [f32],
    ) -> (usize, usize) {
        let k = self.k;
        let alpha = self.alpha;
        let gamma = self.gamma;
        let vgamma = self.v_global as f32 * self.gamma;
        let n_slice_words = b_slice.len() / k;
        if self.touched_scratch.len() < n_slice_words {
            self.touched_scratch.resize(n_slice_words, false);
        }
        self.ensure_mh_indices(slice_id, n_slice_words);
        // live reciprocal table, maintained incrementally as in the
        // exact sweep (acceptance evaluates the live target)
        for kk in 0..k {
            self.inv_s[kk] = 1.0 / (vgamma + s_local[kk]);
        }
        let mh = &mut self.mh;
        // freeze the sweep-shared pieces: the stale reciprocal snapshot
        // and the dense prior alias over γ·stale_inv_s — one O(K) build
        // amortized over every token in the leg
        mh.stale_inv_s.clear();
        mh.stale_inv_s.extend_from_slice(&self.inv_s);
        mh.stale_s.clear();
        mh.stale_s.extend_from_slice(s_local);
        let prior_weights: Vec<f32> =
            mh.stale_inv_s.iter().map(|&v| gamma * v).collect();
        mh.prior_alias = AliasTable::new(&prior_weights);
        mh.prior_mass =
            prior_weights.iter().map(|&w| w as f64).sum::<f64>() as f32;
        if mh.word_props.len() < n_slice_words {
            mh.word_props.resize_with(n_slice_words, || None);
        }
        if mh.count_scratch.len() < k {
            mh.count_scratch.resize(k, 0.0);
        }
        let mut n_touched = 0usize;
        let mut bucket = std::mem::take(&mut self.tokens[slice_id]);
        let n = bucket.len();
        for i in 0..n {
            let t = bucket[i];
            let w = t.word_local as usize;
            if !self.touched_scratch[w] {
                self.touched_scratch[w] = true;
                n_touched += 1;
            }
            let drow = t.doc as usize * k;
            let brow = w * k;
            let s_old = t.z as usize;
            self.d_tab[drow + s_old] -= 1.0;
            b_slice[brow + s_old] -= 1.0;
            s_local[s_old] -= 1.0;
            self.inv_s[s_old] = 1.0 / (vgamma + s_local[s_old]);
            if mh.word_props[w].is_none() {
                let csr = mh.word_csr[slice_id]
                    .as_ref()
                    .expect("word CSR built by ensure_mh_indices");
                mh.word_props[w] = Some(build_word_proposal(
                    csr,
                    w,
                    &bucket,
                    &mh.stale_inv_s,
                    &mut mh.count_scratch,
                    &mut mh.topic_scratch,
                ));
            }
            let mut cur = s_old;
            // ---- word-proposal MH step ----
            {
                let wp = mh.word_props[w].as_ref().unwrap();
                let total = wp.mass + mh.prior_mass;
                let pick = self.rng.next_f32() * total;
                let t_prop = if pick < wp.mass {
                    wp.topics[wp.alias.draw(&mut self.rng)] as usize
                } else {
                    mh.prior_alias.draw(&mut self.rng)
                };
                if t_prop != cur {
                    let p_cur = (gamma + b_slice[brow + cur])
                        * self.inv_s[cur]
                        * (alpha + self.d_tab[drow + cur]);
                    let p_new = (gamma + b_slice[brow + t_prop])
                        * self.inv_s[t_prop]
                        * (alpha + self.d_tab[drow + t_prop]);
                    // Hastings correction with the token's own snapshot
                    // contribution relocated from `cur` to the proposal:
                    // the reverse mechanism would have frozen m−e_cur+e_t
                    // and s̃−e_cur+e_t, so both its weights at {cur, t}
                    // and its normalizer shift (all O(1)).  Without this
                    // the kernel is biased for rare words, where the
                    // token's own contribution dominates its proposal.
                    let vg = vgamma as f64;
                    let m_cur = wp.count(cur) as f64;
                    let m_new = wp.count(t_prop) as f64;
                    let inv_cur = mh.stale_inv_s[cur] as f64;
                    let inv_new = mh.stale_inv_s[t_prop] as f64;
                    let inv_r_cur =
                        1.0 / (vg + mh.stale_s[cur] as f64 - 1.0);
                    let inv_r_new =
                        1.0 / (vg + mh.stale_s[t_prop] as f64 + 1.0);
                    let g = gamma as f64;
                    let w_fwd_cur = (m_cur + g) * inv_cur;
                    let w_fwd_new = (m_new + g) * inv_new;
                    let w_rev_cur = (m_cur - 1.0 + g) * inv_r_cur;
                    let w_rev_new = (m_new + 1.0 + g) * inv_r_new;
                    let z_fwd = total as f64;
                    let z_rev = z_fwd - w_fwd_cur - w_fwd_new
                        + w_rev_cur
                        + w_rev_new;
                    let accept = (p_new as f64 * w_rev_cur * z_fwd)
                        / (p_cur as f64 * w_fwd_new * z_rev);
                    if (self.rng.next_f32() as f64) < accept {
                        cur = t_prop;
                    }
                }
            }
            // ---- doc-proposal MH step ----
            {
                let di = mh
                    .doc_index
                    .as_ref()
                    .expect("doc index built by ensure_mh_indices");
                let d = t.doc as usize;
                let lo = di.starts[d] as usize;
                let hi = di.starts[d + 1] as usize;
                let n_others = (hi - lo - 1) as f32;
                let total = n_others + alpha * k as f32;
                let pick = self.rng.next_f32() * total;
                let t_prop = if pick < n_others {
                    // uniform over the doc's *other* tokens: skip our
                    // own entry so q̂_d(k) = D_dk + α exactly (D_dk
                    // excludes this token; every other stored z agrees
                    // with the live table)
                    let own = di.toks[lo..hi]
                        .binary_search(&(slice_id as u32, i as u32))
                        .expect("token missing from its doc index");
                    let mut j =
                        self.rng.below((hi - lo - 1) as u64) as usize;
                    if j >= own {
                        j += 1;
                    }
                    let (b_id, pos) = di.toks[lo + j];
                    let z = if b_id as usize == slice_id {
                        bucket[pos as usize].z
                    } else {
                        self.tokens[b_id as usize][pos as usize].z
                    };
                    z as usize
                } else {
                    self.rng.below(k as u64) as usize
                };
                if t_prop != cur {
                    let p_cur = (gamma + b_slice[brow + cur])
                        * self.inv_s[cur]
                        * (alpha + self.d_tab[drow + cur]);
                    let p_new = (gamma + b_slice[brow + t_prop])
                        * self.inv_s[t_prop]
                        * (alpha + self.d_tab[drow + t_prop]);
                    let q_cur = self.d_tab[drow + cur] + alpha;
                    let q_new = self.d_tab[drow + t_prop] + alpha;
                    let accept = (p_new as f64 * q_cur as f64)
                        / (p_cur as f64 * q_new as f64);
                    if (self.rng.next_f32() as f64) < accept {
                        cur = t_prop;
                    }
                }
            }
            let z_new = cur;
            self.d_tab[drow + z_new] += 1.0;
            b_slice[brow + z_new] += 1.0;
            s_local[z_new] += 1.0;
            self.inv_s[z_new] = 1.0 / (vgamma + s_local[z_new]);
            bucket[i].z = z_new as u32;
        }
        // reset the touched bitmap and drop this sweep's frozen
        // proposals (both keyed by the words we actually visited)
        for t in bucket.iter() {
            self.touched_scratch[t.word_local as usize] = false;
            mh.word_props[t.word_local as usize] = None;
        }
        self.tokens[slice_id] = bucket;
        (n, n_touched)
    }
}

impl LdaShard for NativeLdaShard {
    fn gibbs_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s: &[f32],
    ) -> (Vec<f32>, usize, usize) {
        let mut s_local = s.to_vec();
        let (n, n_touched) = self.sweep(slice_id, b_slice, &mut s_local);
        (s_local, n, n_touched)
    }

    fn gibbs_slice_into(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_running: &mut Vec<f32>,
    ) -> (usize, usize) {
        self.sweep(slice_id, b_slice, s_running)
    }

    fn set_sampler(&mut self, kind: SamplerKind) {
        self.sampler = kind;
    }

    fn doc_loglik(&self) -> f64 {
        let k = self.k;
        let mut ll = 0.0f64;
        for d in 0..self.n_docs {
            let denom = self.doc_totals[d] + k as f32 * self.alpha;
            for kk in 0..k {
                let c = self.d_tab[d * k + kk];
                if c > 0.0 {
                    ll += c as f64
                        * (((c + self.alpha) / denom) as f64).ln();
                }
            }
        }
        ll
    }

    fn model_bytes(&self) -> u64 {
        (self.d_tab.len() * 4 + self.k * 4) as u64
    }

    fn save_state(&self) -> Vec<u8> {
        // mutable sampler state: topic assignments + RNG position.  The
        // doc-topic table is a pure function of the assignments (sums of
        // 1.0 — exactly representable, order-free) and is rebuilt on load;
        // tokens' doc/word coordinates and doc_totals are immutable.
        let mut w = Wire::new();
        w.put_u64(self.k as u64);
        w.put_u64(self.tokens.len() as u64);
        for bucket in &self.tokens {
            w.put_u32s(&bucket.iter().map(|t| t.z).collect::<Vec<u32>>());
        }
        w.put_u64s(&self.rng.state());
        // the kernel is chain state too: resuming an mh run with the
        // exact kernel (or vice versa) would draw a different chain
        w.put_u64(match self.sampler {
            SamplerKind::Exact => 0,
            SamplerKind::Mh => 1,
        });
        w.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) {
        let mut r = Unwire::new(bytes);
        assert_eq!(r.u64() as usize, self.k, "checkpoint topic-count mismatch");
        assert_eq!(
            r.u64() as usize,
            self.tokens.len(),
            "checkpoint slice-count mismatch"
        );
        self.d_tab.iter_mut().for_each(|c| *c = 0.0);
        for bucket in self.tokens.iter_mut() {
            let zs = r.u32s();
            assert_eq!(
                zs.len(),
                bucket.len(),
                "checkpoint token-count mismatch"
            );
            for (t, z) in bucket.iter_mut().zip(zs) {
                t.z = z;
                self.d_tab[t.doc as usize * self.k + z as usize] += 1.0;
            }
        }
        let st = r.u64s();
        self.rng = Rng::from_state(
            st.try_into().expect("rng state is four words"),
        );
        self.sampler = match r.u64() {
            0 => SamplerKind::Exact,
            1 => SamplerKind::Mh,
            other => panic!("checkpoint has unknown sampler tag {other}"),
        };
        r.done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CscMatrix;

    // ---- Lasso ----

    fn lasso_fixture() -> NativeLassoShard {
        // dense 4x3 matrix as CSC
        let x = CscMatrix::from_triplets(
            4,
            3,
            &[
                (0, 0, 1.0),
                (1, 0, 2.0),
                (1, 1, 1.0),
                (2, 1, -1.0),
                (3, 2, 3.0),
            ],
        );
        NativeLassoShard::new(x, vec![1.0, 2.0, 3.0, 4.0])
    }

    #[test]
    fn lasso_initial_residual_is_y() {
        let s = lasso_fixture();
        assert_eq!(s.residual(), &[1.0, 2.0, 3.0, 4.0]);
        assert!((s.loss() - 0.5 * 30.0).abs() < 1e-9);
    }

    #[test]
    fn lasso_partials_match_definition() {
        let mut s = lasso_fixture();
        // z_0 = x_0^T r + ||x_0||^2 * b_0 with r=y
        let z = s.partials(&[0, 2], &[0.5, 0.0]);
        assert!((z[0] - (1.0 + 4.0 + 5.0 * 0.5)).abs() < 1e-6);
        assert!((z[1] - 12.0).abs() < 1e-6);
    }

    #[test]
    fn lasso_apply_delta_matches_reset() {
        let mut a = lasso_fixture();
        let mut b = lasso_fixture();
        a.apply_delta(&[0, 1], &[0.3, -0.2]);
        let mut beta = vec![0.0f32; 3];
        beta[0] = 0.3;
        beta[1] = -0.2;
        b.reset_residual(&beta);
        for (x, y) in a.residual().iter().zip(b.residual().iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    // ---- MF ----

    fn mf_fixture() -> NativeMfShard {
        // 3 users x 4 items, fully observed rank-1 structure
        let mut trips = Vec::new();
        let w_true = [1.0f32, 2.0, 3.0];
        let h_true = [0.5f32, 1.0, -1.0, 2.0];
        for i in 0..3u32 {
            for j in 0..4u32 {
                trips.push((i, j, w_true[i as usize] * h_true[j as usize]));
            }
        }
        let a = CsrMatrix::from_triplets(3, 4, &trips);
        let w0 = vec![0.5f32; 3]; // rank 1
        let h0 = vec![0.5f32; 4];
        NativeMfShard::new(a, w0, h0, 1, 0.01)
    }

    #[test]
    fn mf_h_stats_shapes_and_signs() {
        let mut s = mf_fixture();
        let (a, b) = s.h_stats(0);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // b_j = sum w_ik^2 = 3 * 0.25
        for bj in &b {
            assert!((bj - 0.75).abs() < 1e-6);
        }
    }

    #[test]
    fn mf_alternating_updates_reduce_loss() {
        let mut s = mf_fixture();
        let lam = 0.01f32;
        let l0 = s.loss();
        for _ in 0..10 {
            // H update: closed form from stats (single worker => pull = local)
            let (a, b) = s.h_stats(0);
            let new_row: Vec<f32> = a
                .iter()
                .zip(b.iter())
                .map(|(ai, bi)| ai / (lam + bi))
                .collect();
            s.set_h_row(0, &new_row);
            s.update_w(0);
        }
        let l1 = s.loss();
        assert!(l1 < l0 * 0.05, "loss {l0} -> {l1}");
    }

    #[test]
    fn mf_set_h_row_keeps_residuals_consistent() {
        let mut s = mf_fixture();
        let (_, _) = s.h_stats(0);
        s.set_h_row(0, &[1.0, 1.0, 1.0, 1.0]);
        // residual must equal a - w h with the new h
        let m = 4;
        for i in 0..3 {
            let wi = s.w[i];
            for (j, r) in s.residual_view().row_iter(i) {
                let a_ij = [0.5f32, 1.0, -1.0, 2.0][j as usize]
                    * [1.0f32, 2.0, 3.0][i];
                let pred = wi * s.h[j as usize % m];
                assert!((r - (a_ij - pred)).abs() < 1e-5);
            }
        }
    }

    // ---- LDA ----

    fn lda_fixture(seed: u64) -> (NativeLdaShard, Vec<f32>, Vec<f32>) {
        let k = 4;
        let vs = 8; // words in slice 0
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::new();
        for _ in 0..100 {
            tokens.push(Token {
                doc: rng.below(5) as u32,
                word_local: rng.below(vs) as u32,
                z: rng.below(k) as u32,
            });
        }
        // B slice counts consistent with assignments
        let mut b = vec![0.0f32; vs * k];
        let mut s = vec![0.0f32; k];
        for t in &tokens {
            b[t.word_local as usize * k + t.z as usize] += 1.0;
            s[t.z as usize] += 1.0;
        }
        let shard = NativeLdaShard::new(
            vec![tokens],
            5,
            k,
            0.1,
            0.01,
            1000,
            seed,
        );
        (shard, b, s)
    }

    #[test]
    fn lda_gibbs_conserves_counts() {
        let (mut shard, mut b, s) = lda_fixture(1);
        let b_total: f32 = b.iter().sum();
        let (s_local, n, touched) = shard.gibbs_slice(0, &mut b, &s);
        assert!(touched > 0 && touched <= 8);
        assert_eq!(n, 100);
        assert!((b.iter().sum::<f32>() - b_total).abs() < 1e-3);
        assert!(
            (s_local.iter().sum::<f32>() - s.iter().sum::<f32>()).abs()
                < 1e-3
        );
        // doc-topic table row sums unchanged
        let (n_docs, k) = shard.dims();
        let mut total = 0.0f32;
        for d in 0..n_docs {
            for kk in 0..k {
                total += shard.d_tab()[d * k + kk];
            }
        }
        assert!((total - 100.0).abs() < 1e-3);
    }

    #[test]
    fn lda_counts_stay_nonnegative() {
        let (mut shard, mut b, s) = lda_fixture(2);
        for _ in 0..5 {
            let _ = shard.gibbs_slice(0, &mut b, &s);
            assert!(b.iter().all(|&c| c >= 0.0));
            assert!(shard.d_tab().iter().all(|&c| c >= -1e-6));
        }
    }

    #[test]
    fn lda_checkpoint_roundtrip_resumes_the_exact_chain() {
        fn bits(v: &[f32]) -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        }
        let (mut a, mut b_a, s) = lda_fixture(31);
        let _ = a.gibbs_slice(0, &mut b_a, &s);
        let blob = a.save_state();
        // restore into a shard built from the same corpus inputs; the B
        // slice travels separately (it lives in the KV plane)
        let (mut c, mut b_c, _) = lda_fixture(31);
        c.load_state(&blob);
        b_c.copy_from_slice(&b_a);
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
        // both shards must now draw the identical Gibbs chain
        let (sa, na, _) = a.gibbs_slice(0, &mut b_a, &s);
        let (sc, nc, _) = c.gibbs_slice(0, &mut b_c, &s);
        assert_eq!(na, nc);
        assert_eq!(bits(&sa), bits(&sc));
        assert_eq!(bits(&b_a), bits(&b_c));
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
    }

    #[test]
    fn mf_checkpoint_roundtrip_is_bit_exact() {
        let mut a = mf_fixture();
        let (sa, sb) = a.h_stats(0);
        let row: Vec<f32> = sa
            .iter()
            .zip(sb.iter())
            .map(|(x, y)| x / (0.01 + y))
            .collect();
        a.set_h_row(0, &row);
        a.update_w(0);
        let blob = a.save_state();
        let mut c = mf_fixture();
        c.load_state(&blob);
        assert_eq!(a.loss().to_bits(), c.loss().to_bits());
        // further identical updates stay bit-identical
        a.update_w(0);
        c.update_w(0);
        let wa: Vec<u32> = a.w.iter().map(|v| v.to_bits()).collect();
        let wc: Vec<u32> = c.w.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wa, wc);
    }

    #[test]
    fn lda_doc_loglik_is_finite_negative() {
        let (shard, _, _) = lda_fixture(3);
        let ll = shard.doc_loglik();
        assert!(ll.is_finite());
        assert!(ll < 0.0);
    }

    // ---- LDA: Metropolis–Hastings kernel ----

    #[test]
    fn mh_sweep_conserves_counts() {
        let (mut shard, mut b, s) = lda_fixture(1);
        shard.set_sampler(SamplerKind::Mh);
        let b_total: f32 = b.iter().sum();
        let mut s_running = s.clone();
        for _ in 0..5 {
            let (n, touched) =
                shard.gibbs_slice_into(0, &mut b, &mut s_running);
            assert_eq!(n, 100);
            assert!(touched > 0 && touched <= 8);
            assert!((b.iter().sum::<f32>() - b_total).abs() < 1e-3);
            assert!(
                (s_running.iter().sum::<f32>() - s.iter().sum::<f32>())
                    .abs()
                    < 1e-3
            );
            assert!(b.iter().all(|&c| c >= 0.0));
            assert!(shard.d_tab().iter().all(|&c| c >= -1e-6));
        }
        let (n_docs, k) = shard.dims();
        let total: f32 = shard.d_tab()[..n_docs * k].iter().sum();
        assert!((total - 100.0).abs() < 1e-3);
    }

    #[test]
    fn mh_sweeps_are_deterministic_per_seed() {
        fn run(seed: u64) -> (Vec<u32>, Vec<u32>) {
            let (mut shard, mut b, s) = lda_fixture(seed);
            shard.set_sampler(SamplerKind::Mh);
            let mut s_running = s;
            for _ in 0..3 {
                let _ = shard.gibbs_slice_into(0, &mut b, &mut s_running);
            }
            (
                b.iter().map(|x| x.to_bits()).collect(),
                shard.d_tab().iter().map(|x| x.to_bits()).collect(),
            )
        }
        assert_eq!(run(9), run(9));
        // and a different seed draws a different chain
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn mh_checkpoint_roundtrip_resumes_the_exact_chain() {
        fn bits(v: &[f32]) -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        }
        let (mut a, mut b_a, s) = lda_fixture(31);
        a.set_sampler(SamplerKind::Mh);
        let mut s_a = s.clone();
        let _ = a.gibbs_slice_into(0, &mut b_a, &mut s_a);
        let blob = a.save_state();
        // the restored shard is NOT told the sampler: the checkpoint
        // carries it, so the resumed chain keeps drawing mh
        let (mut c, mut b_c, _) = lda_fixture(31);
        c.load_state(&blob);
        b_c.copy_from_slice(&b_a);
        let mut s_c = s_a.clone();
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
        let (na, _) = a.gibbs_slice_into(0, &mut b_a, &mut s_a);
        let (nc, _) = c.gibbs_slice_into(0, &mut b_c, &mut s_c);
        assert_eq!(na, nc);
        assert_eq!(bits(&s_a), bits(&s_c));
        assert_eq!(bits(&b_a), bits(&b_c));
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
    }

    /// Frozen-state fixture for the stationarity property test: slice 0
    /// holds exactly one movable token (one word), slice 1 holds fixed
    /// tokens that are never swept but shape the doc-topic and global
    /// topic counts.  The movable token's exact conditional is then a
    /// constant categorical, so a long MH chain over it must match.
    fn single_token_fixture(
        sampler: SamplerKind,
        seed: u64,
    ) -> (NativeLdaShard, Vec<f32>, Vec<f32>, Vec<f64>) {
        let k = 4;
        let alpha = 0.3f32;
        let gamma = 0.5f32;
        let v_global = 10usize;
        // doc 0: 12 frozen tokens; doc 1: 8 frozen (pads s̃ only)
        let doc0_topics = [0u32, 0, 0, 1, 1, 2, 2, 2, 2, 3, 3, 3];
        let doc1_topics = [0u32, 0, 1, 1, 2, 2, 3, 3];
        let mut frozen = Vec::new();
        for (i, &z) in doc0_topics.iter().enumerate() {
            frozen.push(Token { doc: 0, word_local: (i % 5) as u32, z });
        }
        for (i, &z) in doc1_topics.iter().enumerate() {
            frozen.push(Token { doc: 1, word_local: (i % 5) as u32, z });
        }
        let movable = vec![Token { doc: 0, word_local: 0, z: 0 }];
        // slice-0 B counts: just the movable token
        let mut b = vec![0.0f32; k];
        b[0] = 1.0;
        // global topic sums: every token
        let mut s = vec![0.0f32; k];
        s[0] += 1.0;
        for z in doc0_topics.iter().chain(doc1_topics.iter()) {
            s[*z as usize] += 1.0;
        }
        let shard = NativeLdaShard::new(
            vec![movable, frozen],
            2,
            k,
            alpha,
            gamma,
            v_global,
            seed,
        );
        // the exact conditional with the movable token excluded: the
        // excluded counts are constants of the chain
        let d_excl = [3.0f64, 2.0, 4.0, 3.0]; // doc-0 frozen topics
        let s_excl = [5.0f64, 4.0, 6.0, 5.0]; // all frozen topics
        let vg = v_global as f64 * gamma as f64;
        let weights: Vec<f64> = (0..k)
            .map(|kk| {
                gamma as f64 * (alpha as f64 + d_excl[kk])
                    / (vg + s_excl[kk])
            })
            .collect();
        let total: f64 = weights.iter().sum();
        let p: Vec<f64> = weights.iter().map(|w| w / total).collect();
        let mut shard = shard;
        shard.set_sampler(sampler);
        (shard, b, s, p)
    }

    fn empirical_tv(sampler: SamplerKind, seed: u64) -> f64 {
        let (mut shard, mut b, s, p) = single_token_fixture(sampler, seed);
        let mut s_running = s;
        let burn_in = 2_000usize;
        let n_samples = 40_000usize;
        let mut counts = vec![0u64; p.len()];
        for it in 0..burn_in + n_samples {
            let _ = shard.gibbs_slice_into(0, &mut b, &mut s_running);
            if it >= burn_in {
                counts[shard.bucket(0)[0].z as usize] += 1;
            }
        }
        0.5 * p
            .iter()
            .zip(&counts)
            .map(|(&pi, &c)| (pi - c as f64 / n_samples as f64).abs())
            .sum::<f64>()
    }

    #[test]
    fn mh_matches_the_exact_conditional_at_a_frozen_state() {
        // the ISSUE's acceptance-ratio property test: at a frozen state
        // the mh chain's marginal over the single movable token must
        // converge to the same categorical the exact kernel samples
        // from directly.  Both proposal steps are exercised here: the
        // word proposal is mostly prior-alias draws (the word has one
        // token), the doc proposal is mostly other-token draws.
        for seed in [7u64, 19] {
            let tv = empirical_tv(SamplerKind::Mh, seed);
            assert!(tv < 0.05, "seed {seed}: mh tv distance {tv}");
        }
        // sanity: the exact kernel (iid draws from the conditional)
        // passes the same bound with room to spare
        let tv = empirical_tv(SamplerKind::Exact, 7);
        assert!(tv < 0.03, "exact tv distance {tv}");
    }
}
