//! Native (pure-rust, sparse-aware) shard backends.  Mirrors the L1/L2
//! artifact math exactly — integration tests assert agreement with the
//! XLA path to float tolerance.

use super::{LassoShard, LdaShard, MfShard};
use crate::sparse::{CscMatrix, CsrMatrix};
use crate::util::{Rng, Unwire, Wire};

// ------------------------------------------------------------- Lasso -----

/// One worker's row shard of the Lasso problem.
pub struct NativeLassoShard {
    /// Shard design matrix (rows = this worker's samples).
    pub x: CscMatrix,
    pub y: Vec<f32>,
    /// Residual r = y - X beta over this shard.
    r: Vec<f32>,
    /// Cached per-column squared norms over this shard.
    col_norms: Vec<f32>,
}

impl NativeLassoShard {
    pub fn new(x: CscMatrix, y: Vec<f32>) -> Self {
        assert_eq!(x.rows(), y.len());
        let col_norms = (0..x.cols()).map(|j| x.col_norm_sq(j)).collect();
        let r = y.clone(); // beta = 0 initially
        NativeLassoShard { x, y, r, col_norms }
    }

    pub fn residual(&self) -> &[f32] {
        &self.r
    }
}

impl LassoShard for NativeLassoShard {
    fn partials(&mut self, sel: &[usize], beta_sel: &[f32]) -> Vec<f32> {
        sel.iter()
            .zip(beta_sel.iter())
            .map(|(&j, &bj)| {
                self.x.col_dot_dense(j, &self.r) + self.col_norms[j] * bj
            })
            .collect()
    }

    fn apply_delta(&mut self, sel: &[usize], delta: &[f32]) {
        for (&j, &dj) in sel.iter().zip(delta.iter()) {
            if dj != 0.0 {
                self.x.col_axpy_dense(j, -dj, &mut self.r);
            }
        }
    }

    fn reset_residual(&mut self, beta: &[f32]) {
        let xb = self.x.matvec(beta);
        for (ri, (yi, xbi)) in
            self.r.iter_mut().zip(self.y.iter().zip(xb.iter()))
        {
            *ri = yi - xbi;
        }
    }

    fn loss(&self) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(&self.r)
    }

    fn model_bytes(&self) -> u64 {
        // residual + column-norm cache (model-adjacent state)
        (self.r.len() * 4 + self.col_norms.len() * 4) as u64
    }
}

// ---------------------------------------------------------------- MF -----

/// One worker's user-row shard of the MF problem.
pub struct NativeMfShard {
    /// Residuals r_ij stored in the shard's CSR values.
    resid: CsrMatrix,
    /// Local W rows (n_local × k), row-major.
    pub w: Vec<f32>,
    /// Local copy of H (k × m), row-major (synced by the engine).
    pub h: Vec<f32>,
    pub rank: usize,
    n_items: usize,
    lambda: f32,
}

impl NativeMfShard {
    /// Build from the shard's ratings and initial factors; initializes
    /// residuals r = a - w h over observed entries.
    pub fn new(
        a: CsrMatrix,
        w: Vec<f32>,
        h: Vec<f32>,
        rank: usize,
        lambda: f32,
    ) -> Self {
        let n_items = a.cols();
        assert_eq!(w.len(), a.rows() * rank);
        assert_eq!(h.len(), rank * n_items);
        let mut shard =
            NativeMfShard { resid: a, w, h, rank, n_items, lambda };
        shard.recompute_residuals();
        shard
    }

    fn recompute_residuals(&mut self) {
        let k = self.rank;
        let m = self.n_items;
        for i in 0..self.resid.rows() {
            let wi: Vec<f32> = self.w[i * k..(i + 1) * k].to_vec();
            for (pos, (j, v)) in
                self.resid.row(i).0.to_vec().into_iter().zip(
                    self.resid.row(i).1.to_vec().into_iter(),
                ).enumerate()
            {
                let mut pred = 0.0f32;
                for p in 0..k {
                    pred += wi[p] * self.h[p * m + j as usize];
                }
                self.resid.row_values_mut(i)[pos] = v - pred;
            }
        }
    }

    pub fn residual_view(&self) -> &CsrMatrix {
        &self.resid
    }
}

impl MfShard for NativeMfShard {
    fn h_stats(&mut self, k: usize) -> (Vec<f32>, Vec<f32>) {
        let m = self.n_items;
        let kk = self.rank;
        let mut a = vec![0.0f32; m];
        let mut b = vec![0.0f32; m];
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            if wik == 0.0 {
                continue;
            }
            let hk = &self.h[k * m..(k + 1) * m];
            let (cols, vals) = self.resid.row(i);
            for (j, r) in cols.iter().zip(vals.iter()) {
                let j = *j as usize;
                a[j] += (r + wik * hk[j]) * wik;
                b[j] += wik * wik;
            }
        }
        (a, b)
    }

    fn set_h_row(&mut self, k: usize, row: &[f32]) {
        let m = self.n_items;
        debug_assert_eq!(row.len(), m);
        // residual maintenance: r_ij -= w_ik (h'_kj - h_kj)
        let kk = self.rank;
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            if wik == 0.0 {
                continue;
            }
            let (cols, _) = self.resid.row(i);
            let cols = cols.to_vec();
            let vals = self.resid.row_values_mut(i);
            for (pos, j) in cols.iter().enumerate() {
                let j = *j as usize;
                vals[pos] -= wik * (row[j] - self.h[k * m + j]);
            }
        }
        self.h[k * m..(k + 1) * m].copy_from_slice(row);
    }

    fn update_w(&mut self, k: usize) {
        let m = self.n_items;
        let kk = self.rank;
        let hk: Vec<f32> = self.h[k * m..(k + 1) * m].to_vec();
        for i in 0..self.resid.rows() {
            let wik = self.w[i * kk + k];
            let mut num = 0.0f32;
            let mut den = self.lambda;
            {
                let (cols, vals) = self.resid.row(i);
                for (j, r) in cols.iter().zip(vals.iter()) {
                    let h = hk[*j as usize];
                    num += (r + wik * h) * h;
                    den += h * h;
                }
            }
            let w_new = if den > 0.0 { num / den } else { 0.0 };
            let dw = w_new - wik;
            if dw != 0.0 {
                let (cols, _) = self.resid.row(i);
                let cols = cols.to_vec();
                let vals = self.resid.row_values_mut(i);
                for (pos, j) in cols.iter().enumerate() {
                    vals[pos] -= dw * hk[*j as usize];
                }
                self.w[i * kk + k] = w_new;
            }
        }
    }

    fn loss(&self) -> f64 {
        let mut sq = 0.0f64;
        for i in 0..self.resid.rows() {
            for (_, r) in self.resid.row_iter(i) {
                sq += (r as f64) * (r as f64);
            }
        }
        let wreg: f64 =
            self.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        sq + self.lambda as f64 * wreg
    }

    fn model_bytes(&self) -> u64 {
        // W shard + replicated H copy + residual values
        (self.w.len() * 4 + self.h.len() * 4 + self.resid.nnz() * 4) as u64
    }

    fn save_state(&self) -> Vec<u8> {
        // mutable state only: W, the local H copy, residual values (the
        // sparsity pattern and λ are immutable construction inputs)
        let mut wr = Wire::new();
        wr.put_f32s(&self.w);
        wr.put_f32s(&self.h);
        wr.put_u64(self.resid.rows() as u64);
        for i in 0..self.resid.rows() {
            wr.put_f32s(self.resid.row(i).1);
        }
        wr.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) {
        let mut r = Unwire::new(bytes);
        let w = r.f32s();
        assert_eq!(w.len(), self.w.len(), "checkpoint W shape mismatch");
        self.w = w;
        let h = r.f32s();
        assert_eq!(h.len(), self.h.len(), "checkpoint H shape mismatch");
        self.h = h;
        assert_eq!(
            r.u64() as usize,
            self.resid.rows(),
            "checkpoint residual row-count mismatch"
        );
        for i in 0..self.resid.rows() {
            let vals = r.f32s();
            let row = self.resid.row_values_mut(i);
            assert_eq!(vals.len(), row.len(), "checkpoint residual mismatch");
            row.copy_from_slice(&vals);
        }
        r.done();
    }
}

// --------------------------------------------------------------- LDA -----

/// A token with its current topic assignment.
#[derive(Debug, Clone, Copy)]
pub struct Token {
    /// Local document index within the shard.
    pub doc: u32,
    /// Local word index within the word slice.
    pub word_local: u32,
    pub z: u32,
}

/// One worker's document shard: tokens bucketed by word slice.
pub struct NativeLdaShard {
    /// tokens[slice_id] — tokens whose word belongs to that rotation slice.
    tokens: Vec<Vec<Token>>,
    /// Doc-topic counts (n_docs_local × k), row-major f32.
    d_tab: Vec<f32>,
    /// Per-document token totals (for the doc log-likelihood).
    doc_totals: Vec<f32>,
    n_docs: usize,
    k: usize,
    alpha: f32,
    gamma: f32,
    v_global: usize,
    rng: Rng,
    /// Scratch for the conditional distribution.
    prob: Vec<f32>,
    /// Scratch bitmap for touched-word counting (perf: avoids a HashSet in
    /// the sampling loop — see EXPERIMENTS.md §Perf).
    touched_scratch: Vec<bool>,
    /// Scratch for 1/(Vγ + s̃_k): only 2 entries change per token, so the
    /// reciprocals are maintained incrementally instead of recomputed
    /// (removed K divisions/token — EXPERIMENTS.md §Perf).
    inv_s: Vec<f32>,
}

impl NativeLdaShard {
    /// `tokens_by_slice[a]` lists this worker's tokens for slice a, with
    /// initial topic assignments already counted into `d_tab` by the
    /// caller... (no: we count here from the assignments).
    pub fn new(
        tokens_by_slice: Vec<Vec<Token>>,
        n_docs: usize,
        k: usize,
        alpha: f32,
        gamma: f32,
        v_global: usize,
        seed: u64,
    ) -> Self {
        let mut d_tab = vec![0.0f32; n_docs * k];
        let mut doc_totals = vec![0.0f32; n_docs];
        for bucket in &tokens_by_slice {
            for t in bucket {
                d_tab[t.doc as usize * k + t.z as usize] += 1.0;
                doc_totals[t.doc as usize] += 1.0;
            }
        }
        NativeLdaShard {
            tokens: tokens_by_slice,
            d_tab,
            doc_totals,
            n_docs,
            k,
            alpha,
            gamma,
            v_global,
            rng: Rng::new(seed),
            prob: vec![0.0f32; k],
            touched_scratch: Vec::new(),
            inv_s: vec![0.0f32; k],
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.iter().map(|b| b.len()).sum()
    }

    pub fn d_tab(&self) -> &[f32] {
        &self.d_tab
    }

    /// Tokens in one slice bucket (XLA staging).
    pub fn bucket(&self, slice_id: usize) -> &[Token] {
        &self.tokens[slice_id]
    }

    pub fn bucket_mut(&mut self, slice_id: usize) -> &mut Vec<Token> {
        &mut self.tokens[slice_id]
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.n_docs, self.k)
    }

    /// The shared Gibbs-sweep core: samples every token of the slice
    /// in place, maintaining `s_local` (the worker's running local topic
    /// sums) directly in the caller's buffer.  Both `gibbs_slice` (which
    /// copies `s` first) and the allocation-free `gibbs_slice_into` funnel
    /// here, so the RNG sequence is identical by construction.
    fn sweep_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_local: &mut [f32],
    ) -> (usize, usize) {
        let k = self.k;
        let vgamma = self.v_global as f32 * self.gamma;
        // tokens mutated in place; slice words tracked in a reusable bitmap
        // (HashSet insertion was ~30% of the sweep — EXPERIMENTS.md §Perf)
        let n_slice_words = b_slice.len() / k;
        if self.touched_scratch.len() < n_slice_words {
            self.touched_scratch.resize(n_slice_words, false);
        }
        let mut n_touched = 0usize;
        let mut bucket = std::mem::take(&mut self.tokens[slice_id]);
        let n = bucket.len();
        // reciprocal table maintained incrementally (2 updates/token)
        for kk in 0..k {
            self.inv_s[kk] = 1.0 / (vgamma + s_local[kk]);
        }
        for t in bucket.iter_mut() {
            let w = t.word_local as usize;
            if !self.touched_scratch[w] {
                self.touched_scratch[w] = true;
                n_touched += 1;
            }
            let drow = t.doc as usize * k;
            let brow = w * k;
            let zi = t.z as usize;
            self.d_tab[drow + zi] -= 1.0;
            b_slice[brow + zi] -= 1.0;
            s_local[zi] -= 1.0;
            self.inv_s[zi] = 1.0 / (vgamma + s_local[zi]);
            // conditional: (γ+B)·inv_s·(α+D), fused into a running CDF
            let mut total = 0.0f32;
            let d_row = &self.d_tab[drow..drow + k];
            let b_row = &b_slice[brow..brow + k];
            for kk in 0..k {
                let p = (self.gamma + b_row[kk]) * self.inv_s[kk]
                    * (self.alpha + d_row[kk]);
                total += p;
                self.prob[kk] = total;
            }
            let u = self.rng.next_f32() * total;
            // inverse CDF (linear scan; K is small at our scales)
            let mut z_new = k - 1;
            for (kk, &c) in self.prob.iter().enumerate() {
                if u < c {
                    z_new = kk;
                    break;
                }
            }
            self.d_tab[drow + z_new] += 1.0;
            b_slice[brow + z_new] += 1.0;
            s_local[z_new] += 1.0;
            self.inv_s[z_new] = 1.0 / (vgamma + s_local[z_new]);
            t.z = z_new as u32;
        }
        // reset only the bits we set (bitmap reuse across calls)
        for t in bucket.iter() {
            self.touched_scratch[t.word_local as usize] = false;
        }
        self.tokens[slice_id] = bucket;
        (n, n_touched)
    }
}

impl LdaShard for NativeLdaShard {
    fn gibbs_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s: &[f32],
    ) -> (Vec<f32>, usize, usize) {
        let mut s_local = s.to_vec();
        let (n, n_touched) =
            self.sweep_slice(slice_id, b_slice, &mut s_local);
        (s_local, n, n_touched)
    }

    fn gibbs_slice_into(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_running: &mut Vec<f32>,
    ) -> (usize, usize) {
        self.sweep_slice(slice_id, b_slice, s_running)
    }

    fn doc_loglik(&self) -> f64 {
        let k = self.k;
        let mut ll = 0.0f64;
        for d in 0..self.n_docs {
            let denom = self.doc_totals[d] + k as f32 * self.alpha;
            for kk in 0..k {
                let c = self.d_tab[d * k + kk];
                if c > 0.0 {
                    ll += c as f64
                        * (((c + self.alpha) / denom) as f64).ln();
                }
            }
        }
        ll
    }

    fn model_bytes(&self) -> u64 {
        (self.d_tab.len() * 4 + self.k * 4) as u64
    }

    fn save_state(&self) -> Vec<u8> {
        // mutable sampler state: topic assignments + RNG position.  The
        // doc-topic table is a pure function of the assignments (sums of
        // 1.0 — exactly representable, order-free) and is rebuilt on load;
        // tokens' doc/word coordinates and doc_totals are immutable.
        let mut w = Wire::new();
        w.put_u64(self.k as u64);
        w.put_u64(self.tokens.len() as u64);
        for bucket in &self.tokens {
            w.put_u32s(&bucket.iter().map(|t| t.z).collect::<Vec<u32>>());
        }
        w.put_u64s(&self.rng.state());
        w.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) {
        let mut r = Unwire::new(bytes);
        assert_eq!(r.u64() as usize, self.k, "checkpoint topic-count mismatch");
        assert_eq!(
            r.u64() as usize,
            self.tokens.len(),
            "checkpoint slice-count mismatch"
        );
        self.d_tab.iter_mut().for_each(|c| *c = 0.0);
        for bucket in self.tokens.iter_mut() {
            let zs = r.u32s();
            assert_eq!(
                zs.len(),
                bucket.len(),
                "checkpoint token-count mismatch"
            );
            for (t, z) in bucket.iter_mut().zip(zs) {
                t.z = z;
                self.d_tab[t.doc as usize * self.k + z as usize] += 1.0;
            }
        }
        let st = r.u64s();
        self.rng = Rng::from_state(
            st.try_into().expect("rng state is four words"),
        );
        r.done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CscMatrix;

    // ---- Lasso ----

    fn lasso_fixture() -> NativeLassoShard {
        // dense 4x3 matrix as CSC
        let x = CscMatrix::from_triplets(
            4,
            3,
            &[
                (0, 0, 1.0),
                (1, 0, 2.0),
                (1, 1, 1.0),
                (2, 1, -1.0),
                (3, 2, 3.0),
            ],
        );
        NativeLassoShard::new(x, vec![1.0, 2.0, 3.0, 4.0])
    }

    #[test]
    fn lasso_initial_residual_is_y() {
        let s = lasso_fixture();
        assert_eq!(s.residual(), &[1.0, 2.0, 3.0, 4.0]);
        assert!((s.loss() - 0.5 * 30.0).abs() < 1e-9);
    }

    #[test]
    fn lasso_partials_match_definition() {
        let mut s = lasso_fixture();
        // z_0 = x_0^T r + ||x_0||^2 * b_0 with r=y
        let z = s.partials(&[0, 2], &[0.5, 0.0]);
        assert!((z[0] - (1.0 + 4.0 + 5.0 * 0.5)).abs() < 1e-6);
        assert!((z[1] - 12.0).abs() < 1e-6);
    }

    #[test]
    fn lasso_apply_delta_matches_reset() {
        let mut a = lasso_fixture();
        let mut b = lasso_fixture();
        a.apply_delta(&[0, 1], &[0.3, -0.2]);
        let mut beta = vec![0.0f32; 3];
        beta[0] = 0.3;
        beta[1] = -0.2;
        b.reset_residual(&beta);
        for (x, y) in a.residual().iter().zip(b.residual().iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    // ---- MF ----

    fn mf_fixture() -> NativeMfShard {
        // 3 users x 4 items, fully observed rank-1 structure
        let mut trips = Vec::new();
        let w_true = [1.0f32, 2.0, 3.0];
        let h_true = [0.5f32, 1.0, -1.0, 2.0];
        for i in 0..3u32 {
            for j in 0..4u32 {
                trips.push((i, j, w_true[i as usize] * h_true[j as usize]));
            }
        }
        let a = CsrMatrix::from_triplets(3, 4, &trips);
        let w0 = vec![0.5f32; 3]; // rank 1
        let h0 = vec![0.5f32; 4];
        NativeMfShard::new(a, w0, h0, 1, 0.01)
    }

    #[test]
    fn mf_h_stats_shapes_and_signs() {
        let mut s = mf_fixture();
        let (a, b) = s.h_stats(0);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // b_j = sum w_ik^2 = 3 * 0.25
        for bj in &b {
            assert!((bj - 0.75).abs() < 1e-6);
        }
    }

    #[test]
    fn mf_alternating_updates_reduce_loss() {
        let mut s = mf_fixture();
        let lam = 0.01f32;
        let l0 = s.loss();
        for _ in 0..10 {
            // H update: closed form from stats (single worker => pull = local)
            let (a, b) = s.h_stats(0);
            let new_row: Vec<f32> = a
                .iter()
                .zip(b.iter())
                .map(|(ai, bi)| ai / (lam + bi))
                .collect();
            s.set_h_row(0, &new_row);
            s.update_w(0);
        }
        let l1 = s.loss();
        assert!(l1 < l0 * 0.05, "loss {l0} -> {l1}");
    }

    #[test]
    fn mf_set_h_row_keeps_residuals_consistent() {
        let mut s = mf_fixture();
        let (_, _) = s.h_stats(0);
        s.set_h_row(0, &[1.0, 1.0, 1.0, 1.0]);
        // residual must equal a - w h with the new h
        let m = 4;
        for i in 0..3 {
            let wi = s.w[i];
            for (j, r) in s.residual_view().row_iter(i) {
                let a_ij = [0.5f32, 1.0, -1.0, 2.0][j as usize]
                    * [1.0f32, 2.0, 3.0][i];
                let pred = wi * s.h[j as usize % m];
                assert!((r - (a_ij - pred)).abs() < 1e-5);
            }
        }
    }

    // ---- LDA ----

    fn lda_fixture(seed: u64) -> (NativeLdaShard, Vec<f32>, Vec<f32>) {
        let k = 4;
        let vs = 8; // words in slice 0
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::new();
        for _ in 0..100 {
            tokens.push(Token {
                doc: rng.below(5) as u32,
                word_local: rng.below(vs) as u32,
                z: rng.below(k) as u32,
            });
        }
        // B slice counts consistent with assignments
        let mut b = vec![0.0f32; vs * k];
        let mut s = vec![0.0f32; k];
        for t in &tokens {
            b[t.word_local as usize * k + t.z as usize] += 1.0;
            s[t.z as usize] += 1.0;
        }
        let shard = NativeLdaShard::new(
            vec![tokens],
            5,
            k,
            0.1,
            0.01,
            1000,
            seed,
        );
        (shard, b, s)
    }

    #[test]
    fn lda_gibbs_conserves_counts() {
        let (mut shard, mut b, s) = lda_fixture(1);
        let b_total: f32 = b.iter().sum();
        let (s_local, n, touched) = shard.gibbs_slice(0, &mut b, &s);
        assert!(touched > 0 && touched <= 8);
        assert_eq!(n, 100);
        assert!((b.iter().sum::<f32>() - b_total).abs() < 1e-3);
        assert!(
            (s_local.iter().sum::<f32>() - s.iter().sum::<f32>()).abs()
                < 1e-3
        );
        // doc-topic table row sums unchanged
        let (n_docs, k) = shard.dims();
        let mut total = 0.0f32;
        for d in 0..n_docs {
            for kk in 0..k {
                total += shard.d_tab()[d * k + kk];
            }
        }
        assert!((total - 100.0).abs() < 1e-3);
    }

    #[test]
    fn lda_counts_stay_nonnegative() {
        let (mut shard, mut b, s) = lda_fixture(2);
        for _ in 0..5 {
            let _ = shard.gibbs_slice(0, &mut b, &s);
            assert!(b.iter().all(|&c| c >= 0.0));
            assert!(shard.d_tab().iter().all(|&c| c >= -1e-6));
        }
    }

    #[test]
    fn lda_checkpoint_roundtrip_resumes_the_exact_chain() {
        fn bits(v: &[f32]) -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        }
        let (mut a, mut b_a, s) = lda_fixture(31);
        let _ = a.gibbs_slice(0, &mut b_a, &s);
        let blob = a.save_state();
        // restore into a shard built from the same corpus inputs; the B
        // slice travels separately (it lives in the KV plane)
        let (mut c, mut b_c, _) = lda_fixture(31);
        c.load_state(&blob);
        b_c.copy_from_slice(&b_a);
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
        // both shards must now draw the identical Gibbs chain
        let (sa, na, _) = a.gibbs_slice(0, &mut b_a, &s);
        let (sc, nc, _) = c.gibbs_slice(0, &mut b_c, &s);
        assert_eq!(na, nc);
        assert_eq!(bits(&sa), bits(&sc));
        assert_eq!(bits(&b_a), bits(&b_c));
        assert_eq!(bits(a.d_tab()), bits(c.d_tab()));
    }

    #[test]
    fn mf_checkpoint_roundtrip_is_bit_exact() {
        let mut a = mf_fixture();
        let (sa, sb) = a.h_stats(0);
        let row: Vec<f32> = sa
            .iter()
            .zip(sb.iter())
            .map(|(x, y)| x / (0.01 + y))
            .collect();
        a.set_h_row(0, &row);
        a.update_w(0);
        let blob = a.save_state();
        let mut c = mf_fixture();
        c.load_state(&blob);
        assert_eq!(a.loss().to_bits(), c.loss().to_bits());
        // further identical updates stay bit-identical
        a.update_w(0);
        c.update_w(0);
        let wa: Vec<u32> = a.w.iter().map(|v| v.to_bits()).collect();
        let wc: Vec<u32> = c.w.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wa, wc);
    }

    #[test]
    fn lda_doc_loglik_is_finite_negative() {
        let (shard, _, _) = lda_fixture(3);
        let ll = shard.doc_loglik();
        assert!(ll.is_finite());
        assert!(ll < 0.0);
    }
}
