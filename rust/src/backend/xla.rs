//! XLA-artifact shard backends: the three-layer AOT path.
//!
//! Each shard stages its state into the fixed canonical shapes the
//! artifacts were lowered at (see python/compile/shapes.py, recorded in the
//! manifest) and executes the JAX/Pallas graphs via PJRT.  Scheduled sets
//! smaller than the artifact's U are padded by repeating the first index —
//! duplicates compute identical z values and the pull step reads only the
//! valid prefix.

use super::{LassoShard, LdaShard, MfShard};
use crate::backend::native::Token;
use crate::runtime::{Engine, Tensor};
use crate::util::Rng;
use std::sync::Arc;

// ------------------------------------------------------------- Lasso -----

/// Dense row shard evaluated through `lasso_push` / `lasso_residual[_update]`.
pub struct XlaLassoShard {
    engine: Arc<Engine>,
    /// Dense row-major shard design matrix (n × j).
    x: Vec<f32>,
    y: Vec<f32>,
    r: Vec<f32>,
    n: usize,
    j: usize,
    /// Artifact batch width U.
    u: usize,
}

impl XlaLassoShard {
    /// `x` row-major (n × j); dims must match the artifact's canonical
    /// shapes.
    pub fn new(engine: Arc<Engine>, x: Vec<f32>, y: Vec<f32>) -> anyhow::Result<Self> {
        let spec = engine.spec("lasso_push")?;
        let n = spec.inputs[0].dims[0];
        let u = spec.inputs[0].dims[1];
        let rspec = engine.spec("lasso_residual")?;
        let j = rspec.inputs[0].dims[1];
        anyhow::ensure!(
            x.len() == n * j,
            "x must be {n}x{j} dense (got {} elems)",
            x.len()
        );
        anyhow::ensure!(y.len() == n, "y must have {n} rows");
        let r = y.clone();
        Ok(XlaLassoShard { engine, x, y, r, n, j, u })
    }

    pub fn batch_width(&self) -> usize {
        self.u
    }

    /// Gather columns `sel` (padded to U) into a dense (n × U) block.
    fn gather(&self, sel: &[usize]) -> (Vec<f32>, Vec<usize>) {
        let mut padded: Vec<usize> = sel.to_vec();
        while padded.len() < self.u {
            padded.push(sel.first().copied().unwrap_or(0));
        }
        let mut block = vec![0.0f32; self.n * self.u];
        for (c, &j) in padded.iter().enumerate() {
            for row in 0..self.n {
                block[row * self.u + c] = self.x[row * self.j + j];
            }
        }
        (block, padded)
    }
}

impl LassoShard for XlaLassoShard {
    fn partials(&mut self, sel: &[usize], beta_sel: &[f32]) -> Vec<f32> {
        assert!(sel.len() <= self.u, "set larger than artifact width");
        let (block, padded) = self.gather(sel);
        let mut beta_pad = vec![0.0f32; self.u];
        beta_pad[..beta_sel.len()].copy_from_slice(beta_sel);
        for c in sel.len()..self.u {
            // padding repeats sel[0]; give it the true beta so the value is
            // merely duplicated, never wrong
            beta_pad[c] = beta_sel.first().copied().unwrap_or(0.0);
        }
        let _ = padded;
        let out = self
            .engine
            .call(
                "lasso_push",
                &[
                    Tensor::f32(&[self.n, self.u], block),
                    Tensor::f32(&[self.n], self.r.clone()),
                    Tensor::f32(&[self.u], beta_pad),
                ],
            )
            .expect("lasso_push artifact");
        let z = out.into_iter().next().unwrap().into_f32().unwrap();
        z[..sel.len()].to_vec()
    }

    fn apply_delta(&mut self, sel: &[usize], delta: &[f32]) {
        let (block, _) = self.gather(sel);
        let mut delta_pad = vec![0.0f32; self.u];
        delta_pad[..delta.len()].copy_from_slice(delta);
        // padding columns get delta 0 → no effect
        let out = self
            .engine
            .call(
                "lasso_residual_update",
                &[
                    Tensor::f32(&[self.n], self.r.clone()),
                    Tensor::f32(&[self.n, self.u], block),
                    Tensor::f32(&[self.u], delta_pad),
                ],
            )
            .expect("lasso_residual_update artifact");
        self.r = out.into_iter().next().unwrap().into_f32().unwrap();
    }

    fn reset_residual(&mut self, beta: &[f32]) {
        assert_eq!(beta.len(), self.j);
        let out = self
            .engine
            .call(
                "lasso_residual",
                &[
                    Tensor::f32(&[self.n, self.j], self.x.clone()),
                    Tensor::f32(&[self.n], self.y.clone()),
                    Tensor::f32(&[self.j], beta.to_vec()),
                ],
            )
            .expect("lasso_residual artifact");
        self.r = out.into_iter().next().unwrap().into_f32().unwrap();
    }

    fn loss(&self) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(&self.r)
    }

    fn model_bytes(&self) -> u64 {
        (self.r.len() * 4) as u64
    }
}

// ---------------------------------------------------------------- MF -----

/// Dense masked shard evaluated through `mf_push` / `mf_push_w`.
pub struct XlaMfShard {
    engine: Arc<Engine>,
    a: Vec<f32>,
    mask: Vec<f32>,
    w: Vec<f32>,
    h: Vec<f32>,
    n: usize,
    m: usize,
    k: usize,
    lambda: f32,
}

impl XlaMfShard {
    pub fn new(
        engine: Arc<Engine>,
        a: Vec<f32>,
        mask: Vec<f32>,
        w0: Vec<f32>,
        h0: Vec<f32>,
        lambda: f32,
    ) -> anyhow::Result<Self> {
        let spec = engine.spec("mf_push")?;
        let n = spec.inputs[0].dims[0];
        let m = spec.inputs[0].dims[1];
        let k = spec.inputs[2].dims[1];
        anyhow::ensure!(a.len() == n * m && mask.len() == n * m);
        anyhow::ensure!(w0.len() == n * k && h0.len() == k * m);
        Ok(XlaMfShard { engine, a, mask, w: w0, h: h0, n, m, k, lambda })
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.n, self.m, self.k)
    }

    fn inputs_with_k(&self, k: usize) -> Vec<Tensor> {
        vec![
            Tensor::f32(&[self.n, self.m], self.a.clone()),
            Tensor::f32(&[self.n, self.m], self.mask.clone()),
            Tensor::f32(&[self.n, self.k], self.w.clone()),
            Tensor::f32(&[self.k, self.m], self.h.clone()),
            Tensor::scalar_i32(k as i32),
        ]
    }
}

impl MfShard for XlaMfShard {
    fn h_stats(&mut self, k: usize) -> (Vec<f32>, Vec<f32>) {
        let out = self
            .engine
            .call("mf_push", &self.inputs_with_k(k))
            .expect("mf_push artifact");
        let mut it = out.into_iter();
        let a = it.next().unwrap().into_f32().unwrap();
        let b = it.next().unwrap().into_f32().unwrap();
        (a, b)
    }

    fn set_h_row(&mut self, k: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.m);
        self.h[k * self.m..(k + 1) * self.m].copy_from_slice(row);
        // residuals are recomputed inside each artifact call — nothing else
        // to maintain
    }

    fn update_w(&mut self, k: usize) {
        let out = self
            .engine
            .call("mf_push_w", &self.inputs_with_k(k))
            .expect("mf_push_w artifact");
        let mut it = out.into_iter();
        let a = it.next().unwrap().into_f32().unwrap();
        let b = it.next().unwrap().into_f32().unwrap();
        for i in 0..self.n {
            self.w[i * self.k + k] = a[i] / (self.lambda + b[i]);
        }
    }

    fn loss(&self) -> f64 {
        let out = self
            .engine
            .call(
                "mf_objective",
                &[
                    Tensor::f32(&[self.n, self.m], self.a.clone()),
                    Tensor::f32(&[self.n, self.m], self.mask.clone()),
                    Tensor::f32(&[self.n, self.k], self.w.clone()),
                    Tensor::f32(&[self.k, self.m], self.h.clone()),
                ],
            )
            .expect("mf_objective artifact");
        let sq = out[0].as_f32().unwrap()[0] as f64;
        let wreg: f64 = self.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        sq + self.lambda as f64 * wreg
    }

    fn model_bytes(&self) -> u64 {
        (self.w.len() * 4 + self.h.len() * 4) as u64
    }
}

// --------------------------------------------------------------- LDA -----

/// Token shard swept through the `lda_push` scan artifact.  Every slice
/// bucket must hold exactly the artifact's T tokens (the e2e example
/// constructs workloads at that size).
pub struct XlaLdaShard {
    engine: Arc<Engine>,
    tokens: Vec<Vec<Token>>,
    /// Local doc ids per bucket (parallel to tokens).
    d_tab: Vec<f32>,
    n_docs: usize,
    k: usize,
    t_cap: usize,
    vs: usize,
    alpha: f32,
    rng: Rng,
    doc_totals: Vec<f32>,
}

impl XlaLdaShard {
    pub fn new(
        engine: Arc<Engine>,
        tokens_by_slice: Vec<Vec<Token>>,
        n_docs: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let spec = engine.spec("lda_push")?;
        let t_cap = spec.inputs[0].dims[0];
        let nd = spec.inputs[4].dims[0];
        let k = spec.inputs[4].dims[1];
        let vs = spec.inputs[5].dims[0];
        let alpha: f32 = spec.meta_parse("alpha").unwrap_or(0.1);
        anyhow::ensure!(n_docs <= nd, "shard has more docs than artifact ND");
        for (a, b) in tokens_by_slice.iter().enumerate() {
            anyhow::ensure!(
                b.len() == t_cap,
                "bucket {a} has {} tokens; artifact requires exactly {t_cap}",
                b.len()
            );
        }
        let mut d_tab = vec![0.0f32; nd * k];
        let mut doc_totals = vec![0.0f32; nd];
        for bucket in &tokens_by_slice {
            for t in bucket {
                d_tab[t.doc as usize * k + t.z as usize] += 1.0;
                doc_totals[t.doc as usize] += 1.0;
            }
        }
        Ok(XlaLdaShard {
            engine,
            tokens: tokens_by_slice,
            d_tab,
            n_docs: nd,
            k,
            t_cap,
            vs,
            alpha,
            rng: Rng::new(seed),
            doc_totals,
        })
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.iter().map(|b| b.len()).sum()
    }
}

impl LdaShard for XlaLdaShard {
    fn gibbs_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s: &[f32],
    ) -> (Vec<f32>, usize, usize) {
        assert_eq!(b_slice.len(), self.vs * self.k);
        let bucket = &self.tokens[slice_id];
        let t = self.t_cap;
        let touched: std::collections::HashSet<u32> =
            bucket.iter().map(|x| x.word_local).collect();
        let n_touched = touched.len();
        let doc_ids: Vec<i32> = bucket.iter().map(|x| x.doc as i32).collect();
        let word_ids: Vec<i32> =
            bucket.iter().map(|x| x.word_local as i32).collect();
        let z: Vec<i32> = bucket.iter().map(|x| x.z as i32).collect();
        let u: Vec<f32> = (0..t).map(|_| self.rng.next_f32()).collect();
        let out = self
            .engine
            .call(
                "lda_push",
                &[
                    Tensor::i32(&[t], doc_ids),
                    Tensor::i32(&[t], word_ids),
                    Tensor::i32(&[t], z),
                    Tensor::f32(&[t], u),
                    Tensor::f32(&[self.n_docs, self.k], self.d_tab.clone()),
                    Tensor::f32(&[self.vs, self.k], b_slice.to_vec()),
                    Tensor::f32(&[self.k], s.to_vec()),
                ],
            )
            .expect("lda_push artifact");
        let mut it = out.into_iter();
        let z_new = it.next().unwrap().into_i32().unwrap();
        self.d_tab = it.next().unwrap().into_f32().unwrap();
        let b_new = it.next().unwrap().into_f32().unwrap();
        let s_new = it.next().unwrap().into_f32().unwrap();
        b_slice.copy_from_slice(&b_new);
        let bucket = &mut self.tokens[slice_id];
        for (tok, &zn) in bucket.iter_mut().zip(z_new.iter()) {
            tok.z = zn as u32;
        }
        (s_new, t, n_touched)
    }

    fn doc_loglik(&self) -> f64 {
        let k = self.k;
        let mut ll = 0.0f64;
        for d in 0..self.n_docs {
            let denom = self.doc_totals[d] + k as f32 * self.alpha;
            if denom <= 0.0 {
                continue;
            }
            for kk in 0..k {
                let c = self.d_tab[d * k + kk];
                if c > 0.0 {
                    ll += c as f64 * (((c + self.alpha) / denom) as f64).ln();
                }
            }
        }
        ll
    }

    fn model_bytes(&self) -> u64 {
        (self.d_tab.len() * 4 + self.k * 4) as u64
    }
}
