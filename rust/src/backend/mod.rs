//! Shard compute backends: the math inside **push**, behind a trait so the
//! coordinator is agnostic to where it runs.
//!
//! * [`native`] — sparse rust implementations (used for the model-size
//!   sweeps where shapes vary over orders of magnitude).
//! * `xla` (cargo feature `xla`) — the AOT three-layer path: fixed-shape
//!   HLO artifacts (JAX L2 + Pallas L1) executed via PJRT.  Used by the
//!   end-to-end examples and cross-checked against `native` in
//!   integration tests.

pub mod native;
/// AOT PJRT path — requires the `xla` crate (cargo feature `xla`).
#[cfg(feature = "xla")]
pub mod xla;

/// Which LDA sampling kernel a sweep runs (`RunConfig::sampler`, CLI
/// `--sampler exact|mh`).
///
/// * [`SamplerKind::Exact`] (default) — the collapsed-Gibbs running-CDF
///   scan: O(K) per token, bit-exact with every pre-sampler golden.
/// * [`SamplerKind::Mh`] — LightLDA-style Metropolis–Hastings with
///   alias-table proposals rebuilt at each slice lease: amortized O(1)
///   per token, same stationary distribution via stale-proposal
///   acceptance correction.  Rotation-only (the lease is the cache
///   boundary); drawn from a different RNG sub-stream, so mh runs are
///   deterministic per seed but fingerprint differently from exact runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplerKind {
    #[default]
    Exact,
    Mh,
}

impl SamplerKind {
    /// Canonical CLI / trace-header token.
    pub fn as_str(self) -> &'static str {
        match self {
            SamplerKind::Exact => "exact",
            SamplerKind::Mh => "mh",
        }
    }
}

impl std::fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for SamplerKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exact" => Ok(SamplerKind::Exact),
            "mh" => Ok(SamplerKind::Mh),
            other => Err(format!(
                "unknown sampler {other:?} (expected \"exact\" or \"mh\")"
            )),
        }
    }
}

/// Lasso shard compute (one worker's row shard).
pub trait LassoShard: Send {
    /// Partial correlations z_sel for the scheduled columns (paper eq. 6):
    /// z_j = x_j^T r + (x_j^T x_j)_shard · beta_j over this shard.
    fn partials(&mut self, sel: &[usize], beta_sel: &[f32]) -> Vec<f32>;
    /// Apply committed deltas: r -= X_sel · delta.
    fn apply_delta(&mut self, sel: &[usize], delta: &[f32]);
    /// Recompute the residual from scratch given the full beta (drift
    /// correction / initialization).
    fn reset_residual(&mut self, beta: &[f32]);
    /// Shard loss 0.5‖r‖².
    fn loss(&self) -> f64;
    /// Model-state resident bytes (residual + caches).
    fn model_bytes(&self) -> u64;
}

/// MF shard compute (one worker's user-row shard).
pub trait MfShard: Send {
    /// CCD stats for H row k over this shard: (a_j, b_j) per item column.
    fn h_stats(&mut self, k: usize) -> (Vec<f32>, Vec<f32>);
    /// Commit a new H row k (sync): updates local H copy and residuals.
    fn set_h_row(&mut self, k: usize, row: &[f32]);
    /// Locally update W column k (closed-form CCD) and residuals.  λ is
    /// fixed at shard construction.
    fn update_w(&mut self, k: usize);
    /// Shard loss Σ r² + λ‖W_shard‖².
    fn loss(&self) -> f64;
    /// Model bytes (W shard + H copy + residuals).
    fn model_bytes(&self) -> u64;
    /// Serialize the shard's full mutable state for a KV checkpoint
    /// (restore via [`MfShard::load_state`] is bit-exact).  Backends that
    /// never run under `--checkpoint-every` may keep the panicking default.
    fn save_state(&self) -> Vec<u8> {
        unimplemented!("this MfShard backend does not support checkpointing")
    }
    /// Restore state captured by [`MfShard::save_state`] into a shard
    /// built from the same immutable inputs.
    fn load_state(&mut self, _bytes: &[u8]) {
        unimplemented!("this MfShard backend does not support checkpointing")
    }
}

/// LDA shard compute (one worker's document shard).
pub trait LdaShard: Send {
    /// Gibbs-sweep all of this worker's tokens whose words fall in
    /// `slice_id`, mutating the provided B slice in place; returns the
    /// worker's final *local* copy of the topic sums s̃ (for s-error), the
    /// number of tokens sampled, and the number of distinct B rows touched
    /// (the KV-store traffic the network model charges).
    fn gibbs_slice(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s: &[f32],
    ) -> (Vec<f32>, usize, usize);
    /// In-place variant of [`LdaShard::gibbs_slice`] for the rotation hot
    /// path: `s_running` holds the worker's local topic sums on entry and
    /// is updated in place, so a multi-leg sweep reuses one buffer instead
    /// of allocating a fresh `Vec` per leg.  Returns (tokens sampled,
    /// distinct B rows touched).  Must draw the **same RNG sequence** as
    /// `gibbs_slice` — the sim-vs-threads bit-equality contract depends on
    /// it.  The default delegates (correct but allocating); native shards
    /// override allocation-free.
    fn gibbs_slice_into(
        &mut self,
        slice_id: usize,
        b_slice: &mut [f32],
        s_running: &mut Vec<f32>,
    ) -> (usize, usize) {
        let (s_local, n, touched) =
            self.gibbs_slice(slice_id, b_slice, s_running);
        *s_running = s_local;
        (n, touched)
    }
    /// Select the sampling kernel for subsequent sweeps.  The app stamps
    /// the negotiated choice into every task, so shards hear it before
    /// each leg under both backends.  Backends that only implement the
    /// exact kernel keep the default, which rejects `Mh` loudly instead
    /// of silently sampling a different chain.
    fn set_sampler(&mut self, kind: SamplerKind) {
        assert_eq!(
            kind,
            SamplerKind::Exact,
            "this LdaShard backend only implements the exact sampler"
        );
    }
    /// Document-side log-likelihood contribution.
    fn doc_loglik(&self) -> f64;
    /// Model bytes (doc-topic rows + local s copy).
    fn model_bytes(&self) -> u64;
    /// Serialize the shard's full mutable sampler state (topic
    /// assignments + RNG position) for a KV checkpoint; restore via
    /// [`LdaShard::load_state`] is bit-exact, so a resumed run draws the
    /// same Gibbs chain the uninterrupted run would have.  Backends that
    /// never run under `--checkpoint-every` may keep the panicking default.
    fn save_state(&self) -> Vec<u8> {
        unimplemented!("this LdaShard backend does not support checkpointing")
    }
    /// Restore state captured by [`LdaShard::save_state`] into a shard
    /// built from the same corpus shard.
    fn load_state(&mut self, _bytes: &[u8]) {
        unimplemented!("this LdaShard backend does not support checkpointing")
    }
}
