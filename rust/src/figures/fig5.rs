//! **Figure 5** — STRADS LDA s-error Δ_t per iteration (paper eq. 1).
//!
//! Paper result: Δ_t ≤ 0.002 throughout on Wikipedia unigrams with K=5000
//! and 64 machines — parallel Gibbs over rotation-disjoint word slices is
//! nearly exact.

use crate::coordinator::RunConfig;
use crate::figures::common::{figure_corpus, lda_engine, print_table};

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig5Config {
    pub vocab: usize,
    pub n_docs: usize,
    pub n_topics: usize,
    pub n_workers: usize,
    pub iterations: u64,
    pub seed: u64,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config {
            vocab: 20_000,
            n_docs: 2_000,
            n_topics: 100,
            n_workers: 16,
            iterations: 30,
            seed: 42,
        }
    }
}

/// Run and return Δ_t per iteration.
pub fn run(cfg: &Fig5Config) -> Vec<f64> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    let run_cfg = RunConfig::default();
    let mut engine =
        lda_engine(&corpus, cfg.n_topics, cfg.n_workers, cfg.seed, &run_cfg);
    for r in 0..cfg.iterations {
        engine.round(r);
    }
    engine.app().s_error_history.clone()
}

/// Print the series.
pub fn print(series: &[f64]) {
    print_table(
        "Figure 5: STRADS LDA s-error per iteration",
        &["iter", "s_error"],
        &series
            .iter()
            .enumerate()
            .map(|(i, d)| vec![i.to_string(), format!("{d:.6}")])
            .collect::<Vec<_>>(),
    );
    let max = series.iter().cloned().fold(0.0, f64::max);
    println!("  max Δ_t = {max:.6}  (paper: ≤ 0.002 at its scale)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_error_is_tiny_and_bounded() {
        let series = run(&Fig5Config {
            vocab: 2_000,
            n_docs: 300,
            n_topics: 20,
            n_workers: 8,
            iterations: 10,
            seed: 3,
        });
        assert_eq!(series.len(), 10);
        for &d in &series {
            assert!((0.0..=2.0).contains(&d), "Δ_t out of range: {d}");
            assert!(d < 0.05, "Δ_t unexpectedly large: {d}");
        }
    }
}
