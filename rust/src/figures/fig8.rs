//! **Figure 8** — Convergence time versus model size (three panels):
//!
//! * left:   LDA, STRADS vs YahooLDA, sweeping topic count;
//! * center: MF, STRADS CCD vs GraphLab-style ALS, sweeping rank;
//! * right:  Lasso, STRADS dynamic scheduling vs Lasso-RR, sweeping J.
//!
//! Paper result: STRADS reaches larger model sizes (baselines DNF from
//! memory or divergence) and converges faster.  Bars are omitted when a
//! method does not reach 98% of STRADS's convergence point — we report
//! DNF the same way.

use crate::baselines::{AlsConfig, AlsMf, YahooLda, YahooLdaConfig};
use crate::cluster::NetworkConfig;
use crate::coordinator::RunConfig;
use crate::datagen::mf_ratings::{self, MfGenConfig};
use crate::figures::common::{
    figure_corpus, lasso_engine_corr, lda_engine, mf_engine, print_table,
};

/// One bar of a panel: virtual seconds to the shared target, or DNF.
#[derive(Debug, Clone)]
pub struct Bar {
    pub model_size: String,
    pub strads_secs: Option<f64>,
    pub baseline_secs: Option<f64>,
    pub baseline_dnf_reason: Option<String>,
}

fn fmt(bar: &Option<f64>, dnf: &Option<String>) -> String {
    match bar {
        Some(s) => format!("{s:.3}s"),
        None => format!(
            "DNF{}",
            dnf.as_ref().map(|r| format!(" ({r})")).unwrap_or_default()
        ),
    }
}

/// Print one panel.
pub fn print_panel(title: &str, baseline_name: &str, bars: &[Bar]) {
    print_table(
        title,
        &["model size", "STRADS", baseline_name],
        &bars
            .iter()
            .map(|b| {
                vec![
                    b.model_size.clone(),
                    fmt(&b.strads_secs, &None),
                    fmt(&b.baseline_secs, &b.baseline_dnf_reason),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

// ------------------------------------------------------------ LDA panel --

/// LDA panel parameters.
#[derive(Debug, Clone)]
pub struct LdaPanelConfig {
    pub vocab: usize,
    pub n_docs: usize,
    pub topic_counts: Vec<usize>,
    pub n_workers: usize,
    pub sweeps: u64,
    /// Per-machine memory capacity; chosen so the largest model exceeds a
    /// full YahooLDA replica but not a STRADS partition.
    pub mem_capacity: Option<u64>,
    pub seed: u64,
}

impl Default for LdaPanelConfig {
    fn default() -> Self {
        LdaPanelConfig {
            vocab: 20_000,
            n_docs: 2_000,
            topic_counts: vec![50, 100, 200, 400],
            n_workers: 8,
            sweeps: 30,
            mem_capacity: None,
            seed: 42,
        }
    }
}

/// Run the LDA panel.
pub fn run_lda(cfg: &LdaPanelConfig) -> Vec<Bar> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    // default capacity: 1.2× a full word-topic replica at *half* the
    // largest model — YahooLDA fits the small/mid sizes but hits the wall
    // at the top, exactly the paper's "could only handle 5K topics" story;
    // STRADS partitions are 1/P of that and never come close.
    let cap = cfg.mem_capacity.unwrap_or_else(|| {
        let k_max = *cfg.topic_counts.iter().max().unwrap();
        (cfg.vocab * (k_max / 2) * 4 * 6 / 5) as u64
            + (cfg.n_docs * k_max * 4 / cfg.n_workers) as u64
    });
    let mut bars = Vec::new();
    for &k in &cfg.topic_counts {
        // STRADS run
        let run_cfg = RunConfig {
            max_rounds: cfg.sweeps * cfg.n_workers as u64,
            eval_every: cfg.n_workers as u64,
            network: NetworkConfig::gbps1(),
            mem_capacity: Some(cap),
            label: format!("strads-lda-k{k}"),
            ..Default::default()
        };
        let mut strads =
            lda_engine(&corpus, k, cfg.n_workers, cfg.seed, &run_cfg);
        let strads_res = strads.run(&run_cfg);
        // target: 98% of the way from initial LL to STRADS's final LL
        let first = strads_res.recorder.points()[0].objective;
        let last = strads_res.final_objective;
        let target = first + 0.98 * (last - first);
        let strads_secs = strads_res.recorder.time_to_target(target, false);

        // YahooLDA run under the same capacity
        let mut yahoo = YahooLda::new(
            &corpus,
            YahooLdaConfig {
                n_topics: k,
                alpha: 0.1,
                gamma: 0.01,
                n_workers: cfg.n_workers,
                seed: cfg.seed,
            },
            NetworkConfig::gbps1(),
            Some(cap),
        );
        // the baseline gets 3× the sweeps: the paper's comparison is
        // time-to-quality, not fixed iterations — slower but converging
        // baselines should show a time, not a DNF
        let (yrec, yoom) =
            yahoo.run(cfg.sweeps * 3, &format!("yahoo-lda-k{k}"));
        let (baseline_secs, reason) = if let Some(oom) = yoom {
            (None, Some(format!("OOM: {oom}")))
        } else {
            match yrec.time_to_target(target, false) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };

        bars.push(Bar {
            model_size: format!("K={k} (V*K={})", cfg.vocab * k),
            strads_secs,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

// ------------------------------------------------------------- MF panel --

/// MF panel parameters.
#[derive(Debug, Clone)]
pub struct MfPanelConfig {
    pub users: usize,
    pub items: usize,
    pub ranks: Vec<usize>,
    pub n_workers: usize,
    pub sweeps: u64,
    pub lambda: f32,
    pub mem_capacity: Option<u64>,
    pub seed: u64,
}

impl Default for MfPanelConfig {
    fn default() -> Self {
        MfPanelConfig {
            users: 2_000,
            items: 1_500,
            ranks: vec![20, 40, 80, 160],
            n_workers: 8,
            sweeps: 12,
            lambda: 0.05,
            mem_capacity: None,
            seed: 42,
        }
    }
}

/// Run the MF panel.
pub fn run_mf(cfg: &MfPanelConfig) -> Vec<Bar> {
    // capacity: 1.5× STRADS's per-machine share at the largest rank —
    // full-factor ALS replication blows through it at high rank
    let k_max = *cfg.ranks.iter().max().unwrap();
    let cap = cfg.mem_capacity.unwrap_or_else(|| {
        let strads_share = (cfg.users / cfg.n_workers + cfg.items) * k_max * 4;
        (strads_share * 3 / 2) as u64
    });
    let mut bars = Vec::new();
    for &rank in &cfg.ranks {
        let run_cfg = RunConfig {
            max_rounds: cfg.sweeps * 2 * rank as u64,
            eval_every: 2 * rank as u64,
            network: NetworkConfig::gbps40(),
            mem_capacity: Some(cap),
            label: format!("strads-mf-k{rank}"),
            ..Default::default()
        };
        let mut strads = mf_engine(
            cfg.users,
            cfg.items,
            rank,
            cfg.n_workers,
            cfg.lambda,
            cfg.seed,
            &run_cfg,
        );
        let res = strads.run(&run_cfg);
        let first = res.recorder.points()[0].objective;
        let last = res.final_objective;
        let target = first - 0.98 * (first - last);
        let strads_secs = res.recorder.time_to_target(target, true);

        // ALS baseline
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: cfg.users,
            n_items: cfg.items,
            density: 0.012,
            true_rank: 8.min(rank),
            seed: cfg.seed,
            ..Default::default()
        });
        let mut als = AlsMf::new(
            &data.a,
            AlsConfig {
                rank,
                lambda: cfg.lambda,
                n_workers: cfg.n_workers,
                seed: cfg.seed,
            },
            NetworkConfig::gbps40(),
            Some(cap),
        );
        let (arec, aoom) =
            als.run(cfg.sweeps * 3, &format!("als-mf-k{rank}"));
        let (baseline_secs, reason) = if let Some(oom) = aoom {
            (None, Some(format!("OOM: {oom}")))
        } else {
            match arec.time_to_target(target, true) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };
        bars.push(Bar {
            model_size: format!("rank={rank}"),
            strads_secs,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

// ---------------------------------------------------------- Lasso panel --

/// Lasso panel parameters.
#[derive(Debug, Clone)]
pub struct LassoPanelConfig {
    pub n_samples: usize,
    pub feature_counts: Vec<usize>,
    pub n_workers: usize,
    pub u: usize,
    pub rounds: u64,
    pub lambda: f32,
    pub seed: u64,
}

impl Default for LassoPanelConfig {
    fn default() -> Self {
        // the paper's regime: J >> n (overcomplete), sparse solution, U
        // concurrent updates large enough that unfiltered random
        // co-scheduling hits correlated columns (Bradley et al.'s
        // divergence condition)
        LassoPanelConfig {
            n_samples: 256,
            feature_counts: vec![8_192, 16_384, 32_768, 65_536],
            n_workers: 8,
            u: 32,
            rounds: 600,
            lambda: 0.08,
            seed: 42,
        }
    }
}

/// Run the Lasso panel (STRADS priority vs Lasso-RR random).
pub fn run_lasso(cfg: &LassoPanelConfig) -> Vec<Bar> {
    let mut bars = Vec::new();
    for &j in &cfg.feature_counts {
        let run_cfg = RunConfig {
            max_rounds: cfg.rounds,
            eval_every: (cfg.rounds / 20).max(1),
            network: NetworkConfig::gbps40(),
            label: format!("strads-lasso-j{j}"),
            ..Default::default()
        };
        let (mut strads, _) = lasso_engine_corr(
            cfg.n_samples,
            j,
            cfg.n_workers,
            cfg.u,
            true,
            cfg.lambda,
            0.9,
            cfg.seed,
            &run_cfg,
        );
        let res = strads.run(&run_cfg);
        let first = res.recorder.points()[0].objective;
        let last = res.final_objective;
        let target = first - 0.98 * (first - last);
        let strads_secs = res.recorder.time_to_target(target, true);

        let rr_cfg = RunConfig {
            label: format!("lasso-rr-j{j}"),
            ..run_cfg.clone()
        };
        let (mut rr, _) = lasso_engine_corr(
            cfg.n_samples,
            j,
            cfg.n_workers,
            cfg.u,
            false,
            cfg.lambda,
            0.9,
            cfg.seed,
            &rr_cfg,
        );
        let rres = rr.run(&rr_cfg);
        let (baseline_secs, reason) = if !rres.final_objective.is_finite() {
            (None, Some("diverged (correlated co-updates)".into()))
        } else {
            match rres.recorder.time_to_target(target, true) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };
        bars.push(Bar {
            model_size: format!("J={j}"),
            strads_secs,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lda_panel_strads_reaches_target() {
        let bars = run_lda(&LdaPanelConfig {
            vocab: 1_500,
            n_docs: 150,
            topic_counts: vec![8, 16],
            n_workers: 4,
            sweeps: 6,
            seed: 2,
            mem_capacity: None,
        });
        assert_eq!(bars.len(), 2);
        for b in &bars {
            assert!(b.strads_secs.is_some(), "{b:?}");
        }
    }

    #[test]
    fn mf_panel_als_dnfs_at_large_rank() {
        // Netflix-like regime: users >> items, so ALS's full W replication
        // dwarfs STRADS's per-machine share (W shard + H copy).
        let bars = run_mf(&MfPanelConfig {
            users: 600,
            items: 60,
            ranks: vec![4, 32],
            n_workers: 4,
            sweeps: 4,
            seed: 2,
            ..Default::default()
        });
        // capacity is sized from the largest rank's STRADS share; ALS
        // replicates both factors and should blow it at rank 32
        assert!(bars[1].baseline_secs.is_none(), "{bars:?}");
        assert!(bars[1].strads_secs.is_some(), "{bars:?}");
    }

    #[test]
    fn lasso_panel_random_fails_or_lags() {
        let bars = run_lasso(&LassoPanelConfig {
            n_samples: 128,
            feature_counts: vec![2048],
            n_workers: 2,
            u: 16,
            rounds: 150,
            lambda: 0.08,
            seed: 2,
        });
        let b = &bars[0];
        assert!(b.strads_secs.is_some(), "{b:?}");
        // random either diverges (DNF) or is slower than STRADS
        match (b.strads_secs, b.baseline_secs) {
            (Some(s), Some(r)) => assert!(s <= r * 1.5, "{b:?}"),
            (Some(_), None) => {}
            _ => panic!("{b:?}"),
        }
    }
}
