//! **Figure 8** — Convergence time versus model size (three panels):
//!
//! * left:   LDA, STRADS vs YahooLDA, sweeping topic count;
//! * center: MF, STRADS CCD vs GraphLab-style ALS, sweeping rank;
//! * right:  Lasso, STRADS dynamic scheduling vs Lasso-RR, sweeping J.
//!
//! Paper result: STRADS reaches larger model sizes (baselines DNF from
//! memory or divergence) and converges faster.  Bars are omitted when a
//! method does not reach 98% of STRADS's convergence point — we report
//! DNF the same way.

use crate::apps::lda::{setup as lda_setup, BSlice};
use crate::backend::SamplerKind;
use crate::baselines::{AlsConfig, AlsMf, YahooLda, YahooLdaConfig};
use crate::cluster::NetworkConfig;
use crate::coordinator::RunConfig;
use crate::datagen::mf_ratings::{self, MfGenConfig};
use crate::datagen::Corpus;
use crate::figures::common::{
    figure_corpus, lasso_engine_corr, lda_engine, mf_engine, print_table,
};
use std::time::Instant;

/// One bar of a panel: virtual seconds to the shared target, or DNF.
#[derive(Debug, Clone)]
pub struct Bar {
    pub model_size: String,
    pub strads_secs: Option<f64>,
    /// DNF reason for the STRADS side (e.g. the run recorded no eval
    /// points, so there is no convergence target at all).
    pub strads_dnf_reason: Option<String>,
    pub baseline_secs: Option<f64>,
    pub baseline_dnf_reason: Option<String>,
}

/// Both sides DNF because the STRADS run recorded no eval points — there
/// is no target to measure either method against.  Returned instead of
/// indexing `points()[0]` (which panicked when `eval_every` exceeded
/// `max_rounds`, a config any small smoke sweep can produce).
fn no_target_bar(model_size: String) -> Bar {
    Bar {
        model_size,
        strads_secs: None,
        strads_dnf_reason: Some(
            "no eval points recorded (eval_every exceeds max_rounds?)".into(),
        ),
        baseline_secs: None,
        baseline_dnf_reason: Some("no STRADS target to compare against".into()),
    }
}

fn fmt(bar: &Option<f64>, dnf: &Option<String>) -> String {
    match bar {
        Some(s) => format!("{s:.3}s"),
        None => format!(
            "DNF{}",
            dnf.as_ref().map(|r| format!(" ({r})")).unwrap_or_default()
        ),
    }
}

/// Print one panel.
pub fn print_panel(title: &str, baseline_name: &str, bars: &[Bar]) {
    print_table(
        title,
        &["model size", "STRADS", baseline_name],
        &bars
            .iter()
            .map(|b| {
                vec![
                    b.model_size.clone(),
                    fmt(&b.strads_secs, &b.strads_dnf_reason),
                    fmt(&b.baseline_secs, &b.baseline_dnf_reason),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

// ------------------------------------------------------------ LDA panel --

/// LDA panel parameters.
#[derive(Debug, Clone)]
pub struct LdaPanelConfig {
    pub vocab: usize,
    pub n_docs: usize,
    pub topic_counts: Vec<usize>,
    pub n_workers: usize,
    pub sweeps: u64,
    /// Per-machine memory capacity; chosen so the largest model exceeds a
    /// full YahooLDA replica but not a STRADS partition.
    pub mem_capacity: Option<u64>,
    pub seed: u64,
}

impl Default for LdaPanelConfig {
    fn default() -> Self {
        LdaPanelConfig {
            vocab: 20_000,
            n_docs: 2_000,
            topic_counts: vec![50, 100, 200, 400],
            n_workers: 8,
            sweeps: 30,
            mem_capacity: None,
            seed: 42,
        }
    }
}

/// Default LDA panel memory capacity: 1.2× a full word-topic replica at
/// *half* the largest model, plus one worker's doc-topic share — YahooLDA
/// fits the small/mid sizes but hits the wall at the top, exactly the
/// paper's "could only handle 5K topics" story; STRADS partitions are 1/P
/// of that and never come close.
///
/// Computed in f64 with a single final round: the old integer pipeline
/// truncated `k_max / 2` (an odd K silently dropped half a replica row
/// from the budget) and its `vocab * k * 4 * 6` intermediate overflows
/// 32-bit `usize` well before the big-model operating point (500K vocab).
pub fn lda_default_capacity(
    vocab: usize,
    k_max: usize,
    n_docs: usize,
    n_workers: usize,
) -> u64 {
    let replica_half = vocab as f64 * (k_max as f64 / 2.0) * 4.0 * 1.2;
    let doc_share = n_docs as f64 * k_max as f64 * 4.0 / n_workers as f64;
    (replica_half + doc_share).round() as u64
}

/// Run the LDA panel.
pub fn run_lda(cfg: &LdaPanelConfig) -> Vec<Bar> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    let cap = cfg.mem_capacity.unwrap_or_else(|| {
        let k_max = *cfg.topic_counts.iter().max().unwrap();
        lda_default_capacity(cfg.vocab, k_max, cfg.n_docs, cfg.n_workers)
    });
    let mut bars = Vec::new();
    for &k in &cfg.topic_counts {
        // STRADS run
        let run_cfg = RunConfig {
            max_rounds: cfg.sweeps * cfg.n_workers as u64,
            eval_every: cfg.n_workers as u64,
            network: NetworkConfig::gbps1(),
            mem_capacity: Some(cap),
            label: format!("strads-lda-k{k}"),
            ..Default::default()
        };
        let mut strads =
            lda_engine(&corpus, k, cfg.n_workers, cfg.seed, &run_cfg);
        let strads_res = strads.run(&run_cfg);
        // target: 98% of the way from initial LL to STRADS's final LL
        let first = match strads_res.recorder.points().first() {
            Some(p) => p.objective,
            None => {
                bars.push(no_target_bar(format!(
                    "K={k} (V*K={})",
                    cfg.vocab * k
                )));
                continue;
            }
        };
        let last = strads_res.final_objective;
        let target = first + 0.98 * (last - first);
        let strads_secs = strads_res.recorder.time_to_target(target, false);

        // YahooLDA run under the same capacity
        let mut yahoo = YahooLda::new(
            &corpus,
            YahooLdaConfig {
                n_topics: k,
                alpha: 0.1,
                gamma: 0.01,
                n_workers: cfg.n_workers,
                seed: cfg.seed,
            },
            NetworkConfig::gbps1(),
            Some(cap),
        );
        // the baseline gets 3× the sweeps: the paper's comparison is
        // time-to-quality, not fixed iterations — slower but converging
        // baselines should show a time, not a DNF
        let (yrec, yoom) =
            yahoo.run(cfg.sweeps * 3, &format!("yahoo-lda-k{k}"));
        let (baseline_secs, reason) = if let Some(oom) = yoom {
            (None, Some(format!("OOM: {oom}")))
        } else {
            match yrec.time_to_target(target, false) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };

        bars.push(Bar {
            model_size: format!("K={k} (V*K={})", cfg.vocab * k),
            strads_secs,
            strads_dnf_reason: None,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

// ------------------------------------------------------------- MF panel --

/// MF panel parameters.
#[derive(Debug, Clone)]
pub struct MfPanelConfig {
    pub users: usize,
    pub items: usize,
    pub ranks: Vec<usize>,
    pub n_workers: usize,
    pub sweeps: u64,
    pub lambda: f32,
    pub mem_capacity: Option<u64>,
    pub seed: u64,
}

impl Default for MfPanelConfig {
    fn default() -> Self {
        MfPanelConfig {
            users: 2_000,
            items: 1_500,
            ranks: vec![20, 40, 80, 160],
            n_workers: 8,
            sweeps: 12,
            lambda: 0.05,
            mem_capacity: None,
            seed: 42,
        }
    }
}

/// Run the MF panel.
pub fn run_mf(cfg: &MfPanelConfig) -> Vec<Bar> {
    // capacity: 1.5× STRADS's per-machine share at the largest rank —
    // full-factor ALS replication blows through it at high rank
    let k_max = *cfg.ranks.iter().max().unwrap();
    let cap = cfg.mem_capacity.unwrap_or_else(|| {
        let strads_share = (cfg.users / cfg.n_workers + cfg.items) * k_max * 4;
        (strads_share * 3 / 2) as u64
    });
    let mut bars = Vec::new();
    for &rank in &cfg.ranks {
        let run_cfg = RunConfig {
            max_rounds: cfg.sweeps * 2 * rank as u64,
            eval_every: 2 * rank as u64,
            network: NetworkConfig::gbps40(),
            mem_capacity: Some(cap),
            label: format!("strads-mf-k{rank}"),
            ..Default::default()
        };
        let mut strads = mf_engine(
            cfg.users,
            cfg.items,
            rank,
            cfg.n_workers,
            cfg.lambda,
            cfg.seed,
            &run_cfg,
        );
        let res = strads.run(&run_cfg);
        let first = match res.recorder.points().first() {
            Some(p) => p.objective,
            None => {
                bars.push(no_target_bar(format!("rank={rank}")));
                continue;
            }
        };
        let last = res.final_objective;
        let target = first - 0.98 * (first - last);
        let strads_secs = res.recorder.time_to_target(target, true);

        // ALS baseline
        let data = mf_ratings::generate(&MfGenConfig {
            n_users: cfg.users,
            n_items: cfg.items,
            density: 0.012,
            true_rank: 8.min(rank),
            seed: cfg.seed,
            ..Default::default()
        });
        let mut als = AlsMf::new(
            &data.a,
            AlsConfig {
                rank,
                lambda: cfg.lambda,
                n_workers: cfg.n_workers,
                seed: cfg.seed,
            },
            NetworkConfig::gbps40(),
            Some(cap),
        );
        let (arec, aoom) =
            als.run(cfg.sweeps * 3, &format!("als-mf-k{rank}"));
        let (baseline_secs, reason) = if let Some(oom) = aoom {
            (None, Some(format!("OOM: {oom}")))
        } else {
            match arec.time_to_target(target, true) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };
        bars.push(Bar {
            model_size: format!("rank={rank}"),
            strads_secs,
            strads_dnf_reason: None,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

// ---------------------------------------------------------- Lasso panel --

/// Lasso panel parameters.
#[derive(Debug, Clone)]
pub struct LassoPanelConfig {
    pub n_samples: usize,
    pub feature_counts: Vec<usize>,
    pub n_workers: usize,
    pub u: usize,
    pub rounds: u64,
    pub lambda: f32,
    pub seed: u64,
}

impl Default for LassoPanelConfig {
    fn default() -> Self {
        // the paper's regime: J >> n (overcomplete), sparse solution, U
        // concurrent updates large enough that unfiltered random
        // co-scheduling hits correlated columns (Bradley et al.'s
        // divergence condition)
        LassoPanelConfig {
            n_samples: 256,
            feature_counts: vec![8_192, 16_384, 32_768, 65_536],
            n_workers: 8,
            u: 32,
            rounds: 600,
            lambda: 0.08,
            seed: 42,
        }
    }
}

/// Run the Lasso panel (STRADS priority vs Lasso-RR random).
pub fn run_lasso(cfg: &LassoPanelConfig) -> Vec<Bar> {
    let mut bars = Vec::new();
    for &j in &cfg.feature_counts {
        let run_cfg = RunConfig {
            max_rounds: cfg.rounds,
            eval_every: (cfg.rounds / 20).max(1),
            network: NetworkConfig::gbps40(),
            label: format!("strads-lasso-j{j}"),
            ..Default::default()
        };
        let (mut strads, _) = lasso_engine_corr(
            cfg.n_samples,
            j,
            cfg.n_workers,
            cfg.u,
            true,
            cfg.lambda,
            0.9,
            cfg.seed,
            &run_cfg,
        );
        let res = strads.run(&run_cfg);
        let first = match res.recorder.points().first() {
            Some(p) => p.objective,
            None => {
                bars.push(no_target_bar(format!("J={j}")));
                continue;
            }
        };
        let last = res.final_objective;
        let target = first - 0.98 * (first - last);
        let strads_secs = res.recorder.time_to_target(target, true);

        let rr_cfg = RunConfig {
            label: format!("lasso-rr-j{j}"),
            ..run_cfg.clone()
        };
        let (mut rr, _) = lasso_engine_corr(
            cfg.n_samples,
            j,
            cfg.n_workers,
            cfg.u,
            false,
            cfg.lambda,
            0.9,
            cfg.seed,
            &rr_cfg,
        );
        let rres = rr.run(&rr_cfg);
        let (baseline_secs, reason) = if !rres.final_objective.is_finite() {
            (None, Some("diverged (correlated co-updates)".into()))
        } else {
            match rres.recorder.time_to_target(target, true) {
                Some(s) => (Some(s), None),
                None => (None, Some("did not reach target".into())),
            }
        };
        bars.push(Bar {
            model_size: format!("J={j}"),
            strads_secs,
            strads_dnf_reason: None,
            baseline_secs,
            baseline_dnf_reason: reason,
        });
    }
    bars
}

// -------------------------------------------------- sampler-scaling arm --

/// Sampler-scaling arm parameters (the big-model fig8 extension): measure
/// wall-clock ns per sampled token for the exact O(K) kernel vs the
/// alias/MH O(1) kernel as K grows, at a vocabulary large enough that the
/// word-topic model dwarfs the corpus (the LightLDA regime — most words
/// are rare, so an O(K)-per-token kernel pays the full topic count on
/// every draw while MH pays the word's own occupancy).
#[derive(Debug, Clone)]
pub struct SamplerScalingConfig {
    pub vocab: usize,
    pub n_docs: usize,
    /// Topic counts to sweep (the flatness ratio compares last vs first).
    pub topic_counts: Vec<usize>,
    /// Rotation slices U; the per-slice sweep is the lease unit the MH
    /// caches live inside.
    pub n_slices: usize,
    /// Timed full sweeps per (kernel, K) point, after one warmup sweep.
    pub sweeps: u64,
    pub seed: u64,
}

impl Default for SamplerScalingConfig {
    fn default() -> Self {
        // the big-model operating point: 500K vocab, modest corpus
        SamplerScalingConfig {
            vocab: 500_000,
            n_docs: 4_000,
            topic_counts: vec![50, 400],
            n_slices: 8,
            sweeps: 3,
            seed: 42,
        }
    }
}

/// One (kernel, K) measurement of the scaling arm.
#[derive(Debug, Clone)]
pub struct SamplerScalingPoint {
    pub k: usize,
    pub exact_ns_per_token: f64,
    pub mh_ns_per_token: f64,
}

/// Time one kernel at one K: single worker, U slices, wall-clock over
/// whole sweeps driven straight through `gibbs_slice_into` (the rotation
/// hot path, minus the engine so the measurement is pure sampling).
fn time_sampler(
    corpus: &Corpus,
    k: usize,
    cfg: &SamplerScalingConfig,
    kind: SamplerKind,
) -> f64 {
    let lda_setup::LdaSetup { app, mut shards } = lda_setup::build_sliced(
        corpus,
        k,
        1,
        cfg.n_slices,
        None,
        0.1,
        0.01,
        cfg.seed,
    );
    let mut slices: Vec<BSlice> = (0..cfg.n_slices)
        .map(|a| app.peek_slice(a).expect("slice checked in").clone())
        .collect();
    let mut s_running = app.s.clone();
    // at the big-model point the word-topic state is the memory bill:
    // drop the coordinator's copy before sweeping
    drop(app);
    let shard = &mut shards[0];
    shard.set_sampler(kind);
    // warmup: first-touch page faults + the MH index builds happen here
    for (a, slice) in slices.iter_mut().enumerate() {
        shard.gibbs_slice_into(a, &mut slice.counts, &mut s_running);
    }
    let mut n_tokens = 0usize;
    let start = Instant::now();
    for _ in 0..cfg.sweeps.max(1) {
        for (a, slice) in slices.iter_mut().enumerate() {
            let (n, _) =
                shard.gibbs_slice_into(a, &mut slice.counts, &mut s_running);
            n_tokens += n;
        }
    }
    start.elapsed().as_nanos() as f64 / n_tokens.max(1) as f64
}

/// Run the sampler-scaling arm: one [`SamplerScalingPoint`] per K, both
/// kernels on the identical corpus and initialization.
pub fn run_sampler_scaling(
    cfg: &SamplerScalingConfig,
) -> Vec<SamplerScalingPoint> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    cfg.topic_counts
        .iter()
        .map(|&k| SamplerScalingPoint {
            k,
            exact_ns_per_token: time_sampler(
                &corpus,
                k,
                cfg,
                SamplerKind::Exact,
            ),
            mh_ns_per_token: time_sampler(&corpus, k, cfg, SamplerKind::Mh),
        })
        .collect()
}

/// Print the scaling arm.
pub fn print_sampler_scaling(points: &[SamplerScalingPoint]) {
    print_table(
        "fig8 sampler scaling (ns per sampled token)",
        &["K", "exact", "mh"],
        &points
            .iter()
            .map(|p| {
                vec![
                    format!("{}", p.k),
                    format!("{:.1}", p.exact_ns_per_token),
                    format!("{:.1}", p.mh_ns_per_token),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lda_panel_strads_reaches_target() {
        let bars = run_lda(&LdaPanelConfig {
            vocab: 1_500,
            n_docs: 150,
            topic_counts: vec![8, 16],
            n_workers: 4,
            sweeps: 6,
            seed: 2,
            mem_capacity: None,
        });
        assert_eq!(bars.len(), 2);
        for b in &bars {
            assert!(b.strads_secs.is_some(), "{b:?}");
        }
    }

    #[test]
    fn mf_panel_als_dnfs_at_large_rank() {
        // Netflix-like regime: users >> items, so ALS's full W replication
        // dwarfs STRADS's per-machine share (W shard + H copy).
        let bars = run_mf(&MfPanelConfig {
            users: 600,
            items: 60,
            ranks: vec![4, 32],
            n_workers: 4,
            sweeps: 4,
            seed: 2,
            ..Default::default()
        });
        // capacity is sized from the largest rank's STRADS share; ALS
        // replicates both factors and should blow it at rank 32
        assert!(bars[1].baseline_secs.is_none(), "{bars:?}");
        assert!(bars[1].strads_secs.is_some(), "{bars:?}");
    }

    #[test]
    fn default_capacity_matches_the_established_operating_point() {
        // the value the integer formula produced at the classic even-K
        // point: 6000·64·4·6/5 + 2000·128·4/8 = 1_843_200 + 128_000
        assert_eq!(lda_default_capacity(6_000, 128, 2_000, 8), 1_971_200);
    }

    #[test]
    fn default_capacity_does_not_truncate_odd_topic_counts() {
        // odd K: the integer form truncated k/2 and lost half a replica
        // row; the f64 form keeps it.  127/2 → 63.5 rows' worth of bytes.
        let odd = lda_default_capacity(6_000, 127, 2_000, 8);
        let expect = (6_000.0 * 63.5 * 4.0 * 1.2
            + 2_000.0 * 127.0 * 4.0 / 8.0)
            .round() as u64;
        assert_eq!(odd, expect);
        // and it sits strictly between the truncated and rounded-up
        // integer neighbours
        assert!(odd > lda_default_capacity(6_000, 126, 2_000, 8));
        assert!(odd < lda_default_capacity(6_000, 128, 2_000, 8));
    }

    #[test]
    fn default_capacity_is_exact_at_the_big_model_point() {
        // 500K vocab × K=400: 500_000·200·4·1.2 + 4_000·400·4/8
        // (the 32-bit-unsafe regime the f64 pipeline exists for)
        assert_eq!(
            lda_default_capacity(500_000, 400, 4_000, 8),
            480_000_000 + 800_000
        );
    }

    #[test]
    fn no_target_bar_is_a_double_dnf_and_prints() {
        let bar = no_target_bar("K=4".into());
        assert!(bar.strads_secs.is_none());
        assert!(bar.baseline_secs.is_none());
        assert!(
            bar.strads_dnf_reason
                .as_deref()
                .unwrap_or_default()
                .contains("no eval points"),
            "{bar:?}"
        );
        // the table formatter renders both DNF columns without panicking
        print_panel("fig8 dnf smoke", "baseline", &[bar]);
    }

    #[test]
    fn sampler_scaling_arm_reports_positive_timings() {
        // tiny smoke shape: the flatness assertion itself lives in the
        // bench (timing ratios are not stable enough for unit CI)
        let points = run_sampler_scaling(&SamplerScalingConfig {
            vocab: 600,
            n_docs: 60,
            topic_counts: vec![8, 16],
            n_slices: 4,
            sweeps: 1,
            seed: 3,
        });
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.exact_ns_per_token > 0.0, "{p:?}");
            assert!(p.mh_ns_per_token > 0.0, "{p:?}");
        }
    }

    #[test]
    fn lasso_panel_random_fails_or_lags() {
        let bars = run_lasso(&LassoPanelConfig {
            n_samples: 128,
            feature_counts: vec![2048],
            n_workers: 2,
            u: 16,
            rounds: 150,
            lambda: 0.08,
            seed: 2,
        });
        let b = &bars[0];
        assert!(b.strads_secs.is_some(), "{b:?}");
        // random either diverges (DNF) or is slower than STRADS
        match (b.strads_secs, b.baseline_secs) {
            (Some(s), Some(r)) => assert!(s <= r * 1.5, "{b:?}"),
            (Some(_), None) => {}
            _ => panic!("{b:?}"),
        }
    }
}
