//! **Figure 9** — Convergence trajectories (objective vs time) for LDA,
//! MF, and Lasso, STRADS vs the corresponding baseline.
//!
//! Paper result: STRADS dominates each trajectory; the Lasso panel shows
//! the dynamic schedule's objective "plunging" to the optimum while
//! Lasso-RR crawls.

use crate::baselines::{AlsConfig, AlsMf, YahooLda, YahooLdaConfig};
use crate::cluster::{
    HandoffJitter, NetFaultPlan, NetworkConfig, StragglerModel,
};
use crate::coordinator::{
    BackendKind, ExecutionMode, QueueOrder, RunConfig, TraceMode,
};
use crate::datagen::mf_ratings::{self, MfGenConfig};
use crate::figures::common::{
    figure_corpus, lasso_engine_corr, lda_engine, lda_engine_sliced,
    lda_engine_sliced_targets, mf_block_engine, mf_engine, mf_engine_dense,
};
use crate::metrics::Recorder;

/// A labelled pair of trajectories for one panel.
pub struct Panel {
    pub title: String,
    pub strads: Recorder,
    pub baseline: Recorder,
}

/// Scale knob shared by the three panels.
#[derive(Debug, Clone)]
pub struct Fig9Config {
    pub scale: f64,
    pub n_workers: usize,
    pub seed: u64,
}

impl Default for Fig9Config {
    fn default() -> Self {
        Fig9Config { scale: 1.0, n_workers: 8, seed: 42 }
    }
}

fn sc(base: usize, scale: f64) -> usize {
    ((base as f64 * scale) as usize).max(8)
}

/// LDA trajectories: STRADS vs YahooLDA.
pub fn run_lda(cfg: &Fig9Config) -> Panel {
    let corpus =
        figure_corpus(sc(10_000, cfg.scale), sc(1_000, cfg.scale), cfg.seed);
    let k = sc(64, cfg.scale);
    let sweeps = 20u64;
    let run_cfg = RunConfig::builder()
        .max_rounds(sweeps * cfg.n_workers as u64)
        .eval_every(cfg.n_workers as u64)
        .network(NetworkConfig::gbps1())
        .label("STRADS-LDA")
        .build()
        .expect("static fig9 config");
    let mut strads = lda_engine(&corpus, k, cfg.n_workers, cfg.seed, &run_cfg);
    let strads_rec = strads.run(&run_cfg).recorder;

    let mut yahoo = YahooLda::new(
        &corpus,
        YahooLdaConfig {
            n_topics: k,
            alpha: 0.1,
            gamma: 0.01,
            n_workers: cfg.n_workers,
            seed: cfg.seed,
        },
        NetworkConfig::gbps1(),
        None,
    );
    let (yahoo_rec, _) = yahoo.run(sweeps, "YahooLDA");
    Panel {
        title: "Figure 9 (left): LDA log-likelihood vs time".into(),
        strads: strads_rec,
        baseline: yahoo_rec,
    }
}

/// MF trajectories: STRADS CCD vs ALS.
pub fn run_mf(cfg: &Fig9Config) -> Panel {
    let users = sc(1_500, cfg.scale);
    let items = sc(1_000, cfg.scale);
    let rank = sc(32, cfg.scale);
    let lambda = 0.05f32;
    let sweeps = 10u64;
    let run_cfg = RunConfig::builder()
        .max_rounds(sweeps * 2 * rank as u64)
        .eval_every(2 * rank as u64)
        .network(NetworkConfig::gbps40())
        .label("STRADS-MF")
        .build()
        .expect("static fig9 config");
    let mut strads = mf_engine(
        users, items, rank, cfg.n_workers, lambda, cfg.seed, &run_cfg,
    );
    let strads_rec = strads.run(&run_cfg).recorder;

    let data = mf_ratings::generate(&MfGenConfig {
        n_users: users,
        n_items: items,
        density: 0.012,
        true_rank: 8.min(rank),
        seed: cfg.seed,
        ..Default::default()
    });
    let mut als = AlsMf::new(
        &data.a,
        AlsConfig { rank, lambda, n_workers: cfg.n_workers, seed: cfg.seed },
        NetworkConfig::gbps40(),
        None,
    );
    let (als_rec, _) = als.run(sweeps, "GraphLab-ALS");
    Panel {
        title: "Figure 9 (center): MF objective vs time".into(),
        strads: strads_rec,
        baseline: als_rec,
    }
}

/// Lasso trajectories: STRADS dynamic vs Lasso-RR.  The paper's J >> n
/// sparse regime: the dynamic schedule plunges to the optimum while the
/// unfiltered random baseline co-updates correlated columns and stalls or
/// diverges (§3.3, citing Bradley et al.).
pub fn run_lasso(cfg: &Fig9Config) -> Panel {
    let n = sc(256, cfg.scale);
    let j = sc(16_384, cfg.scale);
    let u = 32;
    let rounds = 500u64;
    let mk = |label: &str| {
        RunConfig::builder()
            .max_rounds(rounds)
            .eval_every(rounds / 25)
            .network(NetworkConfig::gbps40())
            .label(label)
            .build()
            .expect("static fig9 config")
    };
    let run_cfg = mk("STRADS-Lasso");
    let (mut strads, _) = lasso_engine_corr(
        n, j, cfg.n_workers, u, true, 0.08, 0.9, cfg.seed, &run_cfg,
    );
    let strads_rec = strads.run(&run_cfg).recorder;

    let rr_cfg = mk("Lasso-RR");
    let (mut rr, _) = lasso_engine_corr(
        n, j, cfg.n_workers, u, false, 0.08, 0.9, cfg.seed, &rr_cfg,
    );
    let rr_rec = rr.run(&rr_cfg).recorder;
    Panel {
        title: "Figure 9 (right): Lasso objective vs time".into(),
        strads: strads_rec,
        baseline: rr_rec,
    }
}

/// One BSP-vs-SSP arm: identical app/data/seed, straggler-skewed compute,
/// objective-vs-virtual-time under both execution modes.
pub struct ModeComparison {
    pub app: String,
    pub bsp: Recorder,
    pub ssp: Recorder,
    /// Common objective target (the easier of the two final objectives).
    pub target: f64,
    pub bsp_secs_to_target: Option<f64>,
    pub ssp_secs_to_target: Option<f64>,
    pub mean_staleness: f64,
    pub max_staleness: u64,
    pub wait_saved_secs: f64,
    /// Worker↔worker traffic per arm (hub-bypassing bytes + handoff
    /// counts), so bench trajectories track network cost, not just
    /// time-to-objective.
    pub bsp_p2p_bytes: u64,
    pub ssp_p2p_bytes: u64,
    pub bsp_handoffs: u64,
    pub ssp_handoffs: u64,
    /// Virtual seconds workers idled waiting for queued slice handoffs
    /// (rotation runs; 0.0 otherwise) — the slack availability ordering
    /// reclaims, quantified per arm.
    pub bsp_handoff_wait_secs: f64,
    pub ssp_handoff_wait_secs: f64,
    /// Slice-legs skipped by `SkipPolicy::Defer` (0 under `Never`) and
    /// the worst per-slice coverage debt observed, per arm — the debt
    /// machinery's counters surfaced into the bench trajectory.
    pub bsp_skipped_legs: u64,
    pub ssp_skipped_legs: u64,
    pub bsp_max_coverage_debt: u64,
    pub ssp_max_coverage_debt: u64,
    /// Seconds workers spent physically parked on the slice data plane
    /// per arm (~0 under the sim backend; the measured router/ledger
    /// contention under `--backend threads`).
    pub bsp_router_block_secs: f64,
    pub ssp_router_block_secs: f64,
}

/// Lasso + MF arms of the BSP-vs-SSP comparison under a rotating
/// `straggler_factor`x compute skew.  (LDA rotates exclusive slices and
/// pipelines through [`run_rotation_comparison`] instead.)
pub fn run_mode_comparison(
    cfg: &Fig9Config,
    staleness: u64,
    straggler_factor: f64,
) -> Vec<ModeComparison> {
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let mut out = Vec::new();

    // ---- Lasso arm ----------------------------------------------------
    {
        let n = sc(256, cfg.scale);
        let j = sc(8_192, cfg.scale);
        let u = 16;
        let rounds = 300u64;
        let run = |mode: ExecutionMode, label: &str| {
            // ideal fabric: the arm isolates the straggler *compute* skew
            // (at figure scale, per-message latency would otherwise dwarf
            // the microsecond-level push compute in both modes)
            let run_cfg = RunConfig::builder()
                .max_rounds(rounds)
                .eval_every(rounds / 10)
                .network(NetworkConfig::ideal())
                .label(label)
                .mode(mode)
                .straggler(straggler.clone())
                .build()
                .expect("static fig9 config");
            let (mut e, _) = lasso_engine_corr(
                n, j, cfg.n_workers, u, true, 0.05, 0.9, cfg.seed, &run_cfg,
            );
            e.run(&run_cfg)
        };
        let bsp = run(ExecutionMode::Bsp, "Lasso-BSP");
        let ssp = run(ExecutionMode::Ssp { staleness }, "Lasso-SSP");
        out.push(comparison("Lasso", bsp, ssp));
    }

    // ---- MF arm -------------------------------------------------------
    {
        let users = sc(600, cfg.scale);
        let items = sc(400, cfg.scale);
        let rank = sc(16, cfg.scale);
        let sweeps = 6u64;
        let run = |mode: ExecutionMode, label: &str| {
            let run_cfg = RunConfig::builder()
                .max_rounds(sweeps * 2 * rank as u64)
                .eval_every(2 * rank as u64)
                .network(NetworkConfig::ideal()) // isolate the compute skew
                .label(label)
                .mode(mode)
                .straggler(straggler.clone())
                .build()
                .expect("static fig9 config");
            let mut e = mf_engine(
                users, items, rank, cfg.n_workers, 0.05, cfg.seed, &run_cfg,
            );
            e.run(&run_cfg)
        };
        let bsp = run(ExecutionMode::Bsp, "MF-BSP");
        let ssp = run(ExecutionMode::Ssp { staleness }, "MF-SSP");
        out.push(comparison("MF", bsp, ssp));
    }
    out
}

/// LDA rotation arm: BSP rotation (per-round checkout/checkin barrier)
/// vs the pipelined router path (`ExecutionMode::Rotation { depth }`)
/// under a rotating `straggler_factor`x compute skew.  The pipelined run
/// lands in the comparison's `ssp` slot.
pub fn run_rotation_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
) -> ModeComparison {
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 8u64;
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let run = |mode: ExecutionMode, label: &str| {
        let run_cfg = RunConfig::builder()
            .max_rounds(sweeps * cfg.n_workers as u64)
            .eval_every(cfg.n_workers as u64)
            .network(NetworkConfig::ideal()) // isolate the compute skew
            .label(label)
            .mode(mode)
            .straggler(straggler.clone())
            .build()
            .expect("static fig9 config");
        let mut e = lda_engine(&corpus, k, cfg.n_workers, cfg.seed, &run_cfg);
        e.run(&run_cfg)
    };
    let bsp = run(ExecutionMode::Bsp, "LDA-BSP-rotation");
    let piped =
        run(ExecutionMode::Rotation { depth }, "LDA-pipelined-rotation");
    comparison_with("LDA-rotation", bsp, piped, false)
}

/// Multi-slice rotation arm: pipelined rotation with U = P slices vs
/// U = 2P (slice over-decomposition) at equal depth and identical corpus,
/// under a rotating `straggler_factor`x compute skew.  With U = 2P a
/// worker sweeps a two-slice queue: one slice samples while the other's
/// handoff is still in flight, so the straggler's lateness propagates in
/// half-round grains instead of stalling each successor a full round.
/// Both runs cover every slice every round (equal sweep work per round);
/// the U = 2P run lands in the `ssp` slot.
///
/// Two measurement choices keep the comparison about *pipeline speed*
/// rather than evaluation noise: objectives are evaluated every **two**
/// sweeps (each eval drains the pipeline — per-sweep drains would erase
/// the wavefront the finer gating buys), and the shared target is the
/// 90%-improvement point of the easier trajectory, which both runs cross
/// in the steep phase of the LL curve (an endpoint target would sit on
/// the plateau, where partition noise decides who crosses first).  The
/// two initial objectives agree to summation order — both builds draw
/// the same topic-assignment stream — so the improvement fractions are
/// comparable.
pub fn run_multislice_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
) -> ModeComparison {
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 8u64;
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let run = |n_slices: usize, label: &str| {
        let run_cfg = RunConfig::builder()
            .max_rounds(sweeps * cfg.n_workers as u64)
            .eval_every(2 * cfg.n_workers as u64)
            .network(NetworkConfig::ideal()) // isolate the compute skew
            .label(label)
            .mode(ExecutionMode::Rotation { depth })
            .straggler(straggler.clone())
            .build()
            .expect("static fig9 config");
        let mut e = lda_engine_sliced(
            &corpus, k, cfg.n_workers, n_slices, cfg.seed, &run_cfg,
        );
        e.run(&run_cfg)
    };
    let single = run(cfg.n_workers, "LDA-rotation-U=P");
    let multi = run(2 * cfg.n_workers, "LDA-rotation-U=2P");
    let mut cmp = comparison_with("LDA-multislice", single, multi, false);
    retarget_fraction(&mut cmp, 0.9, false);
    cmp
}

/// Re-aim a comparison at the `frac`-improvement point of the easier
/// trajectory: both runs cross it in the steep phase of the curve, where
/// timing dominates — an endpoint target sits on the plateau, where
/// partition noise decides who crosses first.
fn retarget_fraction(cmp: &mut ModeComparison, frac: f64, minimizing: bool) {
    let first = cmp.bsp.points()[0].objective;
    let target = first + frac * (cmp.target - first);
    cmp.bsp_secs_to_target = cmp.bsp.time_to_target(target, minimizing);
    cmp.ssp_secs_to_target = cmp.ssp.time_to_target(target, minimizing);
    cmp.target = target;
}

/// Availability-ordered rotation arm: LDA at U = 2P and equal depth,
/// [`QueueOrder::Strict`] vs [`QueueOrder::Availability`], under a
/// rotating `straggler_factor`x compute skew and the given handoff
/// latency model.  The strict run lands in the `bsp` slot, availability
/// in `ssp`.
///
/// The rotation primitive only requires per-round disjointness of the
/// leases, so which queued slice a worker sweeps first is free:
/// earliest-landed-first (the engine's makespan-optimal per-worker
/// discipline, `SliceRouter::try_take` on the data plane) reclaims the
/// stall a strict ring order pays whenever a later-positioned slice
/// arrives before an earlier one — which a straggler or latency jitter
/// makes routine.
pub fn run_availability_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
    jitter: HandoffJitter,
    tag: &str,
) -> ModeComparison {
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 8u64;
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let run = |order: QueueOrder, label: String| {
        let run_cfg = RunConfig::builder()
            .max_rounds(sweeps * cfg.n_workers as u64)
            .eval_every(2 * cfg.n_workers as u64)
            .network(NetworkConfig::ideal()) // isolate compute + handoffs
            .label(label)
            .mode(ExecutionMode::Rotation { depth })
            .straggler(straggler.clone())
            .queue_order(order)
            .handoff_jitter(jitter.clone())
            .build()
            .expect("static fig9 config");
        let mut e = lda_engine_sliced(
            &corpus,
            k,
            cfg.n_workers,
            2 * cfg.n_workers,
            cfg.seed,
            &run_cfg,
        );
        e.run(&run_cfg)
    };
    let strict = run(QueueOrder::Strict, format!("LDA-U2P-strict-{tag}"));
    let avail = run(QueueOrder::Availability, format!("LDA-U2P-avail-{tag}"));
    let mut cmp = comparison_with(
        &format!("LDA-availability-{tag}"),
        strict,
        avail,
        false,
    );
    retarget_fraction(&mut cmp, 0.9, false);
    cmp
}

/// Dynamic-order rotation arm: LDA at U = 6P and equal depth,
/// [`QueueOrder::Availability`] vs [`QueueOrder::Dynamic`], under a
/// rotating `straggler_factor`x compute skew and the given handoff
/// latency model.  The availability run lands in the `bsp` slot, dynamic
/// in `ssp`.
///
/// `zipf_alpha = Some(α)` builds the slices with a **Zipf mass profile**
/// (slice `a` targets `1/(a+1)^α` of the token mass) — the skewed regime
/// mass-weighted ordering exists for; `None` runs the same arm with a
/// uniform profile, where the two disciplines should tie to noise.  Both
/// disciplines are non-idling, so a worker's own round never finishes
/// later under either — the dynamic win comes entirely from *releasing
/// heavy handoffs earlier*, which is why it needs skewed masses, deep
/// queues (U = 6P), and several rounds between eval drains
/// (`eval_every = 2P`) to compound.
pub fn run_dynamic_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
    jitter: HandoffJitter,
    zipf_alpha: Option<f64>,
    tag: &str,
) -> ModeComparison {
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 8u64;
    let u = 6 * cfg.n_workers;
    let targets: Vec<f64> = (0..u)
        .map(|a| match zipf_alpha {
            Some(alpha) => 1.0 / ((a + 1) as f64).powf(alpha),
            None => 1.0,
        })
        .collect();
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let run = |order: QueueOrder, label: String| {
        let run_cfg = RunConfig::builder()
            .max_rounds(sweeps * cfg.n_workers as u64)
            .eval_every(2 * cfg.n_workers as u64)
            .network(NetworkConfig::ideal()) // isolate compute + handoffs
            .label(label)
            .mode(ExecutionMode::Rotation { depth })
            .straggler(straggler.clone())
            .queue_order(order)
            .handoff_jitter(jitter.clone())
            .build()
            .expect("static fig9 config");
        let mut e = lda_engine_sliced_targets(
            &corpus, k, cfg.n_workers, u, &targets, cfg.seed, &run_cfg,
        );
        e.run(&run_cfg)
    };
    let avail =
        run(QueueOrder::Availability, format!("LDA-U6P-avail-{tag}"));
    let dynamic =
        run(QueueOrder::Dynamic, format!("LDA-U6P-dynamic-{tag}"));
    let mut cmp = comparison_with(
        &format!("LDA-dynamic-{tag}"),
        avail,
        dynamic,
        false,
    );
    retarget_fraction(&mut cmp, 0.9, false);
    cmp
}

/// MF block-rotation arm: the CCD MF-BSP baseline vs
/// [`crate::apps::MfBlockApp`]'s rotated SGD block sweeps on the same
/// ratings (denser than the Netflix
/// recipe so each block carries per-round signal), under the same
/// rotating straggler.  The CCD run lands in the `bsp` slot, the rotated
/// SGD run in `ssp`.  The bench asserts the two *converge to the same
/// objective within tolerance* — the algorithms differ, so
/// time-to-target is reported for the trend line, not gated.
pub fn run_mf_block_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
) -> ModeComparison {
    let users = sc(600, cfg.scale);
    let items = sc(400, cfg.scale);
    let rank = sc(16, cfg.scale);
    let lambda = 0.05f32;
    let density = 0.08f64;
    let straggler = StragglerModel::Rotating { factor: straggler_factor };

    // CCD: 6 full sweeps (the SSP-arm recipe)
    let ccd_sweeps = 6u64;
    let ccd_cfg = RunConfig::builder()
        .max_rounds(ccd_sweeps * 2 * rank as u64)
        .eval_every(2 * rank as u64)
        .network(NetworkConfig::ideal())
        .label("MF-BSP")
        .straggler(straggler.clone())
        .build()
        .expect("static fig9 config");
    let mut ccd_engine = mf_engine_dense(
        users, items, rank, cfg.n_workers, lambda, density, cfg.seed,
        &ccd_cfg,
    );
    let ccd = ccd_engine.run(&ccd_cfg);

    // block rotation: ~24 data passes (each rating is swept once every P
    // rounds on average), U = 2P blocks, pipelined handoffs
    let sgd_sweeps = 24u64;
    let sgd_cfg = RunConfig::builder()
        .max_rounds(sgd_sweeps * cfg.n_workers as u64)
        .eval_every(4 * cfg.n_workers as u64)
        .network(NetworkConfig::ideal())
        .label("MF-block-rotation")
        .mode(ExecutionMode::Rotation { depth })
        .straggler(straggler)
        .build()
        .expect("static fig9 config");
    let mut sgd_engine = mf_block_engine(
        users,
        items,
        rank,
        cfg.n_workers,
        2 * cfg.n_workers,
        lambda,
        density,
        cfg.seed,
        &sgd_cfg,
    );
    let sgd = sgd_engine.run(&sgd_cfg);
    comparison_with("MF-block-rotation", ccd, sgd, true)
}

/// The wall-clock validation arm: the same LDA rotation workload run on
/// **both** execution backends, BSP rotation vs pipelined rotation each
/// time.  The virtual-time model predicts pipelined < BSP under a
/// rotating straggler; the threaded runs realize the same straggler as
/// real worker-thread sleeps, so the prediction must also hold in
/// measured wall-clock — that cross-check is what the fig9 bench gates.
pub struct ThreadsComparison {
    pub app: String,
    pub n_workers: usize,
    /// Virtual seconds under the sim backend.
    pub sim_bsp_secs: f64,
    pub sim_pipelined_secs: f64,
    /// Measured wall-clock seconds under `--backend threads`.
    pub wall_bsp_secs: f64,
    pub wall_pipelined_secs: f64,
    /// Final objectives per backend: the depth-1-free Strict/Never
    /// protocol is timing-independent, so each mode's threaded objective
    /// must equal its sim objective bit-for-bit.
    pub sim_bsp_objective: f64,
    pub sim_pipelined_objective: f64,
    pub bsp_objective: f64,
    pub pipelined_objective: f64,
    /// Measured seconds threaded workers parked on the slice data plane.
    pub bsp_router_block_secs: f64,
    pub pipelined_router_block_secs: f64,
    /// Trace fingerprints of the pipelined run under each backend.  The
    /// Strict/Never protocol emits the same grant/take/forward/settle/eval
    /// event set regardless of timing, so the two must be equal — the
    /// cross-backend determinism gate in hash form.
    pub sim_fingerprint: u64,
    pub wall_fingerprint: u64,
    /// Wall seconds the traced threaded pipelined run cost over the
    /// untraced one (noise can drive it negative at figure scale) — the
    /// measured price of `TraceMode::Record`.
    pub trace_overhead_secs: f64,
}

/// Run the threads-vs-sim validation arm on the LDA rotation workload:
/// four runs (BSP rotation and depth-`depth` pipelined rotation, each
/// under [`BackendKind::Sim`] and [`BackendKind::Threads`]) with a
/// rotating `straggler_factor`x skew.  `pace_secs` floors each threaded
/// worker's per-leg compute so the physically-realized skew dominates
/// scheduler noise at figure scale (the sim runs ignore it).
pub fn run_threads_comparison(
    cfg: &Fig9Config,
    depth: u64,
    straggler_factor: f64,
    pace_secs: f64,
) -> ThreadsComparison {
    let corpus =
        figure_corpus(sc(3_000, cfg.scale), sc(300, cfg.scale), cfg.seed);
    let k = sc(16, cfg.scale);
    let sweeps = 4u64;
    let straggler = StragglerModel::Rotating { factor: straggler_factor };
    let run = |mode: ExecutionMode,
               backend: BackendKind,
               trace: TraceMode,
               label: &str| {
        let run_cfg = RunConfig::builder()
            .max_rounds(sweeps * cfg.n_workers as u64)
            .eval_every(2 * cfg.n_workers as u64)
            .network(NetworkConfig::ideal()) // isolate the compute skew
            .label(label)
            .mode(mode)
            .straggler(straggler.clone())
            .backend(backend)
            .threads_pace_secs(match backend {
                BackendKind::Threads => pace_secs,
                BackendKind::Sim => 0.0,
            })
            .trace(trace)
            .build()
            .expect("static fig9 config");
        let mut e = lda_engine(&corpus, k, cfg.n_workers, cfg.seed, &run_cfg);
        e.run(&run_cfg)
    };
    let pipe = ExecutionMode::Rotation { depth };
    let sim_bsp = run(
        ExecutionMode::Bsp,
        BackendKind::Sim,
        TraceMode::Off,
        "LDA-BSP-sim",
    );
    // record the pipelined run on BOTH backends: the fingerprints gate
    // cross-backend event-stream equality, not just final objectives
    let sim_pipe = run(
        pipe,
        BackendKind::Sim,
        TraceMode::Record,
        "LDA-pipelined-sim",
    );
    let thr_bsp = run(
        ExecutionMode::Bsp,
        BackendKind::Threads,
        TraceMode::Off,
        "LDA-BSP-threads",
    );
    // untraced threaded pipelined run carries the wall-clock gate; the
    // traced rerun carries the fingerprint and prices the recorder
    let thr_pipe = run(
        pipe,
        BackendKind::Threads,
        TraceMode::Off,
        "LDA-pipelined-threads",
    );
    let thr_pipe_traced = run(
        pipe,
        BackendKind::Threads,
        TraceMode::Record,
        "LDA-pipelined-threads-traced",
    );
    ThreadsComparison {
        app: "LDA-rotation-threads".into(),
        n_workers: cfg.n_workers,
        sim_bsp_secs: sim_bsp.virtual_secs,
        sim_pipelined_secs: sim_pipe.virtual_secs,
        wall_bsp_secs: thr_bsp.wall_secs,
        wall_pipelined_secs: thr_pipe.wall_secs,
        sim_bsp_objective: sim_bsp.final_objective,
        sim_pipelined_objective: sim_pipe.final_objective,
        bsp_objective: thr_bsp.final_objective,
        pipelined_objective: thr_pipe.final_objective,
        bsp_router_block_secs: thr_bsp.router_block_secs,
        pipelined_router_block_secs: thr_pipe.router_block_secs,
        sim_fingerprint: sim_pipe
            .fingerprint
            .expect("recording sim run fingerprints"),
        wall_fingerprint: thr_pipe_traced
            .fingerprint
            .expect("recording threaded run fingerprints"),
        trace_overhead_secs: thr_pipe_traced.wall_secs - thr_pipe.wall_secs,
    }
}

/// Print the threads-vs-sim validation arm.
pub fn print_threads_comparison(c: &ThreadsComparison) {
    println!(
        "\n== Figure 9 (threads arm): {} on {} real worker threads ==",
        c.app, c.n_workers
    );
    println!(
        "  sim (virtual):  BSP {:.4}s vs pipelined {:.4}s",
        c.sim_bsp_secs, c.sim_pipelined_secs
    );
    println!(
        "  threads (wall): BSP {:.4}s vs pipelined {:.4}s",
        c.wall_bsp_secs, c.wall_pipelined_secs
    );
    println!(
        "  router block:   BSP {:.4}s vs pipelined {:.4}s",
        c.bsp_router_block_secs, c.pipelined_router_block_secs
    );
    println!(
        "  objectives:     BSP {:.6} (sim {:.6}), pipelined {:.6} (sim {:.6})",
        c.bsp_objective,
        c.sim_bsp_objective,
        c.pipelined_objective,
        c.sim_pipelined_objective
    );
    println!(
        "  fingerprints:   sim {:016x} vs threads {:016x} (trace overhead {:+.4}s)",
        c.sim_fingerprint, c.wall_fingerprint, c.trace_overhead_secs
    );
}

/// The chaos arm: the same LDA rotation workload fault-free vs under an
/// injected mid-run crash + later re-join, plus a third run whose fault
/// plan is configured but never fires.
pub struct ChaosComparison {
    pub app: String,
    /// Fault-free trajectory (the reference).
    pub fault_free: Recorder,
    /// Trajectory with worker 1 killed at ~50% and a replacement joining
    /// at ~75% of the run, under periodic checkpoints.
    pub chaos: Recorder,
    /// The fault-free run's 90%-improvement objective — the convergence
    /// target the chaos run must still reach (bounded-delay degradation,
    /// not divergence).
    pub target: f64,
    pub fault_free_secs_to_target: Option<f64>,
    pub chaos_secs_to_target: Option<f64>,
    /// Recovery boundaries fired in the chaos run (kill + join = 2).
    pub recoveries: u64,
    /// Window rounds drained (re-driven) across those recoveries — the
    /// "loses ≤ depth rounds per recovery" guarantee, measured.
    pub rounds_lost: u64,
    /// Wall seconds the chaos run spent serializing checkpoints.
    pub checkpoint_secs: f64,
    /// Fingerprint of the fault-free run's recorded trace.
    pub clean_fingerprint: u64,
    /// Fingerprint of the armed-but-unfired run: a kill scheduled at
    /// `max_rounds` (past the last boundary) plus periodic checkpoints.
    /// Must equal `clean_fingerprint` — arming the fault machinery must
    /// not perturb the schedule.
    pub unfired_fingerprint: u64,
}

/// Run the chaos arm on the U = 2P LDA rotation workload at the given
/// pipeline depth: fault-free reference, armed-but-unfired, and a
/// kill@50% + join@75% chaos run with checkpoints every eval interval.
pub fn run_chaos_comparison(cfg: &Fig9Config, depth: u64) -> ChaosComparison {
    assert!(cfg.n_workers >= 2, "chaos arm kills worker 1");
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 8u64;
    let p = cfg.n_workers as u64;
    let rounds = sweeps * p;
    let kill_at = rounds / 2;
    let join_at = rounds * 3 / 4;
    let run = |label: &str, kills: &[(usize, u64)], joins: &[u64]| {
        let mut b = RunConfig::builder()
            .max_rounds(rounds)
            .eval_every(p)
            .network(NetworkConfig::ideal())
            .label(label)
            .mode(ExecutionMode::Rotation { depth })
            .trace(TraceMode::Record);
        for &(w, at) in kills {
            b = b.kill_worker(w, at);
        }
        for &at in joins {
            b = b.join_worker(at);
        }
        if !(kills.is_empty() && joins.is_empty()) {
            // checkpoint on the eval cadence (drains coincide, so arming
            // checkpoints costs no extra pipeline stalls)
            b = b.checkpoint_every(p);
        }
        let run_cfg = b.build().expect("static chaos-arm config");
        let mut e = lda_engine_sliced(
            &corpus,
            k,
            cfg.n_workers,
            2 * cfg.n_workers,
            cfg.seed,
            &run_cfg,
        );
        e.run(&run_cfg)
    };
    let clean = run("LDA-chaos-clean", &[], &[]);
    // armed but unfired: the kill sits at max_rounds, one past the last
    // boundary the loop visits
    let unfired = run("LDA-chaos-unfired", &[(1, rounds)], &[]);
    let chaos = run("LDA-chaos", &[(1, kill_at)], &[join_at]);
    assert!(
        chaos.aborted.is_none(),
        "chaos run aborted: {:?}",
        chaos.aborted
    );
    // 90%-improvement point of the fault-free trajectory (see
    // retarget_fraction: endpoint targets sit on the plateau)
    let first = clean.recorder.points()[0].objective;
    let target = first + 0.9 * (clean.final_objective - first);
    ChaosComparison {
        app: "LDA-chaos".into(),
        target,
        fault_free_secs_to_target: clean
            .recorder
            .time_to_target(target, false),
        chaos_secs_to_target: chaos.recorder.time_to_target(target, false),
        recoveries: chaos.recoveries,
        rounds_lost: chaos.rounds_lost,
        checkpoint_secs: chaos.checkpoint_secs,
        clean_fingerprint: clean.fingerprint.expect("recorded run"),
        unfired_fingerprint: unfired.fingerprint.expect("recorded run"),
        fault_free: clean.recorder,
        chaos: chaos.recorder,
    }
}

/// Print the chaos arm.
pub fn print_chaos_comparison(c: &ChaosComparison) {
    println!("\n== Figure 9 (chaos arm): {} ==", c.app);
    for rec in [&c.fault_free, &c.chaos] {
        println!("  --- {} ---", rec.label);
        println!("  {:>10}  {:>12}  {:>16}", "round", "vtime(s)", "objective");
        for pt in rec.points() {
            println!(
                "  {:>10}  {:>12.4}  {:>16.6}",
                pt.round, pt.virtual_secs, pt.objective
            );
        }
    }
    println!(
        "  target {:.6}: fault-free {:?}s vs chaos {:?}s",
        c.target, c.fault_free_secs_to_target, c.chaos_secs_to_target
    );
    println!(
        "  recoveries {} ({} window rounds re-driven), checkpoints {:.4}s",
        c.recoveries, c.rounds_lost, c.checkpoint_secs
    );
    println!(
        "  fingerprints: clean {:016x} vs armed-unfired {:016x}",
        c.clean_fingerprint, c.unfired_fingerprint
    );
}

/// The lossy arm: the same LDA rotation workload on a clean fabric vs
/// under drop/dup/delay injection (with the ack/retry redelivery protocol
/// masking the faults), plus a run whose [`NetFaultPlan`] is configured
/// but all-zero.  The protocol's contract, measured: identical math
/// (objective bits equal), a bounded virtual-time penalty, no aborts.
pub struct LossyComparison {
    pub app: String,
    /// Clean-fabric trajectory (the reference).
    pub clean: Recorder,
    /// Trajectory under drop 5% + dup 2% + delay 10%.
    pub lossy: Recorder,
    /// The clean run's 90%-improvement objective.
    pub target: f64,
    pub clean_secs_to_target: Option<f64>,
    pub lossy_secs_to_target: Option<f64>,
    /// Transport-layer work the redelivery protocol did to mask the
    /// faults (all zero in the clean run).
    pub retransmits: u64,
    pub dup_discards: u64,
    pub retry_wait_secs: f64,
    /// Mid-round transport recoveries the engine fired (0 when retry
    /// alone masked every fault — the expected case at these rates).
    pub recoveries: u64,
    pub clean_objective: f64,
    pub lossy_objective: f64,
    /// Fingerprint of the clean run's recorded trace.
    pub clean_fingerprint: u64,
    /// Fingerprint of the run configured with an all-zero plan.  Must
    /// equal `clean_fingerprint`: compiling the fault layer in (rates 0)
    /// must not perturb the schedule.
    pub zero_plan_fingerprint: u64,
}

/// Run the lossy arm on the U = 2P LDA rotation workload at the given
/// pipeline depth, under a jittered 4x rotating straggler: clean
/// reference, all-zero-plan control, and a drop 5% + dup 2% + delay 10%
/// injected run.
pub fn run_lossy_comparison(cfg: &Fig9Config, depth: u64) -> LossyComparison {
    let corpus =
        figure_corpus(sc(6_000, cfg.scale), sc(600, cfg.scale), cfg.seed);
    let k = sc(32, cfg.scale);
    let sweeps = 6u64;
    let p = cfg.n_workers as u64;
    let rounds = sweeps * p;
    let run = |label: &str, plan: Option<NetFaultPlan>| {
        let mut b = RunConfig::builder()
            .max_rounds(rounds)
            .eval_every(p)
            .network(NetworkConfig::ideal())
            .label(label)
            .mode(ExecutionMode::Rotation { depth })
            .straggler(StragglerModel::Rotating { factor: 4.0 })
            .handoff_jitter(HandoffJitter::Jittered {
                base_frac: 0.2,
                jitter_frac: 1.5,
                seed: 5,
            })
            .trace(TraceMode::Record);
        if let Some(plan) = plan {
            b = b.net_faults(plan);
        }
        let run_cfg = b.build().expect("static lossy-arm config");
        let mut e = lda_engine_sliced(
            &corpus,
            k,
            cfg.n_workers,
            2 * cfg.n_workers,
            cfg.seed,
            &run_cfg,
        );
        e.run(&run_cfg)
    };
    let clean = run("LDA-lossy-clean", None);
    let zero = run("LDA-lossy-zero", Some(NetFaultPlan::default()));
    let lossy = run(
        "LDA-lossy",
        Some(NetFaultPlan {
            drop_rate: 0.05,
            dup_rate: 0.02,
            delay_rate: 0.10,
            seed: cfg.seed ^ 0x10551,
        }),
    );
    assert!(
        lossy.aborted.is_none(),
        "lossy run aborted: {:?}",
        lossy.aborted
    );
    // the redelivery protocol masks every fault below the liveness bound:
    // the math must come out bit-identical, not merely close
    assert_eq!(
        clean.final_objective.to_bits(),
        lossy.final_objective.to_bits(),
        "redelivery must mask the fault mix exactly: clean {} vs lossy {}",
        clean.final_objective,
        lossy.final_objective
    );
    let first = clean.recorder.points()[0].objective;
    let target = first + 0.9 * (clean.final_objective - first);
    LossyComparison {
        app: "LDA-lossy".into(),
        target,
        clean_secs_to_target: clean.recorder.time_to_target(target, false),
        lossy_secs_to_target: lossy.recorder.time_to_target(target, false),
        retransmits: lossy.retransmits,
        dup_discards: lossy.dup_discards,
        retry_wait_secs: lossy.retry_wait_secs,
        recoveries: lossy.recoveries,
        clean_objective: clean.final_objective,
        lossy_objective: lossy.final_objective,
        clean_fingerprint: clean.fingerprint.expect("recorded run"),
        zero_plan_fingerprint: zero.fingerprint.expect("recorded run"),
        clean: clean.recorder,
        lossy: lossy.recorder,
    }
}

/// Print the lossy arm.
pub fn print_lossy_comparison(c: &LossyComparison) {
    println!("\n== Figure 9 (lossy arm): {} ==", c.app);
    for rec in [&c.clean, &c.lossy] {
        println!("  --- {} ---", rec.label);
        println!("  {:>10}  {:>12}  {:>16}", "round", "vtime(s)", "objective");
        for pt in rec.points() {
            println!(
                "  {:>10}  {:>12.4}  {:>16.6}",
                pt.round, pt.virtual_secs, pt.objective
            );
        }
    }
    println!(
        "  target {:.6}: clean {:?}s vs lossy {:?}s",
        c.target, c.clean_secs_to_target, c.lossy_secs_to_target
    );
    println!(
        "  masked: {} retransmits, {} dup discards, {:.4}s retry wait, \
         {} recoveries",
        c.retransmits, c.dup_discards, c.retry_wait_secs, c.recoveries
    );
    println!(
        "  objectives bit-equal: {} (clean {:.6})",
        c.clean_objective.to_bits() == c.lossy_objective.to_bits(),
        c.clean_objective
    );
    println!(
        "  fingerprints: clean {:016x} vs zero-plan {:016x}",
        c.clean_fingerprint, c.zero_plan_fingerprint
    );
}

fn comparison(
    app: &str,
    bsp: crate::coordinator::RunResult,
    ssp: crate::coordinator::RunResult,
) -> ModeComparison {
    comparison_with(app, bsp, ssp, true)
}

fn comparison_with(
    app: &str,
    bsp: crate::coordinator::RunResult,
    ssp: crate::coordinator::RunResult,
    minimizing: bool,
) -> ModeComparison {
    // the easier of the two final objectives (larger when minimizing,
    // smaller when maximizing): a target both trajectories reach
    let target = if minimizing {
        bsp.final_objective.max(ssp.final_objective)
    } else {
        bsp.final_objective.min(ssp.final_objective)
    };
    let (mean_staleness, max_staleness, wait_saved_secs) = ssp
        .ssp
        .as_ref()
        .map(|s| (s.mean_staleness(), s.max_staleness(), s.wait_saved_secs))
        .unwrap_or((0.0, 0, 0.0));
    ModeComparison {
        app: app.to_string(),
        bsp_secs_to_target: bsp.recorder.time_to_target(target, minimizing),
        ssp_secs_to_target: ssp.recorder.time_to_target(target, minimizing),
        target,
        bsp_p2p_bytes: bsp.total_p2p_bytes,
        ssp_p2p_bytes: ssp.total_p2p_bytes,
        bsp_handoffs: bsp.total_p2p_msgs,
        ssp_handoffs: ssp.total_p2p_msgs,
        bsp_handoff_wait_secs: bsp.total_handoff_wait_secs,
        ssp_handoff_wait_secs: ssp.total_handoff_wait_secs,
        bsp_skipped_legs: bsp.total_skipped_legs,
        ssp_skipped_legs: ssp.total_skipped_legs,
        bsp_max_coverage_debt: bsp.max_coverage_debt,
        ssp_max_coverage_debt: ssp.max_coverage_debt,
        bsp_router_block_secs: bsp.router_block_secs,
        ssp_router_block_secs: ssp.router_block_secs,
        bsp: bsp.recorder,
        ssp: ssp.recorder,
        mean_staleness,
        max_staleness,
        wait_saved_secs,
    }
}

/// Print a BSP-vs-SSP comparison arm.
pub fn print_mode_comparison(c: &ModeComparison) {
    println!(
        "\n== Figure 9 (SSP arm): {} objective vs virtual time ==",
        c.app
    );
    for rec in [&c.bsp, &c.ssp] {
        println!("  --- {} ---", rec.label);
        println!("  {:>10}  {:>12}  {:>16}", "round", "vtime(s)", "objective");
        for p in rec.points() {
            println!(
                "  {:>10}  {:>12.4}  {:>16.6}",
                p.round, p.virtual_secs, p.objective
            );
        }
    }
    println!(
        "  target {:.6}: BSP {:?}s vs SSP {:?}s  \
         (mean staleness {:.2}, max {}, barrier wait hidden {:.4}s)",
        c.target,
        c.bsp_secs_to_target,
        c.ssp_secs_to_target,
        c.mean_staleness,
        c.max_staleness,
        c.wait_saved_secs
    );
    println!(
        "  p2p traffic: {} bytes / {} handoffs vs {} bytes / {} handoffs",
        c.bsp_p2p_bytes, c.bsp_handoffs, c.ssp_p2p_bytes, c.ssp_handoffs
    );
    println!(
        "  handoff wait: {:.4}s vs {:.4}s",
        c.bsp_handoff_wait_secs, c.ssp_handoff_wait_secs
    );
    println!(
        "  skipped legs: {} (max debt {}) vs {} (max debt {})",
        c.bsp_skipped_legs,
        c.bsp_max_coverage_debt,
        c.ssp_skipped_legs,
        c.ssp_max_coverage_debt
    );
    println!(
        "  router block: {:.4}s vs {:.4}s",
        c.bsp_router_block_secs, c.ssp_router_block_secs
    );
}

/// Print a panel as aligned series.
pub fn print_panel(panel: &Panel) {
    println!("\n== {} ==", panel.title);
    for rec in [&panel.strads, &panel.baseline] {
        println!("  --- {} ---", rec.label);
        println!("  {:>10}  {:>12}  {:>16}", "round", "vtime(s)", "objective");
        for p in rec.points() {
            println!(
                "  {:>10}  {:>12.4}  {:>16.6}",
                p.round, p.virtual_secs, p.objective
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Fig9Config {
        Fig9Config { scale: 0.05, n_workers: 2, seed: 3 }
    }

    #[test]
    fn lda_panel_strads_final_ll_at_least_baseline() {
        let p = run_lda(&tiny());
        let s = p.strads.last_objective().unwrap();
        let b = p.baseline.last_objective().unwrap();
        // same total sweeps; STRADS should be in the same band or better
        assert!(s > b - 0.2 * b.abs(), "strads {s} vs yahoo {b}");
    }

    #[test]
    fn mf_panel_both_converge_strads_no_worse() {
        let p = run_mf(&tiny());
        let s0 = p.strads.points()[0].objective;
        let s1 = p.strads.last_objective().unwrap();
        assert!(s1 < s0);
        let b1 = p.baseline.last_objective().unwrap();
        assert!(b1.is_finite());
    }

    #[test]
    fn lasso_panel_strads_plunges() {
        let p = run_lasso(&Fig9Config { scale: 0.1, n_workers: 2, seed: 3 });
        let s0 = p.strads.points()[0].objective;
        let s1 = p.strads.last_objective().unwrap();
        assert!(s1 < 0.7 * s0, "lasso objective {s0} -> {s1}");
    }

    #[test]
    fn rotation_comparison_converges_and_bounds_staleness() {
        let c = run_rotation_comparison(&tiny(), 2, 4.0);
        assert!(
            c.max_staleness <= 1,
            "depth-2 pipeline observed staleness {}",
            c.max_staleness
        );
        // both trajectories improve the log-likelihood...
        for rec in [&c.bsp, &c.ssp] {
            let first = rec.points()[0].objective;
            let last = rec.last_objective().unwrap();
            assert!(
                last.is_finite() && last > first,
                "{}: {first} -> {last}",
                rec.label
            );
        }
        // ...and both reach the shared target.  No timing-ratio assert at
        // tiny scale (see mode_comparison_converges_and_bounds_staleness);
        // the strict pipelined-beats-BSP assert lives in the fig9 bench.
        assert!(c.bsp_secs_to_target.is_some(), "bsp reaches target");
        assert!(c.ssp_secs_to_target.is_some(), "pipelined reaches target");
    }

    #[test]
    fn multislice_comparison_converges_and_tracks_traffic() {
        let c = run_multislice_comparison(&tiny(), 2, 4.0);
        assert!(c.max_staleness <= 1, "depth-2 bound");
        // both trajectories learn and reach the shared target; the strict
        // U=2P-beats-U=P timing assert lives in the fig9 bench (tiny-scale
        // virtual times ride on microsecond compute and would flake here)
        for rec in [&c.bsp, &c.ssp] {
            let first = rec.points()[0].objective;
            let last = rec.last_objective().unwrap();
            assert!(
                last.is_finite() && last > first,
                "{}: {first} -> {last}",
                rec.label
            );
        }
        assert!(c.bsp_secs_to_target.is_some(), "U=P reaches target");
        assert!(c.ssp_secs_to_target.is_some(), "U=2P reaches target");
        // handoffs ride the p2p links in both arms; the U=2P ring moves
        // twice as many (smaller) slices per round
        assert!(c.bsp_p2p_bytes > 0 && c.ssp_p2p_bytes > 0);
        assert!(
            c.ssp_handoffs > c.bsp_handoffs,
            "U=2P must record more handoffs ({} vs {})",
            c.ssp_handoffs,
            c.bsp_handoffs
        );
    }

    #[test]
    fn availability_comparison_converges_and_accounts_wait() {
        let c = run_availability_comparison(
            &tiny(),
            2,
            4.0,
            HandoffJitter::Jittered {
                base_frac: 0.2,
                jitter_frac: 1.5,
                seed: 3,
            },
            "jitter",
        );
        assert!(c.max_staleness <= 1, "depth-2 bound");
        // both disciplines learn and reach the shared 90% target; the
        // strict availability-beats-strict timing assert lives in the
        // fig9 bench, where scale makes it stable
        for rec in [&c.bsp, &c.ssp] {
            let first = rec.points()[0].objective;
            let last = rec.last_objective().unwrap();
            assert!(
                last.is_finite() && last > first,
                "{}: {first} -> {last}",
                rec.label
            );
        }
        assert!(c.bsp_secs_to_target.is_some(), "strict reaches target");
        assert!(c.ssp_secs_to_target.is_some(), "availability reaches target");
        // with jittered latencies the strict run *must* stall somewhere
        assert!(
            c.bsp_handoff_wait_secs > 0.0,
            "strict order under jitter records no handoff wait"
        );
        assert!(c.ssp_handoff_wait_secs >= 0.0);
    }

    #[test]
    fn dynamic_comparison_converges_and_counts_nothing_skipped() {
        let c = run_dynamic_comparison(
            &tiny(),
            2,
            4.0,
            HandoffJitter::Jittered {
                base_frac: 0.2,
                jitter_frac: 1.5,
                seed: 3,
            },
            Some(1.0),
            "zipf",
        );
        assert!(c.max_staleness <= 1, "depth-2 bound");
        // both disciplines learn and reach the shared 90% target; the
        // dynamic-vs-availability timing assert lives in the fig9 bench,
        // where scale makes it stable
        for rec in [&c.bsp, &c.ssp] {
            let first = rec.points()[0].objective;
            let last = rec.last_objective().unwrap();
            assert!(
                last.is_finite() && last > first,
                "{}: {first} -> {last}",
                rec.label
            );
        }
        assert!(c.bsp_secs_to_target.is_some(), "availability reaches target");
        assert!(c.ssp_secs_to_target.is_some(), "dynamic reaches target");
        // SkipPolicy defaults to Never: the skip counters must stay zero
        assert_eq!(c.bsp_skipped_legs, 0);
        assert_eq!(c.ssp_skipped_legs, 0);
        assert_eq!(c.ssp_max_coverage_debt, 0);
        // Zipf targets: the handoff ring carries real traffic both ways
        assert!(c.bsp_p2p_bytes > 0 && c.ssp_p2p_bytes > 0);
    }

    #[test]
    fn mf_block_comparison_both_converge() {
        let c = run_mf_block_comparison(&tiny(), 2, 4.0);
        for rec in [&c.bsp, &c.ssp] {
            let first = rec.points()[0].objective;
            let last = rec.last_objective().unwrap();
            assert!(
                last.is_finite() && last < first,
                "{}: {first} -> {last}",
                rec.label
            );
        }
        // the rotated SGD arm moves its blocks p2p; CCD has no handoffs
        assert!(c.ssp_p2p_bytes > 0 && c.ssp_handoffs > 0);
        assert_eq!(c.bsp_handoffs, 0);
        // the shared-objective tolerance assert lives in the fig9 bench,
        // where the validated scales make it stable
    }

    #[test]
    fn threads_comparison_matches_sim_objectives() {
        // tiny scale, no pace floor: this test gates *state equivalence*
        // (Strict/Never rotation is timing-independent, so each mode's
        // threaded objective must equal its sim objective bit-for-bit);
        // the wall-clock ordering assert lives in the fig9 bench, where
        // the pace floor makes it stable
        let c = run_threads_comparison(&tiny(), 2, 4.0, 0.0);
        assert_eq!(
            c.bsp_objective.to_bits(),
            c.sim_bsp_objective.to_bits(),
            "threaded BSP diverged from sim: {} vs {}",
            c.bsp_objective,
            c.sim_bsp_objective
        );
        assert_eq!(
            c.pipelined_objective.to_bits(),
            c.sim_pipelined_objective.to_bits(),
            "threaded pipelined diverged from sim: {} vs {}",
            c.pipelined_objective,
            c.sim_pipelined_objective
        );
        // the virtual-time model's prediction at this scale
        assert!(
            c.sim_pipelined_secs < c.sim_bsp_secs,
            "sim predicts pipelined < BSP ({} vs {})",
            c.sim_pipelined_secs,
            c.sim_bsp_secs
        );
        // wall-clock times are measured and positive
        assert!(c.wall_bsp_secs > 0.0 && c.wall_pipelined_secs > 0.0);
        assert!(c.bsp_router_block_secs >= 0.0);
        // the traced pipelined runs emit the same event set on both
        // backends — fingerprints are the determinism gate in hash form
        assert_eq!(
            c.sim_fingerprint, c.wall_fingerprint,
            "sim and threads pipelined fingerprints diverged: \
             {:016x} vs {:016x}",
            c.sim_fingerprint, c.wall_fingerprint
        );
    }

    #[test]
    fn chaos_comparison_recovers_and_unfired_plan_is_inert() {
        let depth = 2u64;
        let c = run_chaos_comparison(&tiny(), depth);
        // one kill + one join boundary fired
        assert_eq!(c.recoveries, 2, "kill + join each fire one recovery");
        // each recovery drains at most the in-flight window
        assert!(
            c.rounds_lost <= c.recoveries * depth,
            "{} rounds lost across {} depth-{depth} recoveries",
            c.rounds_lost,
            c.recoveries
        );
        // bounded-delay degradation: the chaos run still reaches the
        // fault-free run's 90% target within the same round budget
        assert!(
            c.fault_free_secs_to_target.is_some(),
            "fault-free run reaches its own 90% target"
        );
        assert!(
            c.chaos_secs_to_target.is_some(),
            "chaos run never reached the fault-free 90% target {:.6}",
            c.target
        );
        // arming the fault machinery without firing it must not perturb
        // the schedule: bit-identical event stream
        assert_eq!(
            c.clean_fingerprint, c.unfired_fingerprint,
            "armed-but-unfired fault plan changed the trace: \
             {:016x} vs {:016x}",
            c.clean_fingerprint, c.unfired_fingerprint
        );
    }

    #[test]
    fn lossy_comparison_masks_faults_bit_exactly() {
        // run_lossy_comparison itself asserts no-abort and objective
        // bit-equality; this test gates the rest of the contract
        let c = run_lossy_comparison(&tiny(), 2);
        assert_eq!(
            c.clean_objective.to_bits(),
            c.lossy_objective.to_bits(),
            "masked run must match the clean math bit for bit"
        );
        // the fault mix actually exercised the protocol
        assert!(c.retransmits > 0, "drop 5% fired no retransmits");
        assert!(c.dup_discards > 0, "dup 2% fired no duplicate discards");
        assert!(c.retry_wait_secs >= 0.0);
        // at these rates retry masks everything below the recovery path
        assert_eq!(c.recoveries, 0, "retry alone should mask this mix");
        // a configured-but-all-zero plan must be schedule-inert
        assert_eq!(
            c.clean_fingerprint, c.zero_plan_fingerprint,
            "zero-rate NetFaultPlan changed the trace: {:016x} vs {:016x}",
            c.clean_fingerprint, c.zero_plan_fingerprint
        );
        // bounded degradation in deterministic virtual time: the lossy
        // run reaches the clean run's 90% target within 1.25x
        let clean_t =
            c.clean_secs_to_target.expect("clean run reaches its target");
        let lossy_t = c
            .lossy_secs_to_target
            .expect("lossy run never reached the clean 90% target");
        assert!(
            lossy_t <= 1.25 * clean_t,
            "lossy arm too slow: {lossy_t:.4}s vs clean {clean_t:.4}s"
        );
    }

    #[test]
    fn mode_comparison_converges_and_bounds_staleness() {
        let arms = run_mode_comparison(&tiny(), 2, 4.0);
        assert_eq!(arms.len(), 2);
        for c in &arms {
            assert!(
                c.max_staleness <= 2,
                "{}: staleness {} over bound",
                c.app,
                c.max_staleness
            );
            // both trajectories improve on their start
            for rec in [&c.bsp, &c.ssp] {
                let first = rec.points()[0].objective;
                let last = rec.last_objective().unwrap();
                assert!(
                    last.is_finite() && last < first,
                    "{} {}: {first} -> {last}",
                    c.app,
                    rec.label
                );
            }
            // both reach the shared target.  No timing-ratio assert here:
            // at tiny scale the virtual times ride on microsecond-level
            // measured compute and would flake in CI — the strict SSP-wins
            // assert lives in the fig9 bench (4x skew) and in the
            // compute-heavy engine test ssp_hides_a_rotating_straggler.
            assert!(c.bsp_secs_to_target.is_some(), "{}: bsp reaches target", c.app);
            assert!(c.ssp_secs_to_target.is_some(), "{}: ssp reaches target", c.app);
        }
    }
}
