//! **Figure 3** — Topic modeling: memory usage per machine, STRADS
//! (model-parallel) vs YahooLDA-style (data-parallel), as machines grow.
//!
//! Paper result: with more machines, STRADS LDA uses *less memory per
//! machine* (the word-topic table is partitioned), while YahooLDA's
//! per-machine usage stays ≈ flat (full replication).

use crate::baselines::{YahooLda, YahooLdaConfig};
use crate::cluster::NetworkConfig;
use crate::coordinator::RunConfig;
use crate::figures::common::{figure_corpus, lda_engine, print_table};
use crate::util::JsonValue;

/// One measured point.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub machines: usize,
    pub strads_bytes: u64,
    pub yahoo_bytes: u64,
}

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig3Config {
    pub vocab: usize,
    pub n_docs: usize,
    pub n_topics: usize,
    pub machine_counts: Vec<usize>,
    pub seed: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            vocab: 20_000,
            n_docs: 1_000,
            n_topics: 100,
            machine_counts: vec![2, 4, 8, 16, 32],
            seed: 42,
        }
    }
}

/// Run the experiment and return one row per machine count.
pub fn run(cfg: &Fig3Config) -> Vec<Fig3Row> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    let mut rows = Vec::new();
    for &p in &cfg.machine_counts {
        // STRADS: run one rotation round then census
        let run_cfg = RunConfig::default();
        let mut strads =
            lda_engine(&corpus, cfg.n_topics, p, cfg.seed, &run_cfg);
        strads.round(0);
        // census reports worker-resident model state; add the leased B
        // slice (V/p words × K), the in-flight model partition a worker
        // holds at peak.
        let worker_bytes = strads.memory_census().unwrap_or(0);
        let slice_bytes =
            ((cfg.vocab / p).max(1) * cfg.n_topics * 4) as u64;
        let strads_bytes = worker_bytes + slice_bytes;

        let mut yahoo = YahooLda::new(
            &corpus,
            YahooLdaConfig {
                n_topics: cfg.n_topics,
                alpha: 0.1,
                gamma: 0.01,
                n_workers: p,
                seed: cfg.seed,
            },
            NetworkConfig::gbps1(),
            None,
        );
        let yahoo_bytes = yahoo.memory_census().unwrap_or(u64::MAX);

        rows.push(Fig3Row { machines: p, strads_bytes, yahoo_bytes });
    }
    rows
}

/// Print the figure's series.
pub fn print(rows: &[Fig3Row]) {
    print_table(
        "Figure 3: LDA memory per machine (bytes)",
        &["machines", "STRADS", "YahooLDA", "ratio"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.machines.to_string(),
                    r.strads_bytes.to_string(),
                    r.yahoo_bytes.to_string(),
                    format!(
                        "{:.2}x",
                        r.yahoo_bytes as f64 / r.strads_bytes.max(1) as f64
                    ),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

/// JSON emission for downstream plotting.
pub fn to_json(rows: &[Fig3Row]) -> JsonValue {
    JsonValue::Arr(
        rows.iter()
            .map(|r| {
                JsonValue::obj()
                    .field("machines", r.machines)
                    .field("strads_bytes", r.strads_bytes)
                    .field("yahoo_bytes", r.yahoo_bytes)
                    .build()
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Fig3Config {
        Fig3Config {
            vocab: 2_000,
            n_docs: 150,
            n_topics: 16,
            machine_counts: vec![2, 4, 8],
            seed: 1,
        }
    }

    #[test]
    fn strads_memory_shrinks_with_machines() {
        let rows = run(&quick());
        assert!(rows[2].strads_bytes < rows[0].strads_bytes);
    }

    #[test]
    fn yahoo_memory_stays_flat_and_dominates() {
        let rows = run(&quick());
        // replication: per-machine usage does not shrink proportionally
        let ratio =
            rows[0].yahoo_bytes as f64 / rows[2].yahoo_bytes as f64;
        assert!(ratio < 2.0, "yahoo dropped {ratio}x over 4x machines");
        // and at 8 machines STRADS is well below YahooLDA
        assert!(rows[2].strads_bytes * 2 < rows[2].yahoo_bytes);
    }
}
