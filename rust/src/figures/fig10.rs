//! **Figure 10** — STRADS LDA scalability with increasing machines at a
//! fixed model size: convergence trajectories per machine count (left) and
//! time to reach a fixed log-likelihood (right).
//!
//! Paper result: time-to-convergence roughly halves per doubling of
//! machines (near-linear scaling).

use crate::coordinator::RunConfig;
use crate::figures::common::{figure_corpus, lda_engine, print_table};
use crate::metrics::Recorder;

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig10Config {
    pub vocab: usize,
    pub n_docs: usize,
    pub n_topics: usize,
    pub machine_counts: Vec<usize>,
    pub sweeps: u64,
    pub network: crate::cluster::NetworkConfig,
    pub seed: u64,
}

impl Default for Fig10Config {
    fn default() -> Self {
        // token/vocab ratio chosen so compute dominates comm the way the
        // paper's 179M-token corpus did; the scaled-down corpus on the 1G
        // fabric would be communication-bound, which the paper's was not
        // (EXPERIMENTS.md discusses the crossover)
        Fig10Config {
            vocab: 10_000,
            n_docs: 5_000,
            n_topics: 100,
            machine_counts: vec![2, 4, 8, 16, 32],
            sweeps: 20,
            network: crate::cluster::NetworkConfig::gbps40(),
            seed: 42,
        }
    }
}

/// One machine-count result.
pub struct Fig10Row {
    pub machines: usize,
    pub trajectory: Recorder,
    pub time_to_target: Option<f64>,
}

/// Run: trajectories at each machine count + time to the shared target
/// (98% of the slowest configuration's final LL, mirroring the paper's
/// fixed -2.6e9 threshold).
pub fn run(cfg: &Fig10Config) -> Vec<Fig10Row> {
    let corpus = figure_corpus(cfg.vocab, cfg.n_docs, cfg.seed);
    let mut recs = Vec::new();
    for &p in &cfg.machine_counts {
        let run_cfg = RunConfig {
            max_rounds: cfg.sweeps * p as u64, // p rounds = 1 full sweep
            eval_every: p as u64,
            network: cfg.network,
            label: format!("strads-lda-m{p}"),
            ..Default::default()
        };
        let mut engine = lda_engine(&corpus, cfg.n_topics, p, cfg.seed, &run_cfg);
        let res = engine.run(&run_cfg);
        recs.push((p, res.recorder));
    }
    // shared target from the trajectories
    let target = recs
        .iter()
        .map(|(_, r)| {
            let first = r.points()[0].objective;
            let last = r.last_objective().unwrap();
            first + 0.98 * (last - first)
        })
        .fold(f64::NEG_INFINITY, f64::max)
        .min(
            recs.iter()
                .map(|(_, r)| r.last_objective().unwrap())
                .fold(f64::INFINITY, f64::min),
        );
    recs.into_iter()
        .map(|(machines, trajectory)| {
            let t = trajectory.time_to_target(target, false);
            Fig10Row { machines, trajectory, time_to_target: t }
        })
        .collect()
}

/// Print the right-hand panel (time to fixed LL).
pub fn print(rows: &[Fig10Row]) {
    print_table(
        "Figure 10 (right): LDA time to fixed log-likelihood",
        &["machines", "vtime to target", "speedup vs first"],
        &{
            let base = rows
                .first()
                .and_then(|r| r.time_to_target)
                .unwrap_or(f64::NAN);
            rows.iter()
                .map(|r| {
                    vec![
                        r.machines.to_string(),
                        r.time_to_target
                            .map(|t| format!("{t:.2}s"))
                            .unwrap_or_else(|| "DNF".into()),
                        r.time_to_target
                            .map(|t| format!("{:.2}x", base / t))
                            .unwrap_or_else(|| "-".into()),
                    ]
                })
                .collect::<Vec<_>>()
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_machines_is_not_slower() {
        // ideal network isolates compute scaling (the test corpus is far
        // below the comm-vs-compute crossover of the real clusters)
        let rows = run(&Fig10Config {
            vocab: 2_000,
            n_docs: 1_000,
            n_topics: 16,
            machine_counts: vec![2, 8],
            sweeps: 8,
            network: crate::cluster::NetworkConfig::ideal(),
            seed: 5,
        });
        let t2 = rows[0].time_to_target.expect("2-machine run converges");
        let t8 = rows[1].time_to_target.expect("8-machine run converges");
        // virtual-clock scaling: 4x machines should cut time well below 1x
        assert!(
            t8 < t2,
            "8 machines ({t8}s) should beat 2 machines ({t2}s)"
        );
    }
}
