//! One harness per paper figure; each returns structured rows/series and
//! prints the same quantities the paper plots.  Shared by the `cargo bench`
//! targets and `examples/paper_figures.rs`.

pub mod common;
pub mod fig10;
pub mod fig3;
pub mod fig5;
pub mod fig8;
pub mod fig9;
