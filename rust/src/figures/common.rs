//! Shared experiment-construction helpers for the figure harnesses.

use crate::apps::lasso::{LassoApp, LassoConfig, LassoSched};
use crate::apps::lda::{setup as lda_setup, LdaApp};
use crate::apps::mf::{block_setup, MfApp, MfBlockApp, MfConfig};
use crate::backend::native::{NativeLassoShard, NativeMfShard};
use crate::backend::{LassoShard, MfShard};
use crate::coordinator::{RunConfig, StradsEngine};
use crate::datagen::lasso_synth::{self, LassoGenConfig};
use crate::datagen::lda_corpus::{self, CorpusConfig};
use crate::datagen::mf_ratings::{self, MfGenConfig};
use crate::datagen::Corpus;
use crate::scheduler::priority::{PriorityConfig, PriorityScheduler};
use crate::scheduler::RandomScheduler;
use crate::sparse::CscMatrix;
use crate::util::Rng;
use std::sync::Arc;

/// Canonical LDA experiment corpus for figure harnesses.
pub fn figure_corpus(vocab: usize, n_docs: usize, seed: u64) -> Corpus {
    lda_corpus::generate(&CorpusConfig {
        n_docs,
        vocab,
        doc_len_mean: 40,
        n_topics: 20,
        zipf_alpha: 1.1,
        seed,
    })
}

/// Build a STRADS LDA engine over a corpus (U = `workers` slices, the
/// paper's layout).
pub fn lda_engine(
    corpus: &Corpus,
    k: usize,
    workers: usize,
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<LdaApp> {
    let s = lda_setup::build(corpus, k, workers, 0.1, 0.01, seed);
    StradsEngine::new(s.app, s.shards, cfg)
}

/// Build a STRADS LDA engine with `n_slices` ≥ `workers` rotation slices
/// (slice over-decomposition) and a skew-aware ring placement derived from
/// the run config's straggler model.
pub fn lda_engine_sliced(
    corpus: &Corpus,
    k: usize,
    workers: usize,
    n_slices: usize,
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<LdaApp> {
    let speeds = cfg.straggler.mean_speeds(workers, workers as u64);
    let s = lda_setup::build_sliced(
        corpus,
        k,
        workers,
        n_slices,
        Some(&speeds),
        0.1,
        0.01,
        seed,
    );
    StradsEngine::new(s.app, s.shards, cfg)
}

/// Build a STRADS LDA engine with `n_slices` ≥ `workers` rotation slices
/// whose token masses follow the given (relative) per-slice targets —
/// the controlled skew the dynamic-order arms sweep heaviest-first (see
/// [`crate::scheduler::RotationScheduler::partition_words_to_targets`]).
/// Identity ring placement: the skew stays where the profile puts it.
pub fn lda_engine_sliced_targets(
    corpus: &Corpus,
    k: usize,
    workers: usize,
    n_slices: usize,
    mass_targets: &[f64],
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<LdaApp> {
    let s = lda_setup::build_sliced_targets(
        corpus,
        k,
        workers,
        n_slices,
        None,
        Some(mass_targets),
        0.1,
        0.01,
        seed,
    );
    StradsEngine::new(s.app, s.shards, cfg)
}

/// Build a STRADS Lasso engine (priority or random scheduling) on the
/// paper-recipe data (0.9 independent-noise probability).
pub fn lasso_engine(
    n: usize,
    j: usize,
    workers: usize,
    u: usize,
    priority: bool,
    lambda: f32,
    seed: u64,
    cfg: &RunConfig,
) -> (StradsEngine<LassoApp>, Arc<CscMatrix>) {
    lasso_engine_corr(n, j, workers, u, priority, lambda, 0.9, seed, cfg)
}

/// Like [`lasso_engine`] but with a configurable correlation level
/// (`independent_prob` from the paper's recipe; lower ⇒ more correlated
/// adjacent features).
#[allow(clippy::too_many_arguments)]
pub fn lasso_engine_corr(
    n: usize,
    j: usize,
    workers: usize,
    u: usize,
    priority: bool,
    lambda: f32,
    independent_prob: f64,
    seed: u64,
    cfg: &RunConfig,
) -> (StradsEngine<LassoApp>, Arc<CscMatrix>) {
    let prob = lasso_synth::generate(&LassoGenConfig {
        n_samples: n,
        n_features: j,
        independent_prob,
        seed,
        ..Default::default()
    });
    let x = Arc::new(prob.x);
    let sched = if priority {
        LassoSched::Priority(PriorityScheduler::new(
            j,
            PriorityConfig::paper_defaults(u),
            seed ^ 0x51,
        ))
    } else {
        LassoSched::Random(RandomScheduler::new(j, u, seed ^ 0x51))
    };
    let app = LassoApp::new(
        x.clone(),
        LassoConfig { lambda, n_workers: workers },
        sched,
    );
    let per = n / workers;
    let mut states: Vec<Box<dyn LassoShard>> = Vec::new();
    for p in 0..workers {
        let lo = p * per;
        let hi = if p == workers - 1 { n } else { lo + per };
        states.push(Box::new(NativeLassoShard::new(
            x.row_slice(lo, hi),
            prob.y[lo..hi].to_vec(),
        )));
    }
    (StradsEngine::new(app, states, cfg), x)
}

/// Build a STRADS MF engine over generated ratings (the paper's Netflix
/// density).
pub fn mf_engine(
    users: usize,
    items: usize,
    rank: usize,
    workers: usize,
    lambda: f32,
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<MfApp> {
    mf_engine_dense(users, items, rank, workers, lambda, 0.012, seed, cfg)
}

/// Like [`mf_engine`] with a configurable observation density (the
/// MF-rotation comparison runs denser ratings so each item block carries
/// per-round SGD signal; the CCD baseline must see the same data).
#[allow(clippy::too_many_arguments)]
pub fn mf_engine_dense(
    users: usize,
    items: usize,
    rank: usize,
    workers: usize,
    lambda: f32,
    density: f64,
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<MfApp> {
    let data = mf_ratings::generate(&MfGenConfig {
        n_users: users,
        n_items: items,
        density,
        true_rank: 8.min(rank),
        seed,
        ..Default::default()
    });
    let mut rng = Rng::new(seed ^ 0xF00D);
    let scale = 1.0 / (rank as f32).sqrt();
    let h0: Vec<f32> = (0..rank * items).map(|_| rng.normal_f32() * scale).collect();
    let app = MfApp::new(
        MfConfig { rank, n_items: items, lambda, n_workers: workers },
        h0.clone(),
    );
    let per = users / workers;
    let mut states: Vec<Box<dyn MfShard>> = Vec::new();
    for p in 0..workers {
        let lo = p * per;
        let hi = if p == workers - 1 { users } else { lo + per };
        let shard = data.a.row_slice(lo, hi);
        let w0: Vec<f32> = (0..shard.rows() * rank)
            .map(|_| rng.normal_f32() * scale)
            .collect();
        states.push(Box::new(NativeMfShard::new(
            shard, w0, h0.clone(), rank, lambda,
        )));
    }
    StradsEngine::new(app, states, cfg)
}

/// Build a **block-rotation** MF engine ([`MfBlockApp`]): `n_blocks` ≥
/// `workers` nnz-balanced item blocks on the virtual ring, SGD block
/// sweeps (default step schedule, the given `lambda`), skew-aware
/// placement derived from the run config's straggler model.  Same
/// generator/seed as [`mf_engine_dense`], so the two MF apps run the
/// same data.
#[allow(clippy::too_many_arguments)]
pub fn mf_block_engine(
    users: usize,
    items: usize,
    rank: usize,
    workers: usize,
    n_blocks: usize,
    lambda: f32,
    density: f64,
    seed: u64,
    cfg: &RunConfig,
) -> StradsEngine<MfBlockApp> {
    let data = mf_ratings::generate(&MfGenConfig {
        n_users: users,
        n_items: items,
        density,
        true_rank: 8.min(rank),
        seed,
        ..Default::default()
    });
    let speeds = cfg.straggler.mean_speeds(workers, workers as u64);
    let sgd = block_setup::BlockSgdConfig {
        lambda,
        ..Default::default()
    };
    let s = block_setup::build_blocked(
        &data.a,
        rank,
        workers,
        n_blocks,
        Some(&speeds),
        &sgd,
        seed,
    );
    StradsEngine::new(s.app, s.shards, cfg)
}

/// Pretty-print a results table (fixed-width columns).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let widths: Vec<usize> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(h.len())
        })
        .collect();
    let line = |cells: Vec<String>| {
        let mut s = String::from("  ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{s}");
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for r in rows {
        line(r.clone());
    }
}
