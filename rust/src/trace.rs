//! Structured event tracing, bit-exact replay, and run fingerprinting.
//!
//! Every scheduling decision the engine makes — lease grants, queue takes
//! (with arrival stamp and chosen service order), forwards, settles, skips,
//! and coverage-debt charges — can be recorded as a compact typed [`Event`]
//! into a per-run ring-buffered [`TraceBuffer`].  Recording is zero-cost
//! when disabled: every site holds an `Option<Arc<TraceBuffer>>` and the
//! disabled path is a `None` check.
//!
//! A completed [`Trace`] serializes to a canonical line-oriented text form
//! (`strads-trace v1`), hashes to a single [`fingerprint`] (FNV-1a,
//! order-insensitive *within* a round, order-sensitive *across* rounds),
//! and can re-drive a run bit-exact through a [`TraceReplayer`]:
//!
//! * `SkipPolicy::Defer`'s live availability signal is replaced by the
//!   recorded skip set — the debt ledger then evolves identically, closing
//!   the speculative-replay gap PR 5 documented;
//! * `QueueOrder::{Availability, Dynamic}`'s racy service order is replaced
//!   by the recorded per-(round, worker) sweep order, serviced strictly.
//!
//! Why the fingerprint is order-insensitive within a round: a replayed run
//! emits the same *set* of events per round but may emit them in a
//! different order (e.g. grant legs are re-queued into recorded service
//! order before dispatch), so per-round event hashes are combined with a
//! commutative `wrapping_add` and only the round sequence is chained.
//! Order *information* is still fingerprinted — `Take::service_index` is
//! part of the event content.  Two fields are deliberately excluded from
//! hashing: `Take::arrival_seq` (a global deposit counter stamped by racing
//! worker threads — diagnostic, not schedule identity) and all
//! [`Event::Resolve`] events (clock readings; wall time is never
//! bit-reproducible).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};

/// One scheduling decision, as recorded by the engine / scheduler / ledger.
///
/// `round` is the engine round index for engine-recorded events
/// (`Grant`/`Take`/`Forward`/`Settle`/`Eval`/`Resolve`) and the scheduler
/// round counter for scheduler-recorded events (`Skip`/`DebtCharge`); the
/// two advance in lock-step for rotation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Slice `slice` leased at chain version `version` to worker `worker`.
    Grant { round: u64, worker: usize, slice: usize, version: u64 },
    /// Worker `worker` swept `slice` (mailbox version `version`) as its
    /// `service_index`-th leg of the round; `arrival_seq` is the global
    /// deposit stamp the mailbox carried (recorded for diagnosis, excluded
    /// from the fingerprint).
    Take {
        round: u64,
        worker: usize,
        slice: usize,
        version: u64,
        service_index: usize,
        arrival_seq: u64,
    },
    /// Worker `worker` forwarded `slice` at version `version` to ring
    /// successor `dest`, paying `bytes` on the data plane.
    Forward {
        round: u64,
        worker: usize,
        slice: usize,
        version: u64,
        dest: usize,
        bytes: usize,
    },
    /// The coordinator settled the lease on `slice` at version `version`.
    Settle { round: u64, slice: usize, version: u64 },
    /// The scheduler deferred `slice` (still in flight); `debt` is the
    /// slice's coverage debt *after* the charge.
    Skip { round: u64, slice: usize, debt: u64 },
    /// The coverage-debt ledger charged `slice` one deferral; `debt` is the
    /// post-charge balance.
    DebtCharge { round: u64, slice: usize, debt: u64 },
    /// The engine evaluated the objective (`objective_bits` = f64 bits).
    Eval { round: u64, objective_bits: u64 },
    /// A backend resolved a round at clock reading `now_bits` (f64 bits).
    /// Timing-only: never fingerprinted, never replayed.
    Resolve { round: u64, now_bits: u64 },
    /// Worker `worker` died at the round-`round` boundary (fault
    /// injection); the engine drained the pipeline window first, so every
    /// outstanding lease was settled before the crash took effect.
    Crash { round: u64, worker: usize },
    /// Worker `worker` (re)joined the cluster at the round-`round`
    /// boundary.
    Join { round: u64, worker: usize },
    /// A membership-recovery pass completed at the round-`round` boundary
    /// for `worker`: lease fences re-armed, the ring re-placed, `moved`
    /// slices migrated to a different cohort.
    Recover { round: u64, worker: usize, moved: usize },
    /// A consistent KV checkpoint (`bytes` serialized) was taken at the
    /// round-`round` boundary.  Bookkeeping-only: excluded from the
    /// fingerprint so a checkpointed run stays bit-identical to the same
    /// run without checkpoints.
    Checkpoint { round: u64, bytes: usize },
    /// The lossy-transport layer dropped delivery attempt `attempt` of
    /// `slice`'s version-`version` forward (fault injection).  Transport
    /// events carry no round (the data plane does not know the schedule)
    /// and are excluded from fingerprints: the redelivery protocol masks
    /// them, so the *post-masking* event stream — what replay and
    /// fingerprints see — is identical to a clean run's.
    NetDrop { slice: usize, version: u64, attempt: u64 },
    /// The sender retransmitted `slice` at version `version` (delivery
    /// attempt `attempt`) after an earlier attempt was dropped.
    Retransmit { slice: usize, version: u64, attempt: u64 },
    /// The receiver discarded a duplicate delivery of `slice` at version
    /// `version` (already delivered — idempotent receive).
    DupDiscard { slice: usize, version: u64 },
    /// A recovery flush force-delivered the retained payload of `slice`
    /// at version `version` (bypassing pending fault decisions).
    Redeliver { slice: usize, version: u64 },
}

impl Event {
    /// The round this event belongs to.
    pub fn round(&self) -> u64 {
        match *self {
            Event::Grant { round, .. }
            | Event::Take { round, .. }
            | Event::Forward { round, .. }
            | Event::Settle { round, .. }
            | Event::Skip { round, .. }
            | Event::DebtCharge { round, .. }
            | Event::Eval { round, .. }
            | Event::Resolve { round, .. }
            | Event::Crash { round, .. }
            | Event::Join { round, .. }
            | Event::Recover { round, .. }
            | Event::Checkpoint { round, .. } => round,
            // transport events happen below the schedule: no round
            Event::NetDrop { .. }
            | Event::Retransmit { .. }
            | Event::DupDiscard { .. }
            | Event::Redeliver { .. } => 0,
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a hash of one event's schedule-identity fields, or `None` for
/// events excluded from fingerprinting (`Resolve`).
pub fn event_hash(e: &Event) -> Option<u64> {
    let mut h = FNV_OFFSET;
    match *e {
        Event::Grant { round, worker, slice, version } => {
            for v in [1, round, worker as u64, slice as u64, version] {
                h = fnv_u64(h, v);
            }
        }
        Event::Take { round, worker, slice, version, service_index, .. } => {
            // arrival_seq deliberately omitted: the global deposit counter
            // is stamped by racing worker threads.
            for v in
                [2, round, worker as u64, slice as u64, version, service_index as u64]
            {
                h = fnv_u64(h, v);
            }
        }
        Event::Forward { round, worker, slice, version, dest, bytes } => {
            for v in [
                3,
                round,
                worker as u64,
                slice as u64,
                version,
                dest as u64,
                bytes as u64,
            ] {
                h = fnv_u64(h, v);
            }
        }
        Event::Settle { round, slice, version } => {
            for v in [4, round, slice as u64, version] {
                h = fnv_u64(h, v);
            }
        }
        Event::Skip { round, slice, debt } => {
            for v in [5, round, slice as u64, debt] {
                h = fnv_u64(h, v);
            }
        }
        Event::DebtCharge { round, slice, debt } => {
            for v in [6, round, slice as u64, debt] {
                h = fnv_u64(h, v);
            }
        }
        Event::Eval { round, objective_bits } => {
            for v in [7, round, objective_bits] {
                h = fnv_u64(h, v);
            }
        }
        Event::Crash { round, worker } => {
            for v in [8, round, worker as u64] {
                h = fnv_u64(h, v);
            }
        }
        Event::Join { round, worker } => {
            for v in [9, round, worker as u64] {
                h = fnv_u64(h, v);
            }
        }
        Event::Recover { round, worker, moved } => {
            for v in [10, round, worker as u64, moved as u64] {
                h = fnv_u64(h, v);
            }
        }
        // Checkpoint is bookkeeping, not schedule identity: excluding it
        // keeps a checkpointed run's fingerprint bit-identical to the same
        // run without checkpoints (locked by tests/checkpoint_roundtrip.rs).
        // Transport faults are likewise excluded: the redelivery protocol
        // masks them, so a faulted run whose faults were all absorbed
        // fingerprints identically to the clean run (tests/net_chaos.rs).
        Event::Resolve { .. }
        | Event::Checkpoint { .. }
        | Event::NetDrop { .. }
        | Event::Retransmit { .. }
        | Event::DupDiscard { .. }
        | Event::Redeliver { .. } => return None,
    }
    Some(h)
}

/// Fingerprint an event stream: per-round accumulators combine event
/// hashes with commutative `wrapping_add` (order-insensitive within a
/// round), then rounds are chained in ascending order (order-sensitive
/// across rounds).
pub fn fingerprint(events: &[Event]) -> u64 {
    let mut rounds: BTreeMap<u64, u64> = BTreeMap::new();
    for e in events {
        if let Some(h) = event_hash(e) {
            let acc = rounds.entry(e.round()).or_insert(0);
            *acc = acc.wrapping_add(h);
        }
    }
    let mut fp = FNV_OFFSET;
    for (round, acc) in rounds {
        fp = fnv_u64(fp, round);
        fp = fnv_u64(fp, acc);
    }
    fp
}

/// Ring-buffered per-run event recorder (the `TraceRecorder`).
///
/// Shared by `Arc` across the coordinator, scheduler, ledger, and backend;
/// `push` is a short mutex hold (events are `Copy`).  When full the oldest
/// event is dropped and counted, so a runaway run degrades to a bounded
/// suffix instead of unbounded memory.
#[derive(Debug)]
pub struct TraceBuffer {
    inner: Mutex<BufferInner>,
}

#[derive(Debug)]
struct BufferInner {
    events: VecDeque<Event>,
    capacity: usize,
    dropped: u64,
}

impl TraceBuffer {
    /// Default capacity: 1 Mi events (~48 MiB worst case) — far above any
    /// smoke-scale run, bounded for production ones.
    pub fn new() -> Self {
        Self::with_capacity(1 << 20)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity > 0, "trace buffer capacity must be positive");
        TraceBuffer {
            inner: Mutex::new(BufferInner {
                events: VecDeque::with_capacity(capacity.min(1 << 12)),
                capacity,
                dropped: 0,
            }),
        }
    }

    pub fn push(&self, e: Event) {
        let mut g = self.inner.lock().unwrap();
        if g.events.len() == g.capacity {
            g.events.pop_front();
            g.dropped += 1;
        }
        g.events.push_back(e);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped to the ring bound so far (0 ⇒ the trace is complete
    /// and its fingerprint is authoritative).
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Snapshot the recorded events (oldest first) without clearing.
    pub fn snapshot(&self) -> Vec<Event> {
        self.inner.lock().unwrap().events.iter().copied().collect()
    }
}

impl Default for TraceBuffer {
    fn default() -> Self {
        Self::new()
    }
}

/// What a run should do about tracing (a [`RunConfig`] field).
///
/// [`RunConfig`]: crate::coordinator::RunConfig
#[derive(Debug, Clone, Default)]
pub enum TraceMode {
    /// No recording; every trace site is a `None` check.
    #[default]
    Off,
    /// Record events into a fresh ring buffer; `RunResult` carries the
    /// finished [`Trace`] and its fingerprint.
    Record,
    /// Re-drive the run from a previously recorded trace (skip decisions
    /// and service order come from the trace, not live signals) while also
    /// recording, so the replay's fingerprint can be compared to the
    /// original's.  Replay requires `BackendKind::Sim`.
    Replay(Arc<Trace>),
}

impl TraceMode {
    pub fn is_off(&self) -> bool {
        matches!(self, TraceMode::Off)
    }
}

/// The per-run trace wiring handed to every recording/replaying site.
#[derive(Debug, Clone, Default)]
pub struct TracePlumbing {
    /// Recording sink, if this run records.
    pub sink: Option<Arc<TraceBuffer>>,
    /// Replay decisions, if this run replays a prior trace.
    pub replayer: Option<Arc<TraceReplayer>>,
}

impl TracePlumbing {
    /// Build the wiring for a run: `Off` → inert, `Record` → fresh sink,
    /// `Replay` → fresh sink *plus* a replayer over the source trace (a
    /// replayed run records too, so fingerprints can be compared).
    pub fn from_mode(mode: &TraceMode) -> Self {
        match mode {
            TraceMode::Off => TracePlumbing::default(),
            TraceMode::Record => TracePlumbing {
                sink: Some(Arc::new(TraceBuffer::new())),
                replayer: None,
            },
            TraceMode::Replay(trace) => TracePlumbing {
                sink: Some(Arc::new(TraceBuffer::new())),
                replayer: Some(Arc::new(TraceReplayer::from_trace(trace))),
            },
        }
    }

    #[inline]
    pub fn record(&self, e: Event) {
        if let Some(sink) = &self.sink {
            sink.push(e);
        }
    }

    pub fn is_active(&self) -> bool {
        self.sink.is_some()
    }
}

/// A finished, serializable event trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    /// The backend the trace was recorded under (`"sim"` / `"threads"`) —
    /// informational; replay always runs under `Sim`.
    pub backend: String,
    /// The LDA sampling kernel the trace was recorded under.  Replay
    /// *checks* this: an mh chain draws a different RNG sequence than
    /// exact, so re-driving a trace under the other kernel would
    /// silently diverge from the recorded objectives.
    pub sampler: crate::backend::SamplerKind,
    pub events: Vec<Event>,
}

impl Trace {
    pub fn fingerprint(&self) -> u64 {
        fingerprint(&self.events)
    }

    /// Fingerprint only the events of rounds `>= from` — the *suffix*
    /// fingerprint.  A run resumed from a round-`from` checkpoint records
    /// exactly the suffix events, so its full fingerprint must equal the
    /// uninterrupted run's `fingerprint_from(from)` (locked by
    /// `tests/checkpoint_roundtrip.rs`).
    pub fn fingerprint_from(&self, from: u64) -> u64 {
        let suffix: Vec<Event> = self
            .events
            .iter()
            .filter(|e| e.round() >= from)
            .copied()
            .collect();
        fingerprint(&suffix)
    }

    /// Canonical line-oriented text form:
    ///
    /// ```text
    /// strads-trace v1 <backend> [mh]
    /// grant <round> <worker> <slice> <version>
    /// take <round> <worker> <slice> <version> <service_index> <arrival_seq>
    /// forward <round> <worker> <slice> <version> <dest> <bytes>
    /// settle <round> <slice> <version>
    /// skip <round> <slice> <debt>
    /// debt <round> <slice> <debt>
    /// eval <round> <objective_bits:hex>
    /// resolve <round> <now_bits:hex>
    /// ```
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(32 + self.events.len() * 24);
        out.push_str("strads-trace v1 ");
        out.push_str(&self.backend);
        // sampler token only when non-default: exact traces stay
        // byte-identical with every pre-sampler golden
        if self.sampler == crate::backend::SamplerKind::Mh {
            out.push(' ');
            out.push_str(self.sampler.as_str());
        }
        out.push('\n');
        for e in &self.events {
            match *e {
                Event::Grant { round, worker, slice, version } => {
                    out.push_str(&format!(
                        "grant {round} {worker} {slice} {version}\n"
                    ));
                }
                Event::Take {
                    round,
                    worker,
                    slice,
                    version,
                    service_index,
                    arrival_seq,
                } => {
                    out.push_str(&format!(
                        "take {round} {worker} {slice} {version} {service_index} {arrival_seq}\n"
                    ));
                }
                Event::Forward { round, worker, slice, version, dest, bytes } => {
                    out.push_str(&format!(
                        "forward {round} {worker} {slice} {version} {dest} {bytes}\n"
                    ));
                }
                Event::Settle { round, slice, version } => {
                    out.push_str(&format!("settle {round} {slice} {version}\n"));
                }
                Event::Skip { round, slice, debt } => {
                    out.push_str(&format!("skip {round} {slice} {debt}\n"));
                }
                Event::DebtCharge { round, slice, debt } => {
                    out.push_str(&format!("debt {round} {slice} {debt}\n"));
                }
                Event::Eval { round, objective_bits } => {
                    out.push_str(&format!("eval {round} {objective_bits:x}\n"));
                }
                Event::Resolve { round, now_bits } => {
                    out.push_str(&format!("resolve {round} {now_bits:x}\n"));
                }
                Event::Crash { round, worker } => {
                    out.push_str(&format!("crash {round} {worker}\n"));
                }
                Event::Join { round, worker } => {
                    out.push_str(&format!("join {round} {worker}\n"));
                }
                Event::Recover { round, worker, moved } => {
                    out.push_str(&format!("recover {round} {worker} {moved}\n"));
                }
                Event::Checkpoint { round, bytes } => {
                    out.push_str(&format!("ckpt {round} {bytes}\n"));
                }
                Event::NetDrop { slice, version, attempt } => {
                    out.push_str(&format!("netdrop {slice} {version} {attempt}\n"));
                }
                Event::Retransmit { slice, version, attempt } => {
                    out.push_str(&format!("retx {slice} {version} {attempt}\n"));
                }
                Event::DupDiscard { slice, version } => {
                    out.push_str(&format!("dupdiscard {slice} {version}\n"));
                }
                Event::Redeliver { slice, version } => {
                    out.push_str(&format!("redeliver {slice} {version}\n"));
                }
            }
        }
        out
    }

    /// Parse the canonical text form back into a trace.
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty trace")?;
        let mut hp = header.split_whitespace();
        if hp.next() != Some("strads-trace") || hp.next() != Some("v1") {
            return Err(format!("bad trace header: {header:?}"));
        }
        let backend = hp.next().unwrap_or("sim").to_string();
        // optional 4th header token: the sampler the trace was recorded
        // under (absent = exact, the pre-sampler format)
        let sampler = match hp.next() {
            None => crate::backend::SamplerKind::Exact,
            Some(tok) => tok.parse::<crate::backend::SamplerKind>().map_err(
                |e| format!("bad trace header sampler token: {e}"),
            )?,
        };
        let mut events = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let mut f = line.split_whitespace();
            let tag = f.next().ok_or_else(|| format!("line {}: empty", i + 2))?;
            let mut dec = |name: &str| -> Result<u64, String> {
                f.next()
                    .ok_or_else(|| {
                        format!("line {}: missing {name}", i + 2)
                    })?
                    .parse::<u64>()
                    .map_err(|e| format!("line {}: bad {name}: {e}", i + 2))
            };
            let ev = match tag {
                "grant" => Event::Grant {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                },
                "take" => Event::Take {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                    service_index: dec("service_index")? as usize,
                    arrival_seq: dec("arrival_seq")?,
                },
                "forward" => Event::Forward {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                    dest: dec("dest")? as usize,
                    bytes: dec("bytes")? as usize,
                },
                "settle" => Event::Settle {
                    round: dec("round")?,
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                },
                "skip" => Event::Skip {
                    round: dec("round")?,
                    slice: dec("slice")? as usize,
                    debt: dec("debt")?,
                },
                "debt" => Event::DebtCharge {
                    round: dec("round")?,
                    slice: dec("slice")? as usize,
                    debt: dec("debt")?,
                },
                "eval" => {
                    let round = dec("round")?;
                    let bits = f
                        .next()
                        .ok_or_else(|| format!("line {}: missing bits", i + 2))?;
                    Event::Eval {
                        round,
                        objective_bits: u64::from_str_radix(bits, 16).map_err(
                            |e| format!("line {}: bad bits: {e}", i + 2),
                        )?,
                    }
                }
                "resolve" => {
                    let round = dec("round")?;
                    let bits = f
                        .next()
                        .ok_or_else(|| format!("line {}: missing bits", i + 2))?;
                    Event::Resolve {
                        round,
                        now_bits: u64::from_str_radix(bits, 16).map_err(|e| {
                            format!("line {}: bad bits: {e}", i + 2)
                        })?,
                    }
                }
                "crash" => Event::Crash {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                },
                "join" => Event::Join {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                },
                "recover" => Event::Recover {
                    round: dec("round")?,
                    worker: dec("worker")? as usize,
                    moved: dec("moved")? as usize,
                },
                "ckpt" => Event::Checkpoint {
                    round: dec("round")?,
                    bytes: dec("bytes")? as usize,
                },
                "netdrop" => Event::NetDrop {
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                    attempt: dec("attempt")?,
                },
                "retx" => Event::Retransmit {
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                    attempt: dec("attempt")?,
                },
                "dupdiscard" => Event::DupDiscard {
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                },
                "redeliver" => Event::Redeliver {
                    slice: dec("slice")? as usize,
                    version: dec("version")?,
                },
                other => {
                    return Err(format!("line {}: unknown tag {other:?}", i + 2))
                }
            };
            if f.next().is_some() {
                return Err(format!("line {}: trailing fields", i + 2));
            }
            events.push(ev);
        }
        Ok(Trace { backend, sampler, events })
    }
}

/// Replay decisions extracted from a recorded trace.
///
/// Two live signals make rotation runs timing-dependent; the replayer
/// pins both:
///
/// * **skips** — `Defer`'s availability poll is answered by the recorded
///   skip set (`skipped(round, slice)`); feeding `!skipped` into
///   `next_round_grants` reproduces the schedule exactly because the debt
///   ledger evolves deterministically given the same skip sequence;
/// * **service order** — each worker's grant queue is reordered into the
///   recorded sweep order (`service_order(round, worker)`) and then
///   serviced strictly; the recorded order was realizable (it happened),
///   so strict blocking service cannot deadlock.
#[derive(Debug)]
pub struct TraceReplayer {
    skipped: HashSet<(u64, usize)>,
    service: HashMap<(u64, usize), Vec<(usize, usize)>>,
    grants: HashSet<(u64, usize, usize)>,
}

impl TraceReplayer {
    pub fn from_trace(trace: &Trace) -> Self {
        let mut skipped = HashSet::new();
        let mut service: HashMap<(u64, usize), Vec<(usize, usize)>> =
            HashMap::new();
        let mut grants = HashSet::new();
        for e in &trace.events {
            match *e {
                Event::Skip { round, slice, .. } => {
                    skipped.insert((round, slice));
                }
                Event::Take { round, worker, slice, service_index, .. } => {
                    service
                        .entry((round, worker))
                        .or_default()
                        .push((service_index, slice));
                }
                Event::Grant { round, worker, slice, .. } => {
                    grants.insert((round, worker, slice));
                }
                _ => {}
            }
        }
        for order in service.values_mut() {
            order.sort_unstable();
        }
        TraceReplayer { skipped, service, grants }
    }

    /// Was `slice` skipped (deferred) in `round`?
    pub fn skipped(&self, round: u64, slice: usize) -> bool {
        self.skipped.contains(&(round, slice))
    }

    /// The recorded sweep order for `(round, worker)` as slice ids,
    /// earliest-serviced first; `None` if the trace has no takes there.
    pub fn service_order(&self, round: u64, worker: usize) -> Option<Vec<usize>> {
        self.service
            .get(&(round, worker))
            .map(|v| v.iter().map(|&(_, s)| s).collect())
    }

    /// Was `slice` granted to `worker` in `round`?  (Cross-check that the
    /// replayed schedule matches the recorded one grant-for-grant.)
    pub fn granted(&self, round: u64, worker: usize, slice: usize) -> bool {
        self.grants.contains(&(round, worker, slice))
    }

    /// Number of grant events in the source trace.
    pub fn n_grants(&self) -> usize {
        self.grants.len()
    }

    /// Reorder a worker's scheduled queue (`legs`, keyed by `slice_of`)
    /// into the recorded sweep order for `(round, worker)`, so a strict
    /// blocking service reproduces the original take sequence exactly.
    /// Panics on divergence: the recorded order must name exactly the
    /// scheduled slices (the engine's grant cross-check makes any other
    /// outcome a replay bug, not a user error).
    pub fn reorder_legs<L>(
        &self,
        round: u64,
        worker: usize,
        legs: Vec<L>,
        slice_of: impl Fn(&L) -> usize,
    ) -> Vec<L> {
        let Some(recorded) = self.service_order(round, worker) else {
            assert!(
                legs.is_empty(),
                "replay diverged: round {round} schedules worker {worker} \
                 a non-empty queue but the trace records no takes there"
            );
            return legs;
        };
        let mut by_slice: HashMap<usize, L> =
            legs.into_iter().map(|l| (slice_of(&l), l)).collect();
        let out: Vec<L> = recorded
            .iter()
            .map(|s| {
                by_slice.remove(s).unwrap_or_else(|| {
                    panic!(
                        "replay diverged: recorded sweep order for round \
                         {round} worker {worker} takes slice {s}, absent \
                         from the scheduled queue"
                    )
                })
            })
            .collect();
        assert!(
            by_slice.is_empty(),
            "replay diverged: round {round} worker {worker} queue holds \
             slices the recorded sweep order never takes"
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::Grant { round: 0, worker: 0, slice: 2, version: 1 },
            Event::Grant { round: 0, worker: 1, slice: 3, version: 1 },
            Event::Take {
                round: 0,
                worker: 0,
                slice: 2,
                version: 0,
                service_index: 0,
                arrival_seq: 17,
            },
            Event::Forward {
                round: 0,
                worker: 0,
                slice: 2,
                version: 1,
                dest: 1,
                bytes: 4096,
            },
            Event::Settle { round: 0, slice: 2, version: 0 },
            Event::Skip { round: 1, slice: 3, debt: 1 },
            Event::DebtCharge { round: 1, slice: 3, debt: 1 },
            Event::Eval { round: 1, objective_bits: 0x3ff0000000000000 },
            Event::Resolve { round: 1, now_bits: 0x4000000000000000 },
            Event::Crash { round: 2, worker: 1 },
            Event::Recover { round: 2, worker: 1, moved: 3 },
            Event::Join { round: 3, worker: 1 },
            Event::Checkpoint { round: 3, bytes: 4096 },
            Event::NetDrop { slice: 2, version: 5, attempt: 1 },
            Event::Retransmit { slice: 2, version: 5, attempt: 2 },
            Event::DupDiscard { slice: 3, version: 4 },
            Event::Redeliver { slice: 1, version: 7 },
        ]
    }

    #[test]
    fn text_round_trip_is_lossless() {
        let t = Trace {
            backend: "threads".into(),
            sampler: crate::backend::SamplerKind::Exact,
            events: sample_events(),
        };
        let parsed = Trace::parse(&t.to_text()).expect("parse");
        assert_eq!(parsed, t);
        assert_eq!(parsed.fingerprint(), t.fingerprint());
    }

    #[test]
    fn sampler_header_token_round_trips() {
        let t = Trace {
            backend: "sim".into(),
            sampler: crate::backend::SamplerKind::Mh,
            events: sample_events(),
        };
        let text = t.to_text();
        assert!(text.starts_with("strads-trace v1 sim mh\n"), "{text:?}");
        let parsed = Trace::parse(&text).expect("parse");
        assert_eq!(parsed, t);
    }

    #[test]
    fn legacy_three_token_header_parses_as_exact() {
        // traces recorded before the sampler existed have no 4th token
        let parsed =
            Trace::parse("strads-trace v1 threads\ngrant 0 1 2 3\n")
                .expect("parse");
        assert_eq!(parsed.sampler, crate::backend::SamplerKind::Exact);
        assert_eq!(parsed.backend, "threads");
        assert_eq!(parsed.events.len(), 1);
    }

    #[test]
    fn exact_trace_text_has_no_sampler_token() {
        // the exact header must stay byte-identical with pre-sampler
        // goldens
        let t = Trace {
            backend: "sim".into(),
            sampler: crate::backend::SamplerKind::Exact,
            events: Vec::new(),
        };
        assert_eq!(t.to_text(), "strads-trace v1 sim\n");
    }

    #[test]
    fn unknown_sampler_header_token_is_rejected() {
        let err = Trace::parse("strads-trace v1 sim warp\n").unwrap_err();
        assert!(err.contains("sampler"), "{err}");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::parse("").is_err());
        assert!(Trace::parse("not-a-trace v1 sim").is_err());
        assert!(Trace::parse("strads-trace v1 sim\nbogus 1 2 3").is_err());
        assert!(Trace::parse("strads-trace v1 sim\ngrant 1 2").is_err());
        assert!(Trace::parse("strads-trace v1 sim\ngrant 1 2 3 4 5").is_err());
    }

    #[test]
    fn fingerprint_is_order_insensitive_within_a_round() {
        let mut events = sample_events();
        let fp = fingerprint(&events);
        events.swap(0, 1); // both round-0 grants
        assert_eq!(fingerprint(&events), fp);
        events.swap(2, 3); // round-0 take vs forward
        assert_eq!(fingerprint(&events), fp);
    }

    #[test]
    fn fingerprint_is_order_sensitive_across_rounds() {
        let a = vec![
            Event::Settle { round: 0, slice: 1, version: 0 },
            Event::Settle { round: 1, slice: 2, version: 0 },
        ];
        let b = vec![
            Event::Settle { round: 0, slice: 2, version: 0 },
            Event::Settle { round: 1, slice: 1, version: 0 },
        ];
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }

    fn take(round: u64, worker: usize, slice: usize, version: u64, si: usize) -> Event {
        Event::Take {
            round,
            worker,
            slice,
            version,
            service_index: si,
            arrival_seq: 9,
        }
    }

    #[test]
    fn fingerprint_is_sensitive_to_every_identity_field() {
        let base = take(3, 1, 4, 2, 0);
        let variants = [
            take(4, 1, 4, 2, 0),
            take(3, 2, 4, 2, 0),
            take(3, 1, 5, 2, 0),
            take(3, 1, 4, 3, 0),
            take(3, 1, 4, 2, 1),
        ];
        let h0 = event_hash(&base).unwrap();
        for v in variants {
            assert_ne!(event_hash(&v).unwrap(), h0, "{v:?}");
        }
    }

    #[test]
    fn arrival_seq_and_resolve_are_excluded_from_the_fingerprint() {
        let a = Event::Take {
            round: 0,
            worker: 0,
            slice: 1,
            version: 0,
            service_index: 0,
            arrival_seq: 5,
        };
        let b = Event::Take {
            round: 0,
            worker: 0,
            slice: 1,
            version: 0,
            service_index: 0,
            arrival_seq: 99,
        };
        assert_eq!(event_hash(&a), event_hash(&b));
        assert_eq!(
            event_hash(&Event::Resolve { round: 0, now_bits: 1 }),
            None
        );
        let with = vec![a, Event::Resolve { round: 0, now_bits: 1 }];
        let without = vec![a];
        assert_eq!(fingerprint(&with), fingerprint(&without));
    }

    #[test]
    fn checkpoints_are_excluded_but_faults_are_fingerprinted() {
        let base = vec![Event::Settle { round: 0, slice: 1, version: 0 }];
        let mut ckpt = base.clone();
        ckpt.push(Event::Checkpoint { round: 0, bytes: 1024 });
        // a checkpointed run fingerprints identically to the same run
        // without checkpoints
        assert_eq!(fingerprint(&ckpt), fingerprint(&base));
        // a crashed/recovered run does NOT — membership faults are
        // schedule identity
        for e in [
            Event::Crash { round: 0, worker: 1 },
            Event::Join { round: 0, worker: 1 },
            Event::Recover { round: 0, worker: 1, moved: 2 },
        ] {
            let mut faulted = base.clone();
            faulted.push(e);
            assert_ne!(fingerprint(&faulted), fingerprint(&base), "{e:?}");
            assert!(event_hash(&e).is_some());
        }
        // recover's moved count is identity too
        assert_ne!(
            event_hash(&Event::Recover { round: 0, worker: 1, moved: 2 }),
            event_hash(&Event::Recover { round: 0, worker: 1, moved: 3 }),
        );
    }

    #[test]
    fn transport_events_are_excluded_from_the_fingerprint() {
        // the redelivery protocol masks transport faults, so a faulted
        // run whose drops/dups were all absorbed must fingerprint
        // identically to the clean run — net events hash to None
        let base = vec![Event::Settle { round: 0, slice: 1, version: 0 }];
        for e in [
            Event::NetDrop { slice: 1, version: 2, attempt: 1 },
            Event::Retransmit { slice: 1, version: 2, attempt: 2 },
            Event::DupDiscard { slice: 1, version: 2 },
            Event::Redeliver { slice: 1, version: 2 },
        ] {
            assert_eq!(event_hash(&e), None, "{e:?}");
            assert_eq!(e.round(), 0, "transport events carry no round");
            let mut faulted = base.clone();
            faulted.push(e);
            assert_eq!(fingerprint(&faulted), fingerprint(&base), "{e:?}");
        }
    }

    #[test]
    fn ring_buffer_drops_oldest_and_counts() {
        let buf = TraceBuffer::with_capacity(2);
        for v in 0..4 {
            buf.push(Event::Settle { round: v, slice: 0, version: v });
        }
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.dropped(), 2);
        let snap = buf.snapshot();
        assert_eq!(snap[0].round(), 2);
        assert_eq!(snap[1].round(), 3);
    }

    #[test]
    fn replayer_extracts_skips_service_order_and_grants() {
        let trace = Trace {
            backend: "sim".into(),
            sampler: crate::backend::SamplerKind::Exact,
            events: vec![
                Event::Grant { round: 0, worker: 0, slice: 1, version: 1 },
                Event::Grant { round: 0, worker: 0, slice: 2, version: 1 },
                // takes recorded out of order: service_index orders them
                Event::Take {
                    round: 0,
                    worker: 0,
                    slice: 2,
                    version: 0,
                    service_index: 1,
                    arrival_seq: 0,
                },
                Event::Take {
                    round: 0,
                    worker: 0,
                    slice: 1,
                    version: 0,
                    service_index: 0,
                    arrival_seq: 0,
                },
                Event::Skip { round: 2, slice: 4, debt: 1 },
            ],
        };
        let r = TraceReplayer::from_trace(&trace);
        assert!(r.skipped(2, 4));
        assert!(!r.skipped(2, 5));
        assert!(!r.skipped(0, 4));
        assert_eq!(r.service_order(0, 0), Some(vec![1, 2]));
        assert_eq!(r.service_order(0, 1), None);
        assert!(r.granted(0, 0, 1));
        assert!(r.granted(0, 0, 2));
        assert!(!r.granted(0, 0, 3));
        assert_eq!(r.n_grants(), 2);
    }
}
