//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the only place the crate touches XLA; python never runs here.
//! The flow (mirroring /opt/xla-example/load_hlo):
//!
//! ```text
//! manifest.txt ──> ArtifactManifest
//! *.hlo.txt    ──> HloModuleProto::from_text_file
//!                   └─> XlaComputation::from_proto ──> client.compile
//! Engine::call(name, inputs) ──> executable.execute ──> tuple of Literals
//! ```
//!
//! Executables are compiled once and cached ([`Engine`]); per-call overhead
//! is literal staging only.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{ArtifactManifest, ArtifactSpec, Dtype, TensorSpec};
pub use tensor::Tensor;
