//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the only place the crate touches XLA; python never runs here.
//! The flow (mirroring /opt/xla-example/load_hlo):
//!
//! ```text
//! manifest.txt ──> ArtifactManifest
//! *.hlo.txt    ──> HloModuleProto::from_text_file
//!                   └─> XlaComputation::from_proto ──> client.compile
//! Engine::call(name, inputs) ──> executable.execute ──> tuple of Literals
//! ```
//!
//! Executables are compiled once and cached (`Engine`); per-call overhead
//! is literal staging only.

//! The PJRT execution path (the `engine` submodule) needs the `xla`
//! crate, which is not vendorable in the offline build; it is gated
//! behind the `xla` cargo feature.  The manifest and host [`Tensor`]
//! types are pure rust and always available (the CLI's `artifacts`
//! command and the network byte accounting use them without XLA).
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "xla")]
pub use engine::Engine;
pub use manifest::{ArtifactManifest, ArtifactSpec, Dtype, TensorSpec};
pub use tensor::Tensor;
