//! Host tensor: the crate's staging type between app state and XLA
//! literals.  Only f32/i32 appear in the artifact set.

use super::manifest::{Dtype, TensorSpec};
use anyhow::bail;
#[cfg(feature = "xla")]
use anyhow::Context;
#[cfg(feature = "xla")]
use xla::Literal;

/// A host-side dense tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::F32 { dims: dims.to_vec(), data }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::I32 { dims: dims.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::F32 { dims: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        Tensor::I32 { dims: vec![], data: vec![x] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32 { .. } => Dtype::F32,
            Tensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn n_elems(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    /// Payload bytes (network modelling).
    pub fn bytes(&self) -> usize {
        self.n_elems() * 4
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> anyhow::Result<Vec<i32>> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Validate against a manifest spec.
    pub fn check_spec(&self, spec: &TensorSpec) -> anyhow::Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "param {}: dtype mismatch (got {:?}, want {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        if self.dims() != spec.dims.as_slice() {
            bail!(
                "param {}: shape mismatch (got {:?}, want {:?})",
                spec.name,
                self.dims(),
                spec.dims
            );
        }
        Ok(())
    }

    /// Stage into an XLA literal.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        let dims_i64: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { dims, data } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims_i64).context("reshape f32")?
                }
            }
            Tensor::I32 { dims, data } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims_i64).context("reshape i32")?
                }
            }
        };
        Ok(lit)
    }

    /// Read back from an XLA literal using the manifest output spec for
    /// shape/dtype (literals do not carry our dim convention for scalars).
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &Literal, spec: &TensorSpec) -> anyhow::Result<Self> {
        match spec.dtype {
            Dtype::F32 => {
                let data = lit.to_vec::<f32>().context("literal to f32 vec")?;
                if data.len() != spec.n_elems() {
                    bail!(
                        "output {}: element count {} != spec {}",
                        spec.name,
                        data.len(),
                        spec.n_elems()
                    );
                }
                Ok(Tensor::F32 { dims: spec.dims.clone(), data })
            }
            Dtype::I32 => {
                let data = lit.to_vec::<i32>().context("literal to i32 vec")?;
                if data.len() != spec.n_elems() {
                    bail!(
                        "output {}: element count {} != spec {}",
                        spec.name,
                        data.len(),
                        spec.n_elems()
                    );
                }
                Ok(Tensor::I32 { dims: spec.dims.clone(), data })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_len() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.n_elems(), 6);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn construction_rejects_bad_len() {
        Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: Dtype::F32,
            dims: vec![4],
        };
        assert!(Tensor::f32(&[4], vec![0.0; 4]).check_spec(&spec).is_ok());
        assert!(Tensor::f32(&[5], vec![0.0; 5]).check_spec(&spec).is_err());
        assert!(Tensor::i32(&[4], vec![0; 4]).check_spec(&spec).is_err());
    }

    #[test]
    fn scalars() {
        let t = Tensor::scalar_f32(2.5);
        assert!(t.dims().is_empty());
        assert_eq!(t.as_f32().unwrap(), &[2.5]);
        assert_eq!(Tensor::scalar_i32(7).as_i32().unwrap(), &[7]);
    }
}
