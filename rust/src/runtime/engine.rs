//! Executable cache + call interface over the PJRT CPU client.

use super::manifest::{ArtifactManifest, ArtifactSpec};
use super::tensor::Tensor;
use anyhow::{bail, Context};
use std::collections::HashMap;
use std::sync::Mutex;

/// Owns the PJRT client and the compiled executables.
///
/// `call` is thread-safe (the executable cache is mutex-guarded; PJRT CPU
/// execution itself is serialized per call which is correct for the
/// simulated-cluster usage where XLA-backend workers share one device).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    calls: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let manifest = ArtifactManifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            calls: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Total artifact invocations (perf accounting).
    pub fn call_count(&self) -> u64 {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Compile (or fetch cached) and pre-warm an artifact.
    pub fn warm(&self, name: &str) -> anyhow::Result<()> {
        let spec = self.manifest.get(name)?.clone();
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(name) {
            let exe = self.compile_spec(&spec)?;
            cache.insert(name.to_string(), exe);
        }
        Ok(())
    }

    fn compile_spec(&self, spec: &ArtifactSpec) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("loading HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {:?}", spec.name))
    }

    /// Execute artifact `name` with the given inputs; returns the tuple of
    /// outputs as host tensors (order per manifest).
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: got {} inputs, want {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(spec.inputs.iter()) {
            t.check_spec(s).with_context(|| format!("artifact {name}"))?;
        }
        self.warm(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).expect("warmed above");

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        // aot.py lowers with return_tuple=True: always a tuple.
        let elems = out_lit.to_tuple().context("untupling result")?;
        if elems.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: got {} outputs, want {}",
                elems.len(),
                spec.outputs.len()
            );
        }
        elems
            .iter()
            .zip(spec.outputs.iter())
            .map(|(lit, ospec)| Tensor::from_literal(lit, ospec))
            .collect()
    }
}

// SAFETY: all executable access (compile + execute) happens while holding
// the cache mutex, so PJRT objects are never used from two threads at once;
// the CPU PJRT client itself is thread-safe for the remaining read-only
// calls (platform_name).  The raw pointers inside the xla wrappers are
// process-global resources, not thread-affine.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}
