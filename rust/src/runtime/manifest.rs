//! Parser for `artifacts/manifest.txt` (the line-based format emitted by
//! `python/compile/aot.py`):
//!
//! ```text
//! artifact lasso_push
//! file lasso_push.hlo.txt
//! in x_sel float32 2048,64
//! in r float32 2048
//! out z float32 64
//! meta u 64
//! end
//! ```

use anyhow::{anyhow, bail, Context};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Element type of a tensor parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape+dtype of one artifact parameter or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    /// Empty for scalars (manifest dims "-").
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn n_elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: HashMap<String, String>,
}

impl ArtifactSpec {
    /// Look up a meta value parsed as T.
    pub fn meta_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// The full artifact set.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

fn parse_tensor_line(parts: &[&str]) -> anyhow::Result<TensorSpec> {
    if parts.len() != 4 {
        bail!("malformed tensor line: {parts:?}");
    }
    let dims = if parts[3] == "-" {
        Vec::new()
    } else {
        parts[3]
            .split(',')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<anyhow::Result<Vec<_>>>()?
    };
    Ok(TensorSpec { name: parts[1].to_string(), dtype: Dtype::parse(parts[2])?, dims })
}

impl ArtifactManifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {:?}/manifest.txt", dir))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir recorded for artifact file resolution).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Self> {
        let mut artifacts = HashMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            let ctx = || format!("manifest line {}: {line:?}", lineno + 1);
            match parts[0] {
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: artifact without end", ctx());
                    }
                    cur = Some(ArtifactSpec {
                        name: parts.get(1).ok_or_else(|| anyhow!(ctx()))?.to_string(),
                        file: PathBuf::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                        meta: HashMap::new(),
                    });
                }
                "file" => {
                    cur.as_mut().ok_or_else(|| anyhow!(ctx()))?.file =
                        dir.join(parts.get(1).ok_or_else(|| anyhow!(ctx()))?);
                }
                "in" => cur
                    .as_mut()
                    .ok_or_else(|| anyhow!(ctx()))?
                    .inputs
                    .push(parse_tensor_line(&parts).with_context(ctx)?),
                "out" => cur
                    .as_mut()
                    .ok_or_else(|| anyhow!(ctx()))?
                    .outputs
                    .push(parse_tensor_line(&parts).with_context(ctx)?),
                "meta" => {
                    let c = cur.as_mut().ok_or_else(|| anyhow!(ctx()))?;
                    c.meta.insert(
                        parts.get(1).ok_or_else(|| anyhow!(ctx()))?.to_string(),
                        parts.get(2).unwrap_or(&"").to_string(),
                    );
                }
                "end" => {
                    let c = cur.take().ok_or_else(|| anyhow!(ctx()))?;
                    artifacts.insert(c.name.clone(), c);
                }
                other => bail!("{}: unknown directive {other:?}", ctx()),
            }
        }
        if cur.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        Ok(ArtifactManifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact lasso_push
file lasso_push.hlo.txt
in x_sel float32 2048,64
in r float32 2048
in beta_sel float32 64
out z float32 64
meta u 64
end
artifact lasso_objective
file lasso_objective.hlo.txt
in r float32 2048
in beta float32 1024
in lam float32 -
out obj float32 -
end
";

    #[test]
    fn parses_two_artifacts() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("lasso_push").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].dims, vec![2048, 64]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.file, PathBuf::from("/a/lasso_push.hlo.txt"));
        assert_eq!(a.meta_parse::<usize>("u"), Some(64));
    }

    #[test]
    fn scalar_dims_are_empty() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        let o = m.get("lasso_objective").unwrap();
        assert!(o.inputs[2].dims.is_empty());
        assert_eq!(o.inputs[2].n_elems(), 1);
        assert!(o.outputs[0].dims.is_empty());
    }

    #[test]
    fn missing_artifact_errors() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_unterminated_block() {
        let bad = "artifact x\nfile x.hlo.txt\n";
        assert!(ArtifactManifest::parse(bad, PathBuf::from("/")).is_err());
    }

    #[test]
    fn rejects_unknown_directive() {
        let bad = "artifact x\nbogus y\nend\n";
        assert!(ArtifactManifest::parse(bad, PathBuf::from("/")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = "artifact x\nin a float64 3\nend\n";
        assert!(ArtifactManifest::parse(bad, PathBuf::from("/")).is_err());
    }
}
