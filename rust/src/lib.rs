//! # STRADS — Primitives for Dynamic Big Model Parallelism
//!
//! A reproduction of Lee, Kim, Zheng, Ho, Gibson & Xing, *"Primitives for
//! Dynamic Big Model Parallelism"* (CMU, 2014): a **model-parallel**
//! distributed ML framework built around three user-programmable
//! primitives — [`schedule`](scheduler), **push**, and **pull** — plus an
//! automatic BSP **sync**, executed by a rust coordinator over a simulated
//! cluster of workers.
//!
//! The compute hot paths are AOT-compiled JAX/Pallas graphs (HLO text
//! artifacts) executed through the PJRT C API ([`runtime`]); python never
//! runs at coordination time.  A [`backend`] native implementation provides
//! the same math in sparse rust for the model-size sweeps of the paper's
//! evaluation, cross-checked against the XLA path in integration tests.
//!
//! Layout (see DESIGN.md for the full inventory):
//!
//! * [`util`] — PRNG, CLI args, JSON/CSV emit, stats, small linalg
//! * [`sparse`] — CSC/CSR matrices for the Lasso/MF substrates
//! * [`datagen`] — the paper's synthetic workloads (§4.1 recipes)
//! * [`cluster`] — worker threads, star-topology network cost model,
//!   per-machine memory accounting, virtual cluster clock
//! * [`kvstore`] — partitioned model-variable store with leased shards
//! * [`scheduler`] — rotation / round-robin / dynamic-priority / random
//! * [`coordinator`] — the schedule→push→pull→sync round engine
//! * [`apps`] — LDA, MF, Lasso expressed as STRADS applications
//! * [`baselines`] — YahooLDA-style data-parallel LDA, ALS MF, Shotgun
//! * [`backend`] — native compute kernels mirroring the L1/L2 math
//! * [`runtime`] — PJRT client, artifact manifest, executable cache
//! * [`metrics`] — objectives, s-error (paper eq. 1), recorders
//! * [`figures`] — one harness per paper figure (3, 5, 8, 9, 10)
//! * [`trace`] — structured event traces, bit-exact replay, fingerprints
//! * [`testing`] — minimal property-testing framework (offline substrate)

pub mod apps;
pub mod backend;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod datagen;
pub mod figures;
pub mod kvstore;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod sparse;
pub mod testing;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
