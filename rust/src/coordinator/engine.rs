//! Generic schedule → push → pull → sync round loop.
//!
//! One round (paper Fig 1):
//!
//! 1. coordinator `schedule()` picks per-worker tasks;
//! 2. tasks are **pushed** to workers (bytes charged to the star network);
//! 3. workers compute partials over their data shards (measured on-thread);
//! 4. partials return to the coordinator (bytes charged);
//! 5. coordinator `pull()` aggregates and commits the variable update;
//! 6. the resulting sync message is broadcast (**sync**, BSP): FIFO worker
//!    mailboxes guarantee every worker applies it before its next push.
//!
//! The engine owns the virtual cluster clock: each round advances it by
//! `max_p(compute_p) + comm + coordinator_time`, making reported scaling
//! behaviour independent of the physical core count of the build machine.

use crate::cluster::{MemoryTracker, NetworkConfig, NetworkModel, VirtualClock, WorkerPool};
use crate::metrics::Recorder;
use crate::util::stats::Stopwatch;
use std::cell::RefCell;

/// A STRADS application: the user-defined primitives (paper Fig 2).
///
/// `push` and `sync` are associated functions (not `&self`) because they
/// execute on worker threads against worker-owned state; the coordinator
/// side (`schedule`, `pull`) owns the model variables.
pub trait StradsApp {
    /// What `schedule` dispatches to one worker.
    type Task: Send + 'static;
    /// What one worker's `push` returns.
    type Partial: Send + 'static;
    /// What `pull` broadcasts for BSP sync.
    type SyncMsg: Clone + Send + 'static;
    /// Per-worker state: data shard + local model caches.
    type WorkerState: Send + 'static;

    /// Pick the tasks for this round, one per worker (index-aligned).
    fn schedule(&mut self, round: u64) -> Vec<Self::Task>;

    /// Worker-side partial update over the worker's data shard.
    fn push(ws: &mut Self::WorkerState, task: Self::Task) -> Self::Partial;

    /// Aggregate worker partials and commit the update; the returned
    /// message is broadcast to all workers (None = nothing to sync).
    fn pull(&mut self, round: u64, partials: Vec<Self::Partial>) -> Option<Self::SyncMsg>;

    /// Worker-side application of a sync broadcast.
    fn sync(ws: &mut Self::WorkerState, msg: &Self::SyncMsg);

    /// Worker-side contribution to the global objective (shard loss).
    fn eval(ws: &mut Self::WorkerState) -> f64;

    /// Coordinator-side completion of the objective (adds regularizers /
    /// model-wide terms to the summed shard losses).
    fn objective_from(&self, shard_sum: f64) -> f64;

    /// Whether lower objective is better (Lasso/MF minimize; LDA maximizes
    /// log-likelihood).
    fn minimizing() -> bool {
        true
    }

    // ---- accounting hooks (network + memory modelling) ----
    fn task_bytes(task: &Self::Task) -> usize;
    fn partial_bytes(partial: &Self::Partial) -> usize;
    fn sync_bytes(msg: &Self::SyncMsg) -> usize;

    /// When true, task/partial payloads move worker↔worker (the rotation
    /// pattern: model slices pass between peers / are served by the
    /// partitioned KV store) and bypass the coordinator hub.  Scheduling
    /// metadata and sync broadcasts always use the hub.
    fn p2p_payloads() -> bool {
        false
    }

    /// Worker model-state residency in bytes (paper Fig 3); data shards are
    /// excluded by convention (identical across systems).
    fn model_bytes(ws: &Self::WorkerState) -> u64;
}

/// Engine run parameters.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub max_rounds: u64,
    /// Evaluate the objective every this many rounds.
    pub eval_every: u64,
    /// Stop when the objective improves less than this (relative) between
    /// consecutive evals.  None = run all rounds.
    pub rel_tol: Option<f64>,
    pub network: NetworkConfig,
    /// Per-machine model-memory capacity (None = unlimited).
    pub mem_capacity: Option<u64>,
    /// Label for the recorder.
    pub label: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            max_rounds: 100,
            eval_every: 10,
            rel_tol: None,
            network: NetworkConfig::ideal(),
            mem_capacity: None,
            label: "run".to_string(),
        }
    }
}

/// Outcome of an engine run.
#[derive(Debug)]
pub struct RunResult {
    pub recorder: Recorder,
    pub rounds_run: u64,
    pub virtual_secs: f64,
    pub wall_secs: f64,
    pub final_objective: f64,
    pub max_model_bytes_per_machine: u64,
    pub total_network_bytes: u64,
    /// Set if a worker exceeded the modelled memory capacity.
    pub oom: Option<String>,
}

/// The coordinator: owns the app, the worker pool, and all accounting.
pub struct Engine<A: StradsApp> {
    app: A,
    pool: WorkerPool<A::WorkerState>,
    network: NetworkModel,
    clock: VirtualClock,
    memory: MemoryTracker,
}

impl<A: StradsApp> Engine<A> {
    pub fn new(app: A, worker_states: Vec<A::WorkerState>, cfg: &RunConfig) -> Self {
        let n = worker_states.len();
        Engine {
            app,
            pool: WorkerPool::new(worker_states),
            network: NetworkModel::new(cfg.network, n),
            clock: VirtualClock::new(),
            memory: MemoryTracker::new(n, cfg.mem_capacity),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.pool.n_workers()
    }

    pub fn app(&self) -> &A {
        &self.app
    }

    pub fn app_mut(&mut self) -> &mut A {
        &mut self.app
    }

    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    /// Execute one schedule→push→pull→sync round.  Returns the measured
    /// coordinator-side seconds (schedule+pull).
    pub fn round(&mut self, round_idx: u64) -> f64 {
        let coord = Stopwatch::start();
        let tasks = self.app.schedule(round_idx);
        assert_eq!(
            tasks.len(),
            self.pool.n_workers(),
            "schedule must emit one task per worker"
        );
        for (p, t) in tasks.iter().enumerate() {
            if A::p2p_payloads() {
                self.network.send_p2p(p, A::task_bytes(t));
            } else {
                self.network.send_down(p, A::task_bytes(t));
            }
        }
        let schedule_secs = coord.secs();

        // dispatch push: tasks move into per-worker closures
        let slots = RefCell::new(tasks.into_iter().map(Some).collect::<Vec<_>>());
        let results = self.pool.run(|p| {
            let task = slots.borrow_mut()[p].take().expect("one task per worker");
            move |ws: &mut A::WorkerState| A::push(ws, task)
        });

        let mut partials = Vec::with_capacity(results.len());
        let mut compute_secs = Vec::with_capacity(results.len());
        for (p, (partial, secs)) in results.into_iter().enumerate() {
            if A::p2p_payloads() {
                self.network.send_p2p(p, A::partial_bytes(&partial));
            } else {
                self.network.send_up(p, A::partial_bytes(&partial));
            }
            partials.push(partial);
            compute_secs.push(secs);
        }

        let pull_sw = Stopwatch::start();
        let sync_msg = self.app.pull(round_idx, partials);
        let pull_secs = pull_sw.secs();

        if let Some(msg) = sync_msg {
            for p in 0..self.pool.n_workers() {
                self.network.send_down(p, A::sync_bytes(&msg));
            }
            self.pool.broadcast(|_| {
                let msg = msg.clone();
                move |ws: &mut A::WorkerState| A::sync(ws, &msg)
            });
        }

        let comm = self.network.round_time_and_reset();
        let coord_secs = schedule_secs + pull_secs;
        self.clock.advance_round(&compute_secs, comm, coord_secs);
        coord_secs
    }

    /// Query the current global objective (not charged to the clock: the
    /// paper evaluates off the critical path).
    pub fn evaluate(&mut self) -> f64 {
        let shard_sum: f64 = self
            .pool
            .run(|_| |ws: &mut A::WorkerState| A::eval(ws))
            .into_iter()
            .map(|(v, _)| v)
            .sum();
        self.app.objective_from(shard_sum)
    }

    /// Refresh the per-machine memory census.  Returns Err on capacity
    /// violation (the baseline-DNF mechanism of Fig 8).
    pub fn memory_census(&mut self) -> Result<u64, String> {
        let sizes = self
            .pool
            .run(|_| |ws: &mut A::WorkerState| A::model_bytes(ws));
        let mut err = None;
        for (p, (bytes, _)) in sizes.into_iter().enumerate() {
            if let Err(e) = self.memory.set(p, bytes) {
                err = Some(e.to_string());
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(self.memory.max_per_machine()),
        }
    }

    /// Run a full experiment loop with periodic evaluation and optional
    /// early stop.
    pub fn run(&mut self, cfg: &RunConfig) -> RunResult {
        let wall = Stopwatch::start();
        let mut recorder = Recorder::new(&cfg.label);
        let mut last_obj = self.evaluate();
        recorder.record(0, self.clock.seconds(), wall.secs(), last_obj);
        let mut oom = None;

        let mut rounds_run = 0;
        for r in 0..cfg.max_rounds {
            self.round(r);
            rounds_run = r + 1;
            if (r + 1) % cfg.eval_every == 0 || r + 1 == cfg.max_rounds {
                let obj = self.evaluate();
                recorder.record(r + 1, self.clock.seconds(), wall.secs(), obj);
                if let Err(e) = self.memory_census() {
                    oom = Some(e);
                    break;
                }
                if let Some(tol) = cfg.rel_tol {
                    let denom = last_obj.abs().max(1e-12);
                    if ((last_obj - obj).abs() / denom) < tol {
                        last_obj = obj;
                        break;
                    }
                }
                last_obj = obj;
            }
        }

        RunResult {
            rounds_run,
            virtual_secs: self.clock.seconds(),
            wall_secs: wall.secs(),
            final_objective: last_obj,
            max_model_bytes_per_machine: self.memory.max_per_machine(),
            total_network_bytes: self.network.total_bytes(),
            recorder,
            oom,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy app: distributed sum-reduction toward a target.  Each worker
    /// holds a number; pull averages them; sync overwrites.  Converges to
    /// consensus in one round — exercises every engine path.
    struct Consensus {
        n_workers: usize,
        committed: f64,
    }

    impl StradsApp for Consensus {
        type Task = u64;
        type Partial = f64;
        type SyncMsg = f64;
        type WorkerState = f64;

        fn schedule(&mut self, round: u64) -> Vec<u64> {
            vec![round; self.n_workers]
        }

        fn push(ws: &mut f64, _task: u64) -> f64 {
            *ws
        }

        fn pull(&mut self, _round: u64, partials: Vec<f64>) -> Option<f64> {
            self.committed =
                partials.iter().sum::<f64>() / partials.len() as f64;
            Some(self.committed)
        }

        fn sync(ws: &mut f64, msg: &f64) {
            *ws = *msg;
        }

        fn eval(ws: &mut f64) -> f64 {
            *ws
        }

        fn objective_from(&self, shard_sum: f64) -> f64 {
            shard_sum
        }

        fn task_bytes(_: &u64) -> usize {
            8
        }
        fn partial_bytes(_: &f64) -> usize {
            8
        }
        fn sync_bytes(_: &f64) -> usize {
            8
        }
        fn model_bytes(_: &f64) -> u64 {
            8
        }
    }

    #[test]
    fn consensus_in_one_round() {
        let app = Consensus { n_workers: 4, committed: 0.0 };
        let cfg = RunConfig { max_rounds: 2, eval_every: 1, ..Default::default() };
        let mut e = Engine::new(app, vec![1.0, 2.0, 3.0, 6.0], &cfg);
        assert_eq!(e.evaluate(), 12.0);
        e.round(0);
        // all workers now hold the mean 3.0
        assert_eq!(e.evaluate(), 12.0);
        assert_eq!(e.app().committed, 3.0);
    }

    #[test]
    fn run_records_trajectory_and_clock() {
        let app = Consensus { n_workers: 2, committed: 0.0 };
        let cfg = RunConfig {
            max_rounds: 5,
            eval_every: 1,
            network: NetworkConfig::gbps1(),
            label: "consensus".into(),
            ..Default::default()
        };
        let mut e = Engine::new(app, vec![0.0, 10.0], &cfg);
        let res = e.run(&cfg);
        assert_eq!(res.rounds_run, 5);
        assert_eq!(res.recorder.points().len(), 6); // initial + 5 evals
        assert!(res.virtual_secs > 0.0);
        assert!(res.total_network_bytes > 0);
        assert!(res.oom.is_none());
        assert_eq!(res.max_model_bytes_per_machine, 8);
    }

    #[test]
    fn memory_capacity_aborts_run() {
        let app = Consensus { n_workers: 2, committed: 0.0 };
        let cfg = RunConfig {
            max_rounds: 10,
            eval_every: 1,
            mem_capacity: Some(4), // below the 8-byte model
            ..Default::default()
        };
        let mut e = Engine::new(app, vec![0.0, 1.0], &cfg);
        let res = e.run(&cfg);
        assert!(res.oom.is_some());
        assert!(res.rounds_run < 10);
    }

    #[test]
    fn rel_tol_stops_early() {
        let app = Consensus { n_workers: 2, committed: 0.0 };
        let cfg = RunConfig {
            max_rounds: 100,
            eval_every: 1,
            rel_tol: Some(1e-9),
            ..Default::default()
        };
        let mut e = Engine::new(app, vec![5.0, 5.0], &cfg);
        let res = e.run(&cfg);
        assert!(res.rounds_run <= 2, "stopped at {}", res.rounds_run);
    }
}
